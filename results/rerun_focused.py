import sys, time
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks import table2_leaf, fig3_overhead, table3_production, fairness

t0=time.time()
table2_leaf.run("sent140", rounds=300, json_out="results/bench/table2_sent140_r300.json")
print(f"# sent140 r300 done {time.time()-t0:.0f}s", flush=True)
table2_leaf.run("shakespeare", rounds=300, json_out="results/bench/table2_shakespeare_r300.json")
print(f"# shakespeare r300 done {time.time()-t0:.0f}s", flush=True)
fig3_overhead.run("sent140", target_acc=0.70, max_rounds=600, json_out="results/bench/fig3_sent140.json")
print(f"# fig3 done {time.time()-t0:.0f}s", flush=True)
table3_production.run(rounds=800, json_out="results/bench/table3.json")
print(f"# table3 done {time.time()-t0:.0f}s", flush=True)
fairness.run("sent140", rounds=300, json_out="results/bench/fairness_sent140.json")
print(f"# fairness done {time.time()-t0:.0f}s", flush=True)
