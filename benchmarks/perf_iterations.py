"""§Perf hillclimbing experiments (EXPERIMENTS.md).

Three pairs, chosen per the assignment rules from the baseline roofline:
  H1 granite-3-2b x decode_32k    — collective/memory-bound decode: the
     train-mode FSDP weight sharding forces a weight all-gather on every
     decode step; serve-mode TP-only sharding eliminates it.
  H2 deepseek-v2-236b x train_4k  — most collective-bound pair:
     tensor-parallel MoE (baseline) vs expert-parallel all-to-all.
  H3 nemotron-4-340b x train_4k   — the paper-representative meta-step at
     the largest scale: (a) FOMAML vs 2nd-order MAML HLO FLOPs (paper
     claims ~33% compute saving), (b) bf16 outer-Adam moments,
     (c) Megatron-style activation sequence sharding.

Each experiment records hypothesis / change / before / after /
confirmed-or-refuted into results/perf/.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                      # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from benchmarks.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,  # noqa: E402
                                 calibrate_flops_scale, probe_train)
from repro.configs import INPUT_SHAPES, get_config  # noqa: E402
from repro.kernels.attention.ref import mha_reference  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.lm import layer_groups  # noqa: E402
from repro.sharding.context import set_mesh  # noqa: E402


def _cost(fn, args, mesh):
    with mesh:
        compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    from repro.launch.dryrun import parse_collectives
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total_bytes"],
            "coll_by_type": coll["bytes_by_type"]}


# --------------------------------------------- H1: serve weight sharding

def h1_decode_resharding(outdir):
    """Decode collective term: train-mode FSDP weight sharding vs
    serve-mode TP-only sharding for granite-3-2b decode_32k."""
    from benchmarks.roofline import probe_serve
    mesh = make_production_mesh()
    chips = int(np.prod(mesh.devices.shape))
    cfg = get_config("granite-3-2b")
    shape = INPUT_SHAPES["decode_32k"]
    base = probe_serve(cfg, shape, mesh, param_mode="train")
    opt = probe_serve(cfg, shape, mesh, param_mode="serve_tp")

    def terms(t):
        return {"compute_s": t["flops"] * 256 / (chips * PEAK_FLOPS),
                "memory_s": t["bytes"] * 256 / (chips * HBM_BW),
                "collective_s": t["coll"] * chips / (chips * ICI_BW)}

    before, after = terms(base), terms(opt)
    # per-device weight residency for the serve_tp layout
    from benchmarks.roofline import param_counts
    n_total, _ = param_counts(cfg)
    resident_gib = n_total * 2 / 16 / 2**30
    # --- iteration 2: the memory term barely moved; the KV cache is
    # replicated over the model axis (kv_heads 8 < 16) and the f32 upcast
    # in the XLA attention path re-materializes it. Shard the cache
    # LENGTH dim over the model axis (flash-decode partial softmax).
    opt2 = probe_serve(cfg, shape, mesh, param_mode="serve_tp",
                       cache_seq_shard=True)
    after2 = terms(opt2)

    rec = {
        "pair": "granite-3-2b x decode_32k",
        "iterations": [
            {
                "hypothesis": "with train-mode FSDP sharding, every decode "
                              "step all-gathers each layer's weights over "
                              "the data axis; TP-only serve sharding keeps "
                              "weights resident "
                              f"({resident_gib:.2f} GiB/chip, fits v5e) "
                              "and eliminates them.",
                "change": "param_pspecs(mode='serve_tp')",
                "before": {**before,
                           "coll_by_type": base["probes"]["1"]["coll_by_type"]},
                "after": {**after,
                          "coll_by_type": opt["probes"]["1"]["coll_by_type"]},
                "verdict": "PARTIALLY REFUTED: the weight-partial "
                           "all-reduces disappeared (25.9MB -> 0.13MB per "
                           "2-layer probe) but collective_s only moved "
                           "~2% and memory_s not at all — decode is NOT "
                           "weight-gather bound at batch 128; the "
                           "replicated KV cache dominates.",
            },
            {
                "hypothesis": "kv_heads (8) < model axis (16) forces full "
                              "cache replication over the model axis: "
                              "every chip reads the whole 10.7 GiB local "
                              "cache slice each step. Sharding the cache "
                              "LENGTH dim over the model axis divides "
                              "cache reads by 16 at the cost of small "
                              "partial-softmax stat collectives.",
                "change": "cache_pspecs(seq_shard=True) "
                          "(sharding/rules.py)",
                "before": after,
                "after": after2,
                "memory_improvement_x": (after["memory_s"] / after2["memory_s"]
                                         if after2["memory_s"] else None),
                "verdict": ("CONFIRMED" if after2["memory_s"]
                            < after["memory_s"] * 0.5 else "REFUTED"),
            },
        ],
    }
    json.dump(rec, open(os.path.join(outdir, "h1_decode_resharding.json"),
                        "w"), indent=1)
    print(f"perf.h1,granite decode_32k,"
          f"memory_s {before['memory_s']:.4f} -> {after['memory_s']:.4f} "
          f"-> {after2['memory_s']:.4f}, collective_s "
          f"{before['collective_s']:.4f} -> {after['collective_s']:.4f} "
          f"-> {after2['collective_s']:.4f}", flush=True)
    return rec


# ------------------------------------------------------------ H2: EP MoE

def h2_ep_moe(outdir):
    """Collective-term effect of expert-parallel all-to-all MoE vs the
    TP baseline for deepseek-v2 train_4k (probe-extrapolated)."""
    mesh = make_production_mesh()
    chips = int(np.prod(mesh.devices.shape))
    set_mesh(mesh)
    cfg = get_config("deepseek-v2-236b")
    shape = INPUT_SHAPES["train_4k"]

    # baseline probes reuse the roofline sweep artifact when present
    base_path = "results/roofline/deepseek-v2-236b__train_4k.json"
    if os.path.exists(base_path):
        bj = json.load(open(base_path))
        base = {"coll": bj["collective_bytes"] / chips,
                "probes": bj["probes"]}
    else:
        base = probe_train(cfg, shape, mesh)
    ep_cfg = dataclasses.replace(cfg, moe_impl="ep")
    ep = probe_train(ep_cfg, shape, mesh)

    before = base["coll"] * chips / (chips * ICI_BW)
    after = ep["coll"] * chips / (chips * ICI_BW)
    rec = {
        "pair": "deepseek-v2-236b x train_4k",
        "hypothesis": "TP-MoE all-gathers FSDP-sharded expert weights "
                      "(160 experts x 3 x 5120x1536 bf16 per layer) every "
                      "layer; EP keeps expert weights resident (sharded "
                      "over the model axis) and moves only the routed "
                      "tokens (2 all_to_all of ~T*k*d bytes).",
        "change": "repro/sharding/ep_moe.py shard_map all-to-all dispatch "
                  "(cfg.moe_impl='ep')",
        "before": {"collective_s": before,
                   "coll_by_type": base["probes"]["1"]["coll_by_type"]},
        "after": {"collective_s": after,
                  "coll_by_type": ep["probes"]["1"]["coll_by_type"]},
        "improvement_x": before / after if after > 0 else None,
        "confirmed": after < before,
    }
    json.dump(rec, open(os.path.join(outdir, "h2_ep_moe.json"), "w"),
              indent=1)
    print(f"perf.h2,deepseek train_4k,collective_s {before:.2f} -> "
          f"{after:.2f} confirmed={rec['confirmed']}", flush=True)
    return rec


# ----------------------------------------------------- H3: meta-step fit

def h3_metastep(outdir):
    """(a) FOMAML vs MAML HLO FLOPs (paper's ~33% claim); (b) bf16 Adam
    moments; (c) activation seq sharding — memory fit for nemotron."""
    from repro.launch.dryrun import dryrun_one
    mesh = make_production_mesh()
    set_mesh(mesh)
    rec = {"pair": "nemotron-4-340b x train_4k", "iterations": []}

    # (a) order-1 vs order-2 on smollm probes (fast, same code path)
    cfg_s = get_config("smollm-360m")
    shape = INPUT_SHAPES["train_4k"]
    fo = probe_train(cfg_s, shape, mesh, algo="fomaml")
    so = probe_train(cfg_s, shape, mesh, algo="maml")
    ratio = so["flops"] / fo["flops"] if fo["flops"] else None
    rec["iterations"].append({
        "hypothesis": "paper §4.2: FOMAML ~33% cheaper than 2nd-order "
                      "MAML (drops the double-backward).",
        "change": "probe meta-step FLOPs, algo=maml vs fomaml "
                  "(smollm-360m, same shapes)",
        "before_flops": so["flops"], "after_flops": fo["flops"],
        "maml_over_fomaml": ratio,
        "confirmed": bool(ratio and ratio > 1.2),
    })
    print(f"perf.h3a,smollm train_4k,MAML/FOMAML flops={ratio:.2f}",
          flush=True)

    # (b)+(c) nemotron memory: baseline vs bf16 moments vs +seq sharding
    variants = [
        ("baseline", {}),
        ("bf16_adam", {"opt_state_dtype": "bfloat16"}),
        ("bf16_adam+seq_shard", {"opt_state_dtype": "bfloat16",
                                 "shard_seq": True}),
    ]
    mems = {}
    for name, kw in variants:
        r = dryrun_one("nemotron-4-340b", "train_4k", extra_tag=name, **kw)
        mems[name] = r.get("memory", {})
        print(f"perf.h3b,nemotron train_4k,{name},"
              f"args={mems[name].get('argument_bytes', 0)/2**30:.2f}GiB,"
              f"temp={mems[name].get('temp_bytes', 0)/2**30:.2f}GiB",
              flush=True)
    rec["iterations"].append({
        "hypothesis": "Adam moments in f32 are 10.6 GiB/chip for 340B over "
                      "256 chips; bf16 moments halve that. Remat'd "
                      "activations (~96 layer boundaries x per-seq slices) "
                      "dominate temp; sharding the residual stream's "
                      "sequence dim over the model axis divides stored "
                      "activations by 16 at the cost of per-block "
                      "all-gathers.",
        "change": "adam(state_dtype=bf16); cfg.shard_seq=True "
                  "(with_sharding_constraint at block boundaries)",
        "memory": {k: v for k, v in mems.items()},
    })
    json.dump(rec, open(os.path.join(outdir, "h3_metastep.json"), "w"),
              indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="h1,h2,h3")
    ap.add_argument("--outdir", default="results/perf")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    only = set(args.only.split(","))
    if "h1" in only:
        h1_decode_resharding(args.outdir)
    if "h2" in only:
        h2_ep_moe(args.outdir)
    if "h3" in only:
        h3_metastep(args.outdir)


if __name__ == "__main__":
    main()
