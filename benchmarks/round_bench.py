"""End-to-end round benchmark: tree vs packed vs packed+client-plane.

The meta-step bench (``meta_step_bench.py``) timed the *server* half of
the pipeline introduced in PR 1; this bench times the unit the paper
actually iterates — one full FedMeta round (m clients' ModelTraining
inner loop + aggregation + outer Adam) — across

  pipeline:    "tree"         — per-leaf everything (seed path)
               "packed"       — PR 1: flat server half (fused (m, N)
                                aggregation + single-pass flat Adam),
                                tree client inner loop
               "packed_plane" — this PR: the client inner loop also runs
                                on flat memory — chunks of clients adapt
                                in lockstep on a (C, N) plane with the
                                fused inner-update kernel, per-client
                                meta-gradients come out flat
  client_axis: "vmap", "scan", "chunked@k", "sharded" (shard_map over a
               mesh built from every visible device; 1 device on a plain
               CPU host — pass --devices N, matched to the physical core
               count, to see real client parallelism)
  scale:       two model scales; "large" is a deep narrow stack (the
               many-leaf regime where per-leaf dispatch dominates and
               the flat plane pays off most)

recording interleaved-min wall time plus XLA cost/memory analysis per
row (same caveat as the meta-step bench: scan bodies are counted once).

The headline summary number is
``round_speedup_client_plane_vs_packed`` — this PR's full client plane
(fused inner loop + shardable client axis) vs the PR 1 packed pipeline
as it shipped (client axis pinned to one device), best configuration
each, at the larger scale, measured at round granularity. Same-axis
ratios are also recorded for transparency. The second-order algorithms
(maml/meta-sgd order 2) are correct through the client plane but pay a
flat↔tree conversion penalty in reverse-over-reverse mode on CPU — use
them with client_plane=False there (no automatic fallback); see
DESIGN.md §9.

The second half of the bench (``async``) times the round DRIVER, not
just the jitted step: a full `FederatedTrainer.run` over a synthetic
client pool with LEAF-scale local datasets, where each round's host
half (numpy task sampling + staging) costs a real fraction of the
device half. Variants: the PR 3 synchronous loop (prefetch_depth=0,
per-round float() metrics readback) vs the async engine at
prefetch_depth∈{1,2} (deferred metrics, flush at exit) vs fused-K
(lax.scan round blocks). Headline: ``async_speedup`` — sync wall over
the best pipelined wall, at the large scale (DESIGN.md §12). The loop
math is bit-identical across variants (tests/test_async_engine.py), so
this is pure overlap/dispatch win.

The third section (``population``) measures the PR 7 claim directly:
a femnist population served lazily from an independent-mode
`ClientRegistry` (O(1) per-client seeding, bounded LRU cache) through
the population-plane trainer (over-selection + deadline + worker pool),
at 10^3 / 10^4 / 10^5 clients. Each size runs in its OWN subprocess so
``ru_maxrss`` — which is monotone within a process — is a true
per-size peak; the recorded ``peak_rss_mb`` staying flat across three
decades of population is the bounded-memory evidence, and
``rounds_per_s`` shows round throughput is population-size independent.
``--population-only`` re-runs just this section and MERGES it into an
existing BENCH_round.json without touching the other sections' numbers.

Usage:
  PYTHONPATH=src python benchmarks/round_bench.py            # full
  PYTHONPATH=src python benchmarks/round_bench.py --dry-run  # CI smoke
  PYTHONPATH=src python benchmarks/round_bench.py --population-only
Emits results/bench/BENCH_round.json (see --out).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.meta_step_bench import _analyze, _build_task, \
    _time_interleaved

# deep narrow stacks: many leaves, modest per-leaf FLOPs — the regime
# where the inner loop is dispatch-bound and the client plane collapses
# per-client per-leaf op soup into one fused pass per inner step
SCALES = {
    "small": dict(layers=8, width=32, in_dim=16),
    "large": dict(layers=48, width=32, in_dim=16),
    "tiny": dict(layers=3, width=16, in_dim=8),       # --dry-run only
}
INNER_STEPS = 3
CLIENTS = 16

# driver-level async bench: a client pool with LEAF-scale local data so
# host-side sampling (support/query split copies the client's full
# local arrays) is a realistic fraction of the round — the overlap the
# async engine exists to reclaim
ASYNC_SCALES = {
    "large": dict(model="large", pool=256, client_samples=8192, m=16,
                  batch=64, rounds=16, warmup=8, fuse=8),
    "tiny": dict(model="tiny", pool=16, client_samples=64, m=4,
                 batch=8, rounds=4, warmup=2, fuse=2),    # --dry-run
}
ASYNC_VARIANTS = (
    # PR 3 synchronous driver: inline sampling, per-round float() sync
    ("sync", dict()),
    ("prefetch1", dict(prefetch_depth=1, flush_every=0)),
    ("prefetch2", dict(prefetch_depth=2, flush_every=0)),
    # fused-K: lax.scan over K-round blocks staged as one buffer
    ("fused", dict(prefetch_depth=2, flush_every=0)),     # + fuse_rounds
)


def _bench_async(scale_key: str, reps: int):
    """Wall time per round of the full driver loop, per engine variant.

    Every variant replays the identical seeded run (bit-identical
    history — tests/test_async_engine.py), so wall deltas are pure
    pipelining. Warmup rounds compile the per-round step and, for the
    fused variant, the K-round scan block (`warmup` is a multiple of
    K so the timed region never compiles)."""
    import jax

    from repro.data.federated import ClientData, TaskStream
    from repro.federated.server import FederatedTrainer
    from repro.optim import adam

    cfg = ASYNC_SCALES[scale_key]
    algo, model_init, *_ = _build_task(
        SCALES[cfg["model"]], cfg["m"], cfg["batch"], algo_name="fomaml",
        inner_steps=INNER_STEPS)
    rng = np.random.RandomState(0)
    D = SCALES[cfg["model"]]["in_dim"]
    clients = [
        ClientData(rng.normal(0, 1, (cfg["client_samples"], D))
                   .astype(np.float32),
                   rng.normal(0, 1, (cfg["client_samples"], D))
                   .astype(np.float32))
        for _ in range(cfg["pool"])]

    stream = TaskStream(clients, cfg["m"], 0.5, cfg["batch"], cfg["batch"],
                        np.random.RandomState(0))
    t0 = time.perf_counter()
    for _ in range(max(2, cfg["warmup"])):
        stream.next()
    sample_ms = (time.perf_counter() - t0) / max(2, cfg["warmup"]) * 1e3

    rows = []
    for name, knobs in ASYNC_VARIANTS:
        if name == "fused":
            knobs = dict(knobs, fuse_rounds=cfg["fuse"])
        tr = FederatedTrainer(
            algo, adam(1e-3), clients, cfg["m"], support_frac=0.5,
            support_size=cfg["batch"], query_size=cfg["batch"], seed=0,
            packed=True, **knobs)
        state = tr.init(jax.random.PRNGKey(0), model_init)
        state = tr.run(state, cfg["warmup"])
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            state = tr.run(state, cfg["rounds"])
            walls.append((time.perf_counter() - t0) / cfg["rounds"])
        rows.append({"scale": scale_key, "variant": name,
                     "wall_ms_per_round": float(np.min(walls) * 1e3),
                     "rounds_timed": cfg["rounds"] * reps,
                     "sample_ms": sample_ms, **knobs})
        print(f"round.async.{scale_key}.{name},"
              f"{rows[-1]['wall_ms_per_round'] * 1e3:.0f},"
              f"sample_ms={sample_ms:.2f}", flush=True)
    return rows


# bytes-on-the-wire section (DESIGN.md §17): same driver bench, upload
# codec axis. Each variant reruns the identical seeded driver loop with
# a different wire format; rows record the measured wall per round AND
# the codec-true per-client upload bytes, so the summary shows the
# compression multiplier compounding (bf16 2×, int8 ~4×, top-5% bf16
# values ~13× vs dense f32).
COMM_VARIANTS = (
    ("f32", {}),
    ("bf16", dict(block_dtype="bfloat16")),
    ("int8+ef", dict(codec="int8")),
    ("topk0.05+ef", dict(codec="topk", block_dtype="bfloat16")),
)


def _bench_comm(scale_key: str, reps: int):
    """Wall time per round + true upload bytes per client, per codec."""
    import jax
    import jax.numpy as jnp

    from repro.data.federated import ClientData
    from repro.federated.server import FederatedTrainer
    from repro.kernels.meta_update.compress import CompressionConfig
    from repro.optim import adam

    cfg = ASYNC_SCALES[scale_key]
    algo, model_init, *_ = _build_task(
        SCALES[cfg["model"]], cfg["m"], cfg["batch"], algo_name="fomaml",
        inner_steps=INNER_STEPS)
    rng = np.random.RandomState(0)
    D = SCALES[cfg["model"]]["in_dim"]
    clients = [
        ClientData(rng.normal(0, 1, (cfg["client_samples"], D))
                   .astype(np.float32),
                   rng.normal(0, 1, (cfg["client_samples"], D))
                   .astype(np.float32))
        for _ in range(cfg["pool"])]

    rows = []
    for name, knobs in COMM_VARIANTS:
        kw = {}
        if knobs.get("block_dtype"):
            kw["block_dtype"] = jnp.dtype(knobs["block_dtype"])
        if knobs.get("codec"):
            kw["compression"] = CompressionConfig(
                knobs["codec"], topk_frac=0.05)
        tr = FederatedTrainer(
            algo, adam(1e-3), clients, cfg["m"], support_frac=0.5,
            support_size=cfg["batch"], query_size=cfg["batch"], seed=0,
            packed=True, **kw)
        state = tr.init(jax.random.PRNGKey(0), model_init)
        state = tr.run(state, cfg["warmup"])
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            state = tr.run(state, cfg["rounds"])
            walls.append((time.perf_counter() - t0) / cfg["rounds"])
        per_client = (tr.comm.grad_bytes if tr.comm.grad_bytes is not None
                      else tr.comm.phi_bytes)
        rows.append({"scale": scale_key, "variant": name,
                     "codec": tr.comm.codec,
                     "wall_ms_per_round": float(np.min(walls) * 1e3),
                     "upload_bytes_per_client": int(per_client),
                     "phi_bytes": int(tr.comm.phi_bytes),
                     "rounds_timed": cfg["rounds"] * reps})
        print(f"round.comm.{scale_key}.{name},"
              f"{rows[-1]['wall_ms_per_round'] * 1e3:.0f},"
              f"upload_B={per_client}", flush=True)
    return rows


def _summarize_comm(comm_rows):
    if not comm_rows:
        return {}
    base = next((r for r in comm_rows if r["variant"] == "f32"), None)
    if base is None:
        return {}
    out = {"upload_bytes_per_client": {
        r["variant"]: r["upload_bytes_per_client"] for r in comm_rows}}
    for r in comm_rows:
        if r["variant"] != "f32":
            out[f"upload_multiplier_{r['variant']}"] = round(
                base["upload_bytes_per_client"]
                / r["upload_bytes_per_client"], 2)
            out[f"wall_overhead_{r['variant']}"] = round(
                r["wall_ms_per_round"] / base["wall_ms_per_round"], 3)
    return {"comm": out}


POPULATION_SIZES = (1_000, 10_000, 100_000)
POPULATION_SIZES_DRY = (200, 1_000)


def _population_child(n_clients: int, rounds: int, cache: int) -> dict:
    """One population size, measured in THIS process (spawned as a
    subprocess so ru_maxrss is a per-size peak): 20-round femnist
    population-plane run off the independent-mode lazy registry."""
    import resource

    import jax

    from repro.core import classification_loss, make_algorithm
    from repro.federated.experiment import DATASETS
    from repro.federated.population import UnreliabilityConfig
    from repro.federated.server import FederatedTrainer
    from repro.optim import adam

    su = DATASETS["femnist"]
    reg = su["data"](n_clients, 0, lazy=True, independent=True,
                     cache_clients=cache)
    train, _, _ = reg.split_clients(seed=0)
    model = su["model"]()
    algo = make_algorithm("fomaml", *classification_loss(model.apply),
                          inner_lr=0.05)
    tr = FederatedTrainer(
        algo, adam(1e-3), train, 8, support_frac=0.2, support_size=16,
        query_size=16, seed=0, packed=True,
        unreliability=UnreliabilityConfig(fail_rate=0.2, seed=0),
        over_select=0.5, round_deadline=1.6, pool_workers=2)
    state = tr.init(jax.random.PRNGKey(0), model.init)
    state = tr.run(state, 2)              # compile outside the timing
    t0 = time.perf_counter()
    tr.run(state, rounds)
    wall = time.perf_counter() - t0
    return {
        "clients": n_clients, "rounds": rounds,
        "rounds_per_s": rounds / wall,
        "wall_s": wall,
        "peak_rss_mb": resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss / 1024.0,
        "arrived_total": tr.history[-1]["arrived_total"],
        "selected_total": tr.history[-1]["selected_total"],
        "cache": reg.cache_stats(),
    }


def _bench_population(dry: bool):
    """Spawn one subprocess per population size (fresh ru_maxrss each)
    and collect the per-size rows."""
    sizes = POPULATION_SIZES_DRY if dry else POPULATION_SIZES
    rounds, cache = (3, 32) if dry else (20, 64)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH", ""),
                    os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__)))) if p)
    rows = []
    for n in sizes:
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--population-child", str(n), "--population-rounds",
             str(rounds), "--population-cache", str(cache)],
            capture_output=True, text=True, env=env, check=False)
        if proc.returncode != 0:
            raise RuntimeError(
                f"population child (n={n}) failed:\n{proc.stderr[-2000:]}")
        row = json.loads(proc.stdout.strip().splitlines()[-1])
        rows.append(row)
        print(f"round.population.{n},rounds_per_s="
              f"{row['rounds_per_s']:.2f},peak_rss_mb="
              f"{row['peak_rss_mb']:.0f},peak_resident="
              f"{row['cache']['peak_resident']}", flush=True)
    return rows


def _summarize_population(pop_rows):
    if not pop_rows:
        return {}
    lo, hi = pop_rows[0], pop_rows[-1]
    return {"population": {
        "max_clients": hi["clients"],
        "rounds_per_s_at_max": hi["rounds_per_s"],
        "peak_rss_mb_at_max": hi["peak_rss_mb"],
        # bounded-memory evidence: RSS growth across the population
        # decades (≈1.0 = resident set independent of fleet size)
        "rss_growth_vs_smallest": hi["peak_rss_mb"] / lo["peak_rss_mb"],
        "cache_peak_resident": hi["cache"]["peak_resident"],
    }}


def run(*, dry: bool = False, reps: int = 10, algo_name: str = "fomaml",
        json_out: str = "results/bench/BENCH_round.json"):
    import jax

    from repro.core.fedmeta import (init_packed_state, make_meta_train_step,
                                    make_packed_meta_train_step)
    from repro.optim import adam
    from repro.utils.flat import plane_for
    from repro.utils.pytree import tree_size

    scales = ["tiny"] if dry else ["small", "large"]
    m = 4 if dry else CLIENTS
    batch = 8
    reps = 1 if dry else reps
    axes = [("vmap", None), ("sharded", None)] if dry else \
        [("vmap", None), ("scan", None), ("chunked", 4), ("sharded", None)]

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("clients",))

    rows = []
    for scale in scales:
        algo, model_init, sup, qry, weights = _build_task(
            SCALES[scale], m, batch, algo_name=algo_name,
            inner_steps=INNER_STEPS)
        opt = adam(1e-3)
        phi = algo.init_state(jax.random.PRNGKey(0), model_init)
        plane = plane_for(phi)
        n_params = tree_size(phi)

        configs = []
        for pipeline in ("tree", "packed", "packed_plane"):
            for axis, chunk in axes:
                if pipeline == "tree":
                    step = make_meta_train_step(
                        algo, opt, client_axis=axis, client_chunk=chunk,
                        mesh=mesh, donate=False)
                    state = {"phi": phi, "opt": opt.init(phi)}
                else:
                    step = make_packed_meta_train_step(
                        algo, opt, plane, client_axis=axis,
                        client_chunk=chunk, impl="xla",
                        client_plane=(pipeline == "packed_plane"),
                        mesh=mesh, donate=False)
                    state = init_packed_state(opt, plane, phi)
                configs.append({
                    "step": step, "state": state,
                    "args": (sup, qry, weights),
                    "row": {"scale": scale, "pipeline": pipeline,
                            "client_axis": axis, "client_chunk": chunk,
                            "clients": m, "inner_steps": INNER_STEPS,
                            "algo": algo.name, "devices": n_dev,
                            "n_params": int(n_params),
                            "n_padded": int(plane.n_padded)},
                })
        walls = _time_interleaved(configs, reps)
        for c in configs:
            analysis, _ = _analyze(c["step"], c["state"], *c["args"])
            wall_us, wall_med = walls[id(c)]
            row = {**c["row"], "wall_us_per_round": wall_us,
                   "wall_us_median": wall_med, **analysis}
            rows.append(row)
            chunk_tag = (f"@{row['client_chunk']}"
                         if row["client_chunk"] else "")
            print(f"round.{scale}.{row['pipeline']}."
                  f"{row['client_axis']}{chunk_tag},{wall_us:.0f},"
                  f"temp={analysis['temp_bytes']}", flush=True)

    async_rows = _bench_async("tiny" if dry else "large",
                              reps=1 if dry else 2)
    comm_rows = _bench_comm("tiny" if dry else "large",
                            reps=1 if dry else 2)
    pop_rows = _bench_population(dry)

    report = {
        "bench": "round",
        "backend": jax.default_backend(),
        "devices": n_dev,
        "dry_run": dry,
        "reps": reps,
        "rows": rows,
        "async_rows": async_rows,
        "comm_rows": comm_rows,
        "population_rows": pop_rows,
        "summary": {**_summarize(rows, async_rows),
                    **_summarize_comm(comm_rows),
                    **_summarize_population(pop_rows)},
    }
    os.makedirs(os.path.dirname(json_out) or ".", exist_ok=True)
    with open(json_out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {json_out}", flush=True)
    return report


def run_population_only(*, dry: bool = False, json_out: str):
    """Run just the population section and merge it into an existing
    report (the other sections' committed numbers are left untouched)."""
    return _run_section_only("population_rows", _bench_population(dry),
                             _summarize_population, dry=dry,
                             json_out=json_out)


def run_comm_only(*, dry: bool = False, json_out: str):
    """Run just the bytes-on-the-wire section (§17) and merge it into an
    existing report, population-only style."""
    rows = _bench_comm("tiny" if dry else "large", reps=1 if dry else 2)
    return _run_section_only("comm_rows", rows, _summarize_comm,
                             dry=dry, json_out=json_out)


def _run_section_only(key, rows, summarize, *, dry, json_out):
    report = {"bench": "round", "dry_run": dry, "summary": {}}
    if os.path.exists(json_out):
        with open(json_out) as f:
            report = json.load(f)
    report[key] = rows
    report.setdefault("summary", {}).update(summarize(rows))
    os.makedirs(os.path.dirname(json_out) or ".", exist_ok=True)
    with open(json_out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {json_out} ({key} section merged)", flush=True)
    return report


def _summarize_async(async_rows):
    """sync driver wall vs the best pipelined variant, same seed, same
    (bit-identical) math — the measured overlap win."""
    sync = next((r for r in async_rows if r["variant"] == "sync"), None)
    piped = [r for r in async_rows if r["variant"] != "sync"]
    if not (sync and piped):
        return {}
    best = min(piped, key=lambda r: r["wall_ms_per_round"])
    out = {
        "async_speedup": sync["wall_ms_per_round"] / best["wall_ms_per_round"],
        "async_headline": {
            "sync_wall_ms": sync["wall_ms_per_round"],
            "best_variant": best["variant"],
            "best_wall_ms": best["wall_ms_per_round"],
            "host_sample_ms": sync["sample_ms"],
        },
    }
    for r in piped:
        out[f"async_speedup_{r['variant']}"] = (
            sync["wall_ms_per_round"] / r["wall_ms_per_round"])
    return out


def _summarize(rows, async_rows=()):
    out = {}
    scales = {r["scale"] for r in rows}
    big = "large" if "large" in scales else sorted(scales)[-1]
    out["largest_scale"] = big

    def pick(pipeline, axis):
        for r in rows:
            if (r["scale"] == big and r["pipeline"] == pipeline
                    and r["client_axis"] == axis):
                return r
        return None

    def best(pipeline, axes):
        cand = [pick(pipeline, a) for a in axes]
        cand = [r for r in cand if r]
        return min(cand, key=lambda r: r["wall_us_per_round"]) \
            if cand else None

    # headline: this PR's full client plane (fused inner loop + the
    # shardable client axis) vs the PR 1 packed pipeline as it shipped
    # (client axis pinned to one device: vmap/scan/chunked only), best
    # configuration each, at the larger scale — round granularity
    pr1 = best("packed", ("vmap", "scan", "chunked"))
    plane = best("packed_plane", ("vmap", "scan", "chunked", "sharded"))
    if pr1 and plane:
        out["round_speedup_client_plane_vs_packed"] = (
            pr1["wall_us_per_round"] / plane["wall_us_per_round"])
        out["headline"] = {
            "pr1_packed_best": f"{pr1['pipeline']}/{pr1['client_axis']}",
            "client_plane_best":
                f"{plane['pipeline']}/{plane['client_axis']}",
            "wall_us_pr1": pr1["wall_us_per_round"],
            "wall_us_client_plane": plane["wall_us_per_round"],
        }

    # transparency: same-axis ratios, including the sharded axis applied
    # to the PR 1 pipeline (the sharded axis alone, without the fused
    # inner loop, is also new in this PR)
    for axis in ("vmap", "scan", "chunked", "sharded"):
        pk, pl_ = pick("packed", axis), pick("packed_plane", axis)
        if pk and pl_:
            out[f"round_speedup_client_plane_vs_packed_{axis}"] = (
                pk["wall_us_per_round"] / pl_["wall_us_per_round"])

    # and vs the seed default (tree/vmap), for the trajectory
    tree_v = pick("tree", "vmap")
    if tree_v and plane:
        out["round_speedup_client_plane_vs_tree_vmap"] = (
            tree_v["wall_us_per_round"] / plane["wall_us_per_round"])
    out.update(_summarize_async(async_rows))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny scale, 1 rep — CI smoke")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--algo", default="fomaml")
    ap.add_argument("--population-only", action="store_true",
                    help="run just the population-scaling section and "
                         "merge it into the existing --out report")
    ap.add_argument("--comm-only", action="store_true",
                    help="run just the bytes-on-the-wire (codec) "
                         "section and merge it into the existing --out "
                         "report")
    ap.add_argument("--population-child", type=int, default=0,
                    help=argparse.SUPPRESS)   # internal: subprocess mode
    ap.add_argument("--population-rounds", type=int, default=20,
                    help=argparse.SUPPRESS)
    ap.add_argument("--population-cache", type=int, default=64,
                    help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host CPU devices (sets XLA_FLAGS; must "
                         "run before jax is imported — match the "
                         "physical core count for a fair sharded row)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: the committed artifact "
                         "for full runs, the gitignored smoke/ dir for "
                         "--dry-run so a doc-following smoke cannot "
                         "clobber the full-run numbers)")
    args = ap.parse_args()
    if args.out is None:
        args.out = ("results/bench/smoke/BENCH_round.json" if args.dry_run
                    else "results/bench/BENCH_round.json")
    if args.population_child:
        print(json.dumps(_population_child(
            args.population_child, args.population_rounds,
            args.population_cache)), flush=True)
        return
    if args.population_only:
        run_population_only(dry=args.dry_run, json_out=args.out)
        return
    if args.comm_only:
        run_comm_only(dry=args.dry_run, json_out=args.out)
        return
    if args.devices:
        import os
        import sys
        if "jax" in sys.modules:
            raise RuntimeError("--devices must be set before jax import; "
                               "run round_bench.py standalone")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    run(dry=args.dry_run, reps=args.reps, algo_name=args.algo,
        json_out=args.out)


if __name__ == "__main__":
    main()
