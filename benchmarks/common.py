"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import jax

from repro.core import classification_loss, make_algorithm
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import (FederatedTrainer, evaluate_global,
                                    evaluate_meta)
from repro.optim import adam

META_METHODS = ("maml", "fomaml", "meta-sgd")


def _rounds_to_target(history, target_acc):
    if not target_acc:
        return None
    from repro.federated.experiment import comm_to_target
    return (comm_to_target(history, target_acc) or {}).get("rounds")


def run_fedmeta(method, model, dataset_splits, *, rounds, clients_per_round,
                support_frac, support_size, query_size, inner_lr, outer_lr,
                eval_every=0, target_acc=None, seed=0):
    """Train one FedMeta method; returns dict with accuracy/comm history."""
    train, val, test = dataset_splits
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm(method, loss_fn, eval_fn, inner_lr=inner_lr)
    tr = FederatedTrainer(algo, adam(outer_lr), train,
                          clients_per_round=clients_per_round,
                          support_frac=support_frac,
                          support_size=support_size, query_size=query_size,
                          seed=seed)
    state = tr.init(jax.random.PRNGKey(seed), model.init)
    tr.measure_flops(state)
    t0 = time.time()
    ev = eval_every or max(rounds // 8, 1)
    state = tr.run(state, rounds, eval_every=ev, eval_clients=val)
    test_acc, per_client, _ = evaluate_meta(
        algo, tr.phi_tree(state), test, support_frac=support_frac,
        support_size=support_size, query_size=query_size, seed=seed,
        evaluator=tr.evaluator())
    return {"method": method, "test_acc": test_acc,
            "per_client": per_client.tolist(),
            "seconds": time.time() - t0,
            "history": tr.history, "comm": tr.comm.summary(),
            "rounds_to_target": _rounds_to_target(tr.history, target_acc),
            "state": state, "algo": algo}


def run_fedavg(model, dataset_splits, *, rounds, clients_per_round,
               support_frac, support_size, query_size, local_lr,
               local_steps=3, eval_every=0, target_acc=None, seed=0,
               meta_eval=False):
    """FedAvg baseline; meta_eval=True gives FedAvg(Meta) scoring."""
    train, val, test = dataset_splits
    loss_fn, eval_fn = classification_loss(model.apply)
    fa = FedAvgTrainer(loss_fn, eval_fn, local_lr=local_lr,
                       local_steps=local_steps, train_clients=train,
                       clients_per_round=clients_per_round,
                       support_frac=support_frac, support_size=support_size,
                       query_size=query_size, seed=seed, meta_eval=meta_eval)
    state = fa.init(jax.random.PRNGKey(seed), model.init)
    fa.measure_flops(state)
    t0 = time.time()
    ev = eval_every or max(rounds // 8, 1)
    state = fa.run(state, rounds, eval_every=ev, eval_clients=val)
    test_acc, per_client, _ = evaluate_global(
        eval_fn, state["theta"], test, support_frac=support_frac,
        support_size=support_size, query_size=query_size, seed=seed,
        evaluator=fa.evaluator())
    return {"method": fa.name, "test_acc": test_acc,
            "per_client": per_client.tolist(),
            "seconds": time.time() - t0, "history": fa.history,
            "comm": fa.comm.summary(),
            "rounds_to_target": _rounds_to_target(fa.history, target_acc),
            "state": state}
