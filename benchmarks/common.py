"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classification_loss, make_algorithm
from repro.data.federated import sample_task_batch
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import (FederatedTrainer, evaluate_global,
                                    evaluate_meta, make_global_evaluator,
                                    make_meta_evaluator)
from repro.optim import adam

META_METHODS = ("maml", "fomaml", "meta-sgd")


def run_fedmeta(method, model, dataset_splits, *, rounds, clients_per_round,
                support_frac, support_size, query_size, inner_lr, outer_lr,
                eval_every=0, target_acc=None, seed=0):
    """Train one FedMeta method; returns dict with accuracy/comm history."""
    train, val, test = dataset_splits
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm(method, loss_fn, eval_fn, inner_lr=inner_lr)
    tr = FederatedTrainer(algo, adam(outer_lr), train,
                          clients_per_round=clients_per_round,
                          support_frac=support_frac,
                          support_size=support_size, query_size=query_size,
                          seed=seed)
    state = tr.init(jax.random.PRNGKey(seed), model.init)
    tr.measure_flops(state)
    t0 = time.time()
    rounds_to_target = None
    evaluator = make_meta_evaluator(algo)
    ev = eval_every or max(rounds // 8, 1)
    for start in range(0, rounds, ev):
        n = min(ev, rounds - start)
        state = tr.run(state, n)
        acc, _ = evaluate_meta(algo, state["phi"], val,
                               support_frac=support_frac,
                               support_size=support_size,
                               query_size=query_size, seed=seed,
                               evaluator=evaluator)
        tr.history.append({"round": start + n, "val_acc": acc,
                           **tr.comm.summary()})
        if target_acc and rounds_to_target is None and acc >= target_acc:
            rounds_to_target = start + n
    test_acc, per_client = evaluate_meta(algo, state["phi"], test,
                                         support_frac=support_frac,
                                         support_size=support_size,
                                         query_size=query_size, seed=seed,
                                         evaluator=evaluator)
    return {"method": method, "test_acc": test_acc,
            "per_client": per_client.tolist(),
            "seconds": time.time() - t0,
            "history": tr.history, "comm": tr.comm.summary(),
            "rounds_to_target": rounds_to_target, "state": state,
            "algo": algo}


def run_fedavg(model, dataset_splits, *, rounds, clients_per_round,
               support_frac, support_size, query_size, local_lr,
               local_steps=3, eval_every=0, target_acc=None, seed=0,
               meta_eval=False):
    """FedAvg baseline; meta_eval=True gives FedAvg(Meta) scoring."""
    train, val, test = dataset_splits
    loss_fn, eval_fn = classification_loss(model.apply)
    fa = FedAvgTrainer(loss_fn, eval_fn, local_lr=local_lr,
                       local_steps=local_steps)
    state = fa.init_state(jax.random.PRNGKey(seed), model.init)
    from repro.federated.comm import CommTracker
    comm = CommTracker.for_state(state, clients_per_round)
    rng = np.random.RandomState(seed)
    step = jax.jit(lambda th, bx, by, w: fa.round_step(
        {"theta": th}, (bx, by), w)["theta"])
    t0 = time.time()
    history = []
    rounds_to_target = None
    ev = eval_every or max(rounds // 8, 1)
    ft = fa.finetune if meta_eval else None
    evaluator = make_global_evaluator(eval_fn, ft)
    for r in range(rounds):
        tb = sample_task_batch(train, clients_per_round, 0.5,
                               support_size, query_size, rng)
        # FedAvg trains on ALL local data (paper §4.1): support+query
        bx = np.concatenate([tb.support_x[:, None], tb.query_x[:, None]], 1)
        by = np.concatenate([tb.support_y[:, None], tb.query_y[:, None]], 1)
        reps = int(np.ceil(local_steps / 2))
        bx = np.tile(bx, (1, reps, 1) + (1,) * (bx.ndim - 3))[:, :local_steps]
        by = np.tile(by, (1, reps, 1))[:, :local_steps]
        state["theta"] = step(state["theta"], jnp.asarray(bx),
                              jnp.asarray(by), jnp.asarray(tb.weight))
        comm.tick()
        if (r + 1) % ev == 0 or r == rounds - 1:
            acc, _ = evaluate_global(eval_fn, state["theta"], val,
                                     support_frac=support_frac,
                                     support_size=support_size,
                                     query_size=query_size, seed=seed,
                                     finetune=ft, evaluator=evaluator)
            history.append({"round": r + 1, "val_acc": acc, **comm.summary()})
            if target_acc and rounds_to_target is None and acc >= target_acc:
                rounds_to_target = r + 1
    test_acc, per_client = evaluate_global(
        eval_fn, state["theta"], test, support_frac=support_frac,
        support_size=support_size, query_size=query_size, seed=seed,
        finetune=ft, evaluator=evaluator)
    return {"method": "fedavg(meta)" if meta_eval else "fedavg",
            "test_acc": test_acc, "per_client": per_client.tolist(),
            "seconds": time.time() - t0, "history": history,
            "comm": comm.summary(), "rounds_to_target": rounds_to_target,
            "state": state}
