"""Render the §Repro-results section of EXPERIMENTS.md from
results/bench/*.json (run after `python -m benchmarks.run`)."""
from __future__ import annotations

import glob
import json
import os


def main():
    out = ["## §Repro-results (synthetic-LEAF, CPU, reduced rounds)\n"]

    rows = []
    for f in sorted(glob.glob("results/bench/table2_*.json")):
        rows += json.load(open(f))
    if rows:
        out.append("### Table 2 analogue — final test accuracy "
                   "(support fraction 0.2)\n")
        out.append("| dataset | method | test acc | comm MB | seconds |")
        out.append("|---|---|---|---|---|")
        for r in rows:
            out.append(f"| {r['dataset']} | {r['method']} | "
                       f"{r['test_acc']:.4f} | {r['comm_MB']:.1f} | "
                       f"{r['seconds']:.0f} |")
        # verdict per dataset
        out.append("")
        for ds in sorted({r["dataset"] for r in rows}):
            sub = {r["method"]: r["test_acc"] for r in rows
                   if r["dataset"] == ds}
            best_meta = max(sub.get("maml", 0), sub.get("fomaml", 0),
                            sub.get("meta-sgd", 0))
            fa = sub.get("fedavg", 0)
            fam = sub.get("fedavg(meta)", 0)
            verdict = ("CONFIRMED" if best_meta > max(fa, fam) else
                       ("PARTIAL (FedMeta > FedAvg only)"
                        if best_meta > fa else "NOT REPRODUCED"))
            out.append(f"- **{ds}**: best FedMeta {best_meta:.3f} vs "
                       f"FedAvg {fa:.3f} / FedAvg(Meta) {fam:.3f} — "
                       f"{verdict}")
        out.append("")

    for f3, label in (("results/bench/fig3_sent140.json", "target 0.70"),
                      ("results/bench/fig3_sent140_t55.json", "target 0.55 "
                       "(FedAvg-attainable)")):
        if not os.path.exists(f3):
            continue
        rows = json.load(open(f3))
        out.append(f"### Figure 3 analogue — overhead to {label}\n")
        out.append("| method | rounds to target | comm MB | client GFLOPs | "
                   "comm reduction vs FedAvg |")
        out.append("|---|---|---|---|---|")
        for r in rows:
            out.append(f"| {r['method']} | {r['rounds_to_target']} | "
                       f"{r['comm_MB_to_target']} | "
                       f"{r['client_GFLOPs_to_target']} | "
                       f"{r.get('comm_reduction_vs_fedavg', '-')} |")
        out.append("")

    t3 = "results/bench/table3.json"
    if os.path.exists(t3):
        rows = json.load(open(t3))
        out.append("### Table 3 analogue — recommendation task\n")
        out.append("| method | top-1 | top-4 |")
        out.append("|---|---|---|")
        for k, v in rows.items():
            out.append(f"| {k} | {v['top1']:.4f} | {v['top4']:.4f} |")
        out.append("")

    for fr, label in (("results/bench/fairness_sent140.json",
                       "sent140, 300 rounds"),
                      ("results/bench/fairness.json",
                       "femnist, 48 rounds — under-trained")):
        if not os.path.exists(fr):
            continue
        rows = json.load(open(fr))
        out.append(f"### Fairness — per-client accuracy distribution "
                   f"({label})\n")
        out.append("| method | mean | std | p10 | p90 |")
        out.append("|---|---|---|---|---|")
        for k, v in rows.items():
            out.append(f"| {k} | {v['mean']:.3f} | {v['std']:.3f} | "
                       f"{v['p10']:.3f} | {v['p90']:.3f} |")
        out.append("")

    block = "\n".join(out)
    doc = open("EXPERIMENTS.md").read()
    marker = "## §Repro-results"
    if marker in doc:
        head = doc.split(marker)[0]
        tail_marker = "\n## §Dry-run"
        tail = tail_marker + doc.split(tail_marker, 1)[1]
        doc = head + block + tail
    else:
        doc = doc.replace("\n## §Dry-run", "\n" + block + "\n## §Dry-run", 1)
    open("EXPERIMENTS.md", "w").write(doc)
    print("filled §Repro-results with", len(out), "lines")


if __name__ == "__main__":
    main()
