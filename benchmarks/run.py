"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus the
per-table result lines emitted by each module.

  (default) reduced rounds so the suite finishes on 1 CPU core
  --full   paper-scale rounds (hours on CPU)
  --only   comma-separated subset:
           kernels,meta_step,round,table2,fig3,table3,fairness

All artifacts go under --outdir (default results/bench/) — nothing is
written at the repo root.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def _bench_kernels():
    """Microbench the three Pallas kernel oracles (wall time on CPU; TPU
    numbers come from the roofline analysis, not from here)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.attention.ops import flash_attention
    from repro.kernels.meta_update.ops import meta_update
    from repro.kernels.ssd.ops import ssd_chunked

    rng = np.random.RandomState(0)
    rows = []

    q = jnp.asarray(rng.normal(0, 1, (1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 512, 2, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, impl="xla"))
    f(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(q, k, v).block_until_ready()
    rows.append(("kernel.attention.xla", (time.perf_counter() - t0) / 10 * 1e6,
                 "B1xL512xH4"))

    x = jnp.asarray(rng.normal(0, 1, (1, 256, 4, 16)), jnp.float32)
    dt = jnp.asarray(np.ones((1, 256, 4)) * 0.1, jnp.float32)
    A = jnp.asarray(-np.ones(4), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (1, 256, 32)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (1, 256, 32)), jnp.float32)
    g = jax.jit(lambda *a: ssd_chunked(*a, chunk=64, impl="xla"))
    g(x, dt, A, Bm, Cm).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        g(x, dt, A, Bm, Cm).block_until_ready()
    rows.append(("kernel.ssd.xla", (time.perf_counter() - t0) / 10 * 1e6,
                 "L256xh4"))

    theta = {"w": jnp.zeros((1 << 20,), jnp.float32)}
    grads = {"w": jnp.ones((1 << 20,), jnp.float32)}
    h = jax.jit(lambda t, g: meta_update(t, 0.01, g, impl="xla"))
    h(theta, grads)["w"].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        h(theta, grads)["w"].block_until_ready()
    rows.append(("kernel.meta_update.xla",
                 (time.perf_counter() - t0) / 10 * 1e6, "1M params"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only",
                    default="kernels,meta_step,round,experiment,table2,fig3,"
                            "table3,fairness")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--outdir", default="results/bench")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    # reduced (non---full) runs write ONLY under this gitignored smoke
    # dir — a doc-following smoke run can never shadow (or accidentally
    # get committed next to) a real artifact
    smoke_dir = os.path.join(args.outdir, "smoke")
    if not args.full:
        os.makedirs(smoke_dir, exist_ok=True)
    only = set(args.only.split(","))
    rounds = args.rounds or (400 if args.full else 120)

    print("name,us_per_call,derived", flush=True)
    if "kernels" in only:
        for name, us, derived in _bench_kernels():
            print(f"{name},{us:.1f},{derived}", flush=True)

    if "meta_step" in only:
        from benchmarks import meta_step_bench
        t0 = time.time()
        # the committed perf-trajectory artifact lives in outdir; a
        # reduced run writes into the gitignored smoke/ subdir so it
        # cannot clobber the full-run numbers
        out = (os.path.join(args.outdir, "BENCH_meta_step.json")
               if args.full
               else os.path.join(smoke_dir, "BENCH_meta_step.json"))
        report = meta_step_bench.run(dry=not args.full, json_out=out)
        spd = report["summary"].get("wall_speedup_packed_vs_tree_vmap")
        print(f"meta_step,{(time.time()-t0)*1e6:.0f},"
              f"packed_speedup={f'{spd:.2f}x' if spd else 'n/a'}", flush=True)

    if "round" in only:
        from benchmarks import round_bench
        t0 = time.time()
        out = (os.path.join(args.outdir, "BENCH_round.json")
               if args.full
               else os.path.join(smoke_dir, "BENCH_round.json"))
        report = round_bench.run(dry=not args.full, json_out=out)
        spd = report["summary"].get("round_speedup_client_plane_vs_packed")
        aspd = report["summary"].get("async_speedup")
        print(f"round,{(time.time()-t0)*1e6:.0f},"
              f"client_plane_speedup={f'{spd:.2f}x' if spd else 'n/a'},"
              f"async_speedup={f'{aspd:.2f}x' if aspd else 'n/a'}",
              flush=True)

    if "experiment" in only:
        from benchmarks import experiment_bench
        t0 = time.time()
        # smoke summary goes into the gitignored smoke/ dir — must not
        # clobber the committed full-run numbers (same guard as the
        # other benches) — and ALL artifacts stay under --outdir (the
        # committed results/experiments/ refresh goes through
        # experiment_bench / examples/compare_fedmeta_fedavg.py
        # directly)
        out = (os.path.join(args.outdir, "experiment_summary.json")
               if args.full
               else os.path.join(smoke_dir, "experiment_summary.json"))
        summary = experiment_bench.run(
            dry=not args.full, json_out=out,
            out_dir=(os.path.join(args.outdir, "experiments")
                     if args.full
                     else os.path.join(smoke_dir, "experiments")))
        # headline = best FEDMETA reduction; fedavg(meta) is a baseline.
        # ">=x" strings mark lower bounds and survive into the headline.
        reds = [v for s in summary.values()
                for m, v in s["comm_reduction_vs_fedavg"].items()
                if v and m not in ("fedavg", "fedavg(meta)")]
        best = max(reds, key=lambda v: float(str(v).lstrip(">="))) \
            if reds else "n/a"
        print(f"experiment,{(time.time()-t0)*1e6:.0f},"
              f"max_comm_reduction={best}", flush=True)

    if "table2" in only:
        from benchmarks import table2_leaf
        datasets = ("femnist", "shakespeare", "sent140")
        fracs = (0.2, 0.5, 0.9) if args.full else (0.2,)
        for dsname in datasets:
            t0 = time.time()
            rows = table2_leaf.run(
                dsname, rounds=rounds, support_fracs=fracs,
                json_out=os.path.join(args.outdir, f"table2_{dsname}.json"))
            best = max(rows, key=lambda r: r["test_acc"])
            print(f"table2.{dsname},{(time.time()-t0)*1e6/max(rounds,1):.0f},"
                  f"best={best['method']}@{best['test_acc']:.3f}", flush=True)

    if "fig3" in only:
        from benchmarks import fig3_overhead
        t0 = time.time()
        rows = fig3_overhead.run(
            "sent140", target_acc=0.70, max_rounds=rounds * 2,
            json_out=os.path.join(args.outdir, "fig3_sent140.json"))
        red = [r.get("comm_reduction_vs_fedavg") for r in rows
               if r["method"] in ("maml", "meta-sgd")
               and r.get("comm_reduction_vs_fedavg")]
        print(f"fig3.sent140,{(time.time()-t0)*1e6:.0f},"
              f"comm_reduction={max(red) if red else 'n/a'}", flush=True)

    if "table3" in only:
        from benchmarks import table3_production
        t0 = time.time()
        rows = table3_production.run(
            rounds=rounds,
            json_out=os.path.join(args.outdir, "table3.json"))
        best = max(rows.items(), key=lambda kv: kv[1]["top1"])
        print(f"table3,{(time.time()-t0)*1e6:.0f},"
              f"best={best[0]}@top1={best[1]['top1']:.3f}", flush=True)

    if "fairness" in only:
        from benchmarks import fairness
        t0 = time.time()
        rows = fairness.run(
            "femnist", rounds=rounds,
            json_out=os.path.join(args.outdir, "fairness.json"))
        print(f"fairness.femnist,{(time.time()-t0)*1e6:.0f},"
              f"std_fedavg={rows['fedavg']['std']:.3f}_maml="
              f"{rows['maml']['std']:.3f}", flush=True)


if __name__ == "__main__":
    main()
