"""Serving-plane benchmark: adaptation-on-demand latency/throughput.

Measures the two halves of the personalized serving engine
(`federated.serving.ServingEngine`, DESIGN.md §18):

  adapt rows    cold-cache adaptation latency (p50/p99 ms per request)
                and sustained requests/s vs. adaptation batch size on
                the deep-narrow MLP meta-task (shared with
                meta_step_bench) — the axis the (chunk, N) plane kernel
                is supposed to win: one fused inner-update serves B
                concurrent clients for ~the cost of one.
  e2e row       reduced-LM end-to-end serve (Zipf traffic -> cache ->
                adapt -> prefill -> decode) with cache hit rate and
                decode p50 — the deployment path of paper §3.2.

Timing discipline: one untimed serve compiles every executable, then
the cache and counters reset and `reps` timed serves run on the same
request stream (min wall -> requests/s; latency percentiles come from
the fastest rep).

Usage:
  PYTHONPATH=src python benchmarks/serve_bench.py            # full
  PYTHONPATH=src python benchmarks/serve_bench.py --dry-run  # CI smoke
Emits results/bench/BENCH_serve.json (see --out).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.meta_step_bench import SCALES, _build_task


def _mlp_requests(scale_cfg, n, batch, seed=0):
    """n single-client requests with distinct clients (cold cache path).
    Support shape matches `_build_task`'s per-client (batch, D) slices."""
    import jax.numpy as jnp

    from repro.federated.serving import ServeRequest

    D = scale_cfg["in_dim"]
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        sup = (jnp.asarray(rng.normal(0, 1, (batch, D)), jnp.float32),
               jnp.asarray(rng.normal(0, 1, (batch, D)), jnp.float32))
        reqs.append(ServeRequest(rid=i, client=i, arrival=float(i), support=sup))
    return reqs


def _timed_serves(engine, requests, reps, **kw):
    """Warmup-compile, then `reps` cold-cache serves; returns the report
    of the fastest rep plus the min wall."""
    engine.serve(requests, **kw)                 # compile everything
    best = None
    for _ in range(reps):
        engine.cache.clear()
        rep = engine.serve(requests, **kw)
        if best is None or rep.wall_s < best.wall_s:
            best = rep
    return best


def _bench_adapt(dry: bool, reps: int):
    from repro.core import make_algorithm  # noqa: F401  (env sanity)
    from repro.federated.serving import AdaptationCache, ServingEngine

    scale = "tiny" if dry else "small"
    batches = (1, 2) if dry else (1, 2, 4, 8, 16)
    per_batch = 2 if dry else 8
    data_batch = 8
    rows = []
    algo, model_init, _, _, _ = _build_task(SCALES[scale], 2, data_batch)
    import jax
    phi = algo.init_state(jax.random.PRNGKey(0), model_init)
    for B in batches:
        n = B * per_batch
        reqs = _mlp_requests(SCALES[scale], n, data_batch)
        engine = ServingEngine(algo, phi, adapt_batch=B,
                               cache=AdaptationCache(None))
        rep = _timed_serves(engine, reqs, reps)
        s = rep.summary()
        rows.append({"section": "adapt", "scale": scale, "adapt_batch": B,
                     "requests": n,
                     "adapt_p50_ms": s["adapt_p50_ms"],
                     "adapt_p99_ms": s["adapt_p99_ms"],
                     "requests_per_s": s["requests_per_s"],
                     "wall_s": rep.wall_s})
        print(f"adapt B={B:3d}: p50 {s['adapt_p50_ms']:8.3f} ms  "
              f"p99 {s['adapt_p99_ms']:8.3f} ms  "
              f"{s['requests_per_s']:8.1f} req/s", flush=True)
    return rows


def _bench_e2e(dry: bool, reps: int):
    import jax.numpy as jnp

    from repro.configs import get_config, reduced_config
    from repro.federated.serving import TrafficModel
    from repro.launch.serve import build_engine

    cfg = reduced_config(get_config("smollm-360m"))
    n, tokens, prompt_len = (6, 2, 8) if dry else (24, 4, 16)
    engine = build_engine(cfg, adapt_batch=2, cache_capacity=16)
    traffic = TrafficModel(num_clients=max(2, n // 3), rate=32.0,
                           support_sizes=(2, 4), think_time=0.01, seed=0)
    make_support = lambda r, size: jnp.asarray(
        r.randint(0, cfg.vocab_size, (size, 32)), jnp.int32)
    make_prompt = lambda r: jnp.asarray(
        r.randint(0, cfg.vocab_size, (prompt_len,)), jnp.int32)
    reqs = traffic.requests(n, make_support, make_prompt)
    rep = _timed_serves(engine, reqs, reps, max_new_tokens=tokens)
    s = rep.summary()
    row = {"section": "e2e", "arch": cfg.name, "requests": n,
           "max_new_tokens": tokens, "prompt_len": prompt_len,
           "hits": s["hits"], "misses": s["misses"],
           "adapt_p50_ms": s["adapt_p50_ms"],
           "adapt_p99_ms": s["adapt_p99_ms"],
           "decode_p50_ms": s.get("decode_p50_ms"),
           "requests_per_s": s["requests_per_s"],
           "cache": s["cache"], "wall_s": rep.wall_s}
    print(f"e2e {cfg.name}: {s['hits']}/{n} hits  "
          f"adapt p50 {s['adapt_p50_ms']:.1f} ms  "
          f"{s['requests_per_s']:.2f} req/s", flush=True)
    return [row]


def _summarize(adapt_rows, e2e_rows):
    by_b = {r["adapt_batch"]: r for r in adapt_rows}
    bmax = max(by_b)
    out = {
        "throughput_by_batch": {str(b): by_b[b]["requests_per_s"]
                                for b in sorted(by_b)},
        "batch_speedup": (by_b[bmax]["requests_per_s"]
                          / by_b[1]["requests_per_s"]) if 1 in by_b else None,
        "best_requests_per_s": max(r["requests_per_s"] for r in adapt_rows),
    }
    if e2e_rows:
        e = e2e_rows[0]
        out["e2e"] = {"arch": e["arch"], "hit_rate": e["hits"] / e["requests"],
                      "requests_per_s": e["requests_per_s"],
                      "decode_p50_ms": e["decode_p50_ms"]}
    return out


def run(*, dry: bool = False, reps: int = 5,
        json_out: str = "results/bench/BENCH_serve.json"):
    import jax

    reps = 1 if dry else reps
    t0 = time.perf_counter()
    adapt_rows = _bench_adapt(dry, reps)
    e2e_rows = _bench_e2e(dry, reps)
    report = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "dry_run": dry,
        "reps": reps,
        "adapt_rows": adapt_rows,
        "e2e_rows": e2e_rows,
        "summary": _summarize(adapt_rows, e2e_rows),
        "bench_wall_s": time.perf_counter() - t0,
    }
    os.makedirs(os.path.dirname(json_out) or ".", exist_ok=True)
    with open(json_out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {json_out}", flush=True)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny scale, 1 rep — CI smoke")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None,
                    help="output JSON; defaults to results/bench/ "
                         "for full runs, the gitignored smoke/ dir for "
                         "--dry-run so a doc-following smoke cannot "
                         "clobber the committed artifact")
    args = ap.parse_args()
    if args.out is None:
        args.out = ("results/bench/smoke/BENCH_serve.json" if args.dry_run
                    else "results/bench/BENCH_serve.json")
    run(dry=args.dry_run, reps=args.reps, json_out=args.out)


if __name__ == "__main__":
    main()
