"""Meta-step pipeline benchmark: tree vs packed plane × client axes.

The repo's first perf-trajectory datapoint. For each model scale it
compiles and times one full server round (m clients' ModelTraining +
aggregation + outer Adam) under every combination of

  pipeline:    "tree"        — per-leaf aggregation + per-leaf Adam
                               (seed path)
               "packed"      — packed parameter plane: fused (m, N)
                               weighted aggregation + single-pass flat
                               Adam, f32 everywhere (bit-equivalent
                               metrics to tree)
               "packed_bf16" — same plane with the bf16 gradient block
                               + bf16 Adam moments (f32 accumulation);
                               models half-precision client uploads
  impl:        "xla" (default), "pallas_interpret" (opt-in: interpreter
               is orders of magnitude slower on CPU; numbers are for
               correctness spot-checks, not perf)
  client_axis: "vmap", "scan", "chunked@k"

and records median wall time, HLO flops / "bytes accessed" (XLA cost
analysis), and compiled temp-buffer size (peak scratch memory — the
number that should scale with the chunk size, not clients-per-round).

Caveat: XLA cost analysis counts a scan/while body ONCE, not times the
trip count, so "bytes accessed" is only comparable between rows with
the same client_axis. The summary therefore compares pipelines at
axis="vmap" (fully unrolled, accurately counted) and uses temp_bytes —
which is accurate — for the chunked-memory claim.

Usage:
  PYTHONPATH=src python benchmarks/meta_step_bench.py            # full
  PYTHONPATH=src python benchmarks/meta_step_bench.py --dry-run  # CI smoke
Emits results/bench/BENCH_meta_step.json (see --out).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


# (layers, width, in_dim): "small" is a shallow CNN-class budget; "large"
# is a deep stack (64 leaves, ~0.5M params) — the regime where the tree
# path's per-leaf op soup costs the most dispatch/fusion overhead
SCALES = {
    "small": dict(layers=6, width=64, in_dim=32),
    "large": dict(layers=32, width=128, in_dim=64),
    "tiny": dict(layers=3, width=16, in_dim=8),       # --dry-run only
}


def _build_task(scale_cfg, m, batch, seed=0, algo_name="fomaml",
                inner_steps=1):
    """Deep-narrow MLP meta-learning task (shared with round_bench)."""
    import jax
    import jax.numpy as jnp

    from repro.core import make_algorithm

    L, W, D = scale_cfg["layers"], scale_cfg["width"], scale_cfg["in_dim"]
    rng = np.random.RandomState(seed)

    def model_init(key):
        dims = [D] + [W] * (L - 1) + [D]
        params = {}
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            params[f"W{i}"] = jnp.asarray(
                rng.normal(0, 1 / np.sqrt(a), (a, b)), jnp.float32)
            params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
        return params

    def forward(params, x):
        h = x
        for i in range(L):
            h = h @ params[f"W{i}"] + params[f"b{i}"]
            if i < L - 1:
                h = jnp.tanh(h)
        return h

    def loss_fn(params, data):
        x, y = data
        return jnp.mean(jnp.square(forward(params, x) - y))

    def eval_fn(params, data):
        return loss_fn(params, data), {"accuracy": jnp.zeros(())}

    algo = make_algorithm(algo_name, loss_fn, eval_fn, inner_lr=0.05,
                          inner_steps=inner_steps)
    sup = (jnp.asarray(rng.normal(0, 1, (m, batch, D)), jnp.float32),
           jnp.asarray(rng.normal(0, 1, (m, batch, D)), jnp.float32))
    qry = (jnp.asarray(rng.normal(0, 1, (m, batch, D)), jnp.float32),
           jnp.asarray(rng.normal(0, 1, (m, batch, D)), jnp.float32))
    weights = jnp.asarray(rng.uniform(1, 10, (m,)), jnp.float32)
    return algo, model_init, sup, qry, weights


def _analyze(step, state, sup, qry, weights):
    """Compile once; pull XLA cost/memory analysis out of the executable."""
    out = {"flops": None, "bytes_accessed": None, "temp_bytes": None}
    try:
        compiled = step.lower(state, sup, qry, weights).compile()
    except Exception:
        return out, None
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out["flops"] = float(cost.get("flops", 0.0))
        out["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    except Exception:
        pass
    try:
        mem = compiled.memory_analysis()
        out["temp_bytes"] = int(mem.temp_size_in_bytes)
    except Exception:
        pass
    return out, compiled


def _time_interleaved(configs, reps):
    """Round-robin timing across configs so machine noise hits every
    config equally; per-config min is the noise-robust statistic."""
    import jax

    for c in configs:                        # warmup / compile
        s, met = c["step"](c["state"], *c["args"])
        jax.block_until_ready((s, met))
    times = {id(c): [] for c in configs}
    for _ in range(reps):
        for c in configs:
            t0 = time.perf_counter()
            s, met = c["step"](c["state"], *c["args"])
            jax.block_until_ready((s, met))
            times[id(c)].append(time.perf_counter() - t0)
    return {id(c): (float(np.min(t) * 1e6), float(np.median(t) * 1e6))
            for c, t in ((c, times[id(c)]) for c in configs)}


def run(*, dry: bool = False, interpret: bool = False, reps: int = 10,
        json_out: str = "results/bench/BENCH_meta_step.json"):
    import jax

    from repro.core.fedmeta import (init_packed_state, make_meta_train_step,
                                    make_packed_meta_train_step)
    from repro.optim import adam
    from repro.utils.flat import plane_for
    from repro.utils.pytree import tree_size

    scales = ["tiny"] if dry else ["tiny", "small", "large"]
    m = 4 if dry else 8
    batch = 8 if dry else 32
    reps = 1 if dry else reps
    axes = [("vmap", None), ("chunked", 2)] if dry else \
        [("vmap", None), ("scan", None), ("chunked", 2), ("chunked", 4)]

    import jax.numpy as jnp

    rows = []
    for scale in scales:
        algo, model_init, sup, qry, weights = _build_task(
            SCALES[scale], m, batch)
        opt = adam(1e-3)
        opt_bf16 = adam(1e-3, state_dtype=jnp.bfloat16)
        phi = algo.init_state(jax.random.PRNGKey(0), model_init)
        plane = plane_for(phi)
        n_params = tree_size(phi)

        pipelines = [("tree", "xla"), ("packed", "xla"),
                     ("packed_bf16", "xla")]
        if interpret:
            pipelines.append(("packed", "pallas_interpret"))
        configs = []
        for pipeline, impl in pipelines:
            for axis, chunk in axes:
                # donate=False: the timing loop re-feeds the same state
                # object, which donation would invalidate after one call
                # on backends that implement it
                if pipeline == "tree":
                    step = make_meta_train_step(
                        algo, opt, client_axis=axis, client_chunk=chunk,
                        donate=False)
                    state = {"phi": phi, "opt": opt.init(phi)}
                elif pipeline == "packed":
                    step = make_packed_meta_train_step(
                        algo, opt, plane, client_axis=axis,
                        client_chunk=chunk, impl=impl, donate=False)
                    state = init_packed_state(opt, plane, phi)
                else:   # packed_bf16: bf16 grad block + bf16 moments
                    step = make_packed_meta_train_step(
                        algo, opt_bf16, plane, client_axis=axis,
                        client_chunk=chunk, impl=impl,
                        block_dtype=jnp.bfloat16, donate=False)
                    state = init_packed_state(opt_bf16, plane, phi)
                configs.append({
                    "step": step, "state": state,
                    "args": (sup, qry, weights),
                    "row": {"scale": scale, "pipeline": pipeline,
                            "impl": impl, "client_axis": axis,
                            "client_chunk": chunk, "clients": m,
                            "n_params": int(n_params),
                            "n_padded": int(plane.n_padded)},
                })
        walls = _time_interleaved(configs, reps)
        for c in configs:
            analysis, _ = _analyze(c["step"], c["state"], *c["args"])
            wall_us, wall_med = walls[id(c)]
            row = {**c["row"], "wall_us_per_round": wall_us,
                   "wall_us_median": wall_med, **analysis}
            rows.append(row)
            print(f"meta_step.{scale}.{row['pipeline']}[{row['impl']}]."
                  f"{row['client_axis']}"
                  f"{'@' + str(row['client_chunk']) if row['client_chunk'] else ''},"
                  f"{wall_us:.0f},"
                  f"bytes={analysis['bytes_accessed']},"
                  f"temp={analysis['temp_bytes']}", flush=True)

    report = {
        "bench": "meta_step",
        "backend": jax.default_backend(),
        "dry_run": dry,
        "reps": reps,
        "rows": rows,
        "summary": _summarize(rows),
    }
    os.makedirs(os.path.dirname(json_out) or ".", exist_ok=True)
    with open(json_out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {json_out}", flush=True)
    return report


def _summarize(rows):
    """Headline comparisons at the largest scale, vmap-vs-vmap (the only
    axis where XLA counts bytes accurately — see module docstring), plus
    chunked temp-memory scaling."""
    out = {}
    scales = {r["scale"] for r in rows}
    big = "large" if "large" in scales else sorted(scales)[-1]
    out["largest_scale"] = big

    def pick(pipeline, axis, chunk=None):
        for r in rows:
            if (r["scale"] == big and r["pipeline"] == pipeline
                    and r["impl"] == "xla" and r["client_axis"] == axis
                    and r["client_chunk"] == chunk):
                return r
        return None

    tree_v = pick("tree", "vmap")
    for name in ("packed", "packed_bf16"):
        pk = pick(name, "vmap")
        if not (tree_v and pk):
            continue
        out[f"wall_speedup_{name}_vs_tree_vmap"] = (
            tree_v["wall_us_per_round"] / pk["wall_us_per_round"])
        if tree_v["bytes_accessed"] and pk["bytes_accessed"]:
            out[f"bytes_accessed_ratio_{name}_vs_tree"] = (
                pk["bytes_accessed"] / tree_v["bytes_accessed"])

    # the full pipeline (plane + fused kernels + chunked execution)
    # against the seed default path (tree, vmap)
    pipeline_rows = [r for r in rows
                     if r["scale"] == big and r["impl"] == "xla"
                     and r["pipeline"].startswith("packed")]
    if tree_v and pipeline_rows:
        best = min(pipeline_rows, key=lambda r: r["wall_us_per_round"])
        out["pipeline_vs_seed_default"] = {
            "seed": "tree/vmap",
            "pipeline": (f"{best['pipeline']}/{best['client_axis']}"
                         + (f"@{best['client_chunk']}"
                            if best["client_chunk"] else "")),
            "wall_us_seed": tree_v["wall_us_per_round"],
            "wall_us_pipeline": best["wall_us_per_round"],
            "wall_speedup": (tree_v["wall_us_per_round"]
                             / best["wall_us_per_round"]),
            "bytes_accessed_seed": tree_v["bytes_accessed"],
            "bytes_accessed_pipeline": best["bytes_accessed"],
            "caveat": ("bytes for scan/chunked rows count the loop body "
                       "once (XLA cost analysis does not multiply by trip "
                       "count); same-axis ratios above are exact"),
        }

    # dispatch-overhead regime: where the plane's op-count collapse shows
    # on the CPU backend (XLA:CPU already loop-fuses the per-leaf soup at
    # larger scales, so large-scale CPU wall is parity; the pallas path
    # targets TPU, where per-leaf HLO dispatch is the bottleneck)
    tiny_tree = next((r for r in rows if r["scale"] == "tiny"
                      and r["pipeline"] == "tree"
                      and r["client_axis"] == "vmap"), None)
    tiny_packed = next((r for r in rows if r["scale"] == "tiny"
                        and r["pipeline"] == "packed"
                        and r["client_axis"] == "vmap"), None)
    if tiny_tree and tiny_packed:
        out["wall_speedup_packed_vs_tree_vmap_tiny"] = (
            tiny_tree["wall_us_per_round"]
            / tiny_packed["wall_us_per_round"])
    # peak scratch memory scales with the chunk size, not clients m
    for pipeline in ("tree", "packed"):
        chunk_rows = [r for r in rows
                      if r["scale"] == big and r["client_axis"] == "chunked"
                      and r["pipeline"] == pipeline and r["temp_bytes"]]
        vmap_row = pick(pipeline, "vmap")
        if len(chunk_rows) >= 2:
            chunk_rows.sort(key=lambda r: r["client_chunk"])
            out[f"{pipeline}_temp_bytes_by_chunk"] = {
                str(r["client_chunk"]): r["temp_bytes"] for r in chunk_rows}
            if vmap_row and vmap_row["temp_bytes"]:
                out[f"{pipeline}_temp_bytes_vmap_all_clients"] = \
                    vmap_row["temp_bytes"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny scale, 1 rep — CI smoke")
    ap.add_argument("--interpret", action="store_true",
                    help="also run packed pallas_interpret (slow)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--out", default=None,
                    help="output JSON (default: the committed artifact "
                         "for full runs, the gitignored smoke/ dir for "
                         "--dry-run so a doc-following smoke cannot "
                         "clobber the full-run numbers)")
    args = ap.parse_args()
    if args.out is None:
        args.out = ("results/bench/smoke/BENCH_meta_step.json"
                    if args.dry_run
                    else "results/bench/BENCH_meta_step.json")
    run(dry=args.dry_run, interpret=args.interpret, reps=args.reps,
        json_out=args.out)


if __name__ == "__main__":
    main()
