"""Assemble EXPERIMENTS.md tables from results/ artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dryrun] [--roofline]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}EB"


def dryrun_table(d="results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        mesh = "2x16x16" if "pod2" in f else "16x16"
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], mesh, r["status"], "-", "-",
                         "-", "-"))
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        rows.append((
            r["arch"], r["shape"], mesh, "ok",
            fmt_bytes(mem.get("argument_bytes")),
            fmt_bytes(mem.get("temp_bytes")),
            fmt_bytes(coll.get("total_bytes")),
            f"{r.get('compile_s', 0):.0f}s",
        ))
    hdr = ("| arch | shape | mesh | status | args/dev | temp | "
           "collective bytes (per-iter HLO) | compile |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for row in rows:
        lines.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(lines)


def roofline_table(d="results/roofline"):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL_FLOPs | useful ratio |",
        "|" + "---|" * 8,
    ]
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR "
                         f"{r['error'][:60]} | | | | | |")
            continue
        recs.append(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['model_flops']:.2e} | "
            f"{(r['useful_flops_ratio'] or 0):.3f} |")
    return "\n".join(lines), recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    args = ap.parse_args()
    if args.dryrun or not args.roofline:
        print(dryrun_table())
    if args.roofline or not args.dryrun:
        t, _ = roofline_table()
        print()
        print(t)


if __name__ == "__main__":
    main()
