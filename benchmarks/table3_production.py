"""Paper Table 3: industrial recommendation task — META (FedMeta with
LR/NN models) vs SELF (stand-alone per-client: MFU, MRU, NB, LR, NN) vs
MIXED (unified global classifier fine-tuned per client).

Synthetic production dataset mirrors the published shape (per-client
service subsets, context-dependent next-service labels). Metrics: Top-1
and Top-4 accuracy on each test client's (chronological) query set.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classification_loss, make_algorithm, topk_accuracy
from repro.data import make_recommend
from repro.data.federated import sample_task_batch
from repro.federated.server import FederatedTrainer
from repro.models.paper import rec_lr, rec_nn
from repro.optim import adam


def _topk(logits, labels, k):
    return float(topk_accuracy(jnp.asarray(logits), jnp.asarray(labels), k))


def _self_baselines(test_clients, support_frac, num_classes):
    """MFU / MRU / NB stand-alone baselines (paper §4.3 SELF setting)."""
    rows = {}
    accs = {m: ([], []) for m in ("MFU", "MRU", "NB")}
    for c in test_clients:
        n = c.n
        n_sup = max(1, int(support_frac * n))
        sup_y, qry_y = c.y[:n_sup], c.y[n_sup:]
        sup_x, qry_x = c.x[:n_sup], c.x[n_sup:]
        if len(qry_y) == 0:
            continue
        # MFU: most frequent services in support
        counts = np.bincount(sup_y, minlength=num_classes)
        order = np.argsort(-counts)
        accs["MFU"][0].append(np.mean(qry_y == order[0]))
        accs["MFU"][1].append(np.mean(np.isin(qry_y, order[:4])))
        # MRU: last-used service feature (one-hot tail of x)
        ctx_dim = c.x.shape[1] - num_classes
        last = np.argmax(qry_x[:, ctx_dim:], axis=1)
        accs["MRU"][0].append(np.mean(qry_y == last))
        # top-4 MRU: last + 3 most recent distinct from support tail
        recent = list(dict.fromkeys(sup_y[::-1]))[:4]
        hit4 = [(y == l) or (y in recent[:3]) for y, l in zip(qry_y, last)]
        accs["MRU"][1].append(np.mean(hit4))
        # NB: naive Bayes on binarized context features given class
        xb = (sup_x[:, :ctx_dim] > 0).astype(np.float64)
        qb = (qry_x[:, :ctx_dim] > 0).astype(np.float64)
        classes = np.unique(sup_y)
        logps = np.full((len(qry_y), num_classes), -1e9)
        for cl in classes:
            mask = sup_y == cl
            prior = np.log(mask.mean())
            theta = (xb[mask].sum(0) + 1) / (mask.sum() + 2)
            logps[:, cl] = (prior + qb @ np.log(theta)
                            + (1 - qb) @ np.log1p(-theta))
        accs["NB"][0].append(np.mean(np.argmax(logps, 1) == qry_y))
        top4 = np.argsort(-logps, axis=1)[:, :4]
        accs["NB"][1].append(np.mean([y in t for y, t in zip(qry_y, top4)]))
    for m, (t1, t4) in accs.items():
        rows[m] = {"top1": float(np.mean(t1)), "top4": float(np.mean(t4))}
    return rows


def run(support_frac: float = 0.8, rounds: int = 200, seed: int = 0,
        num_clients: int = 120, json_out: str | None = None):
    ds = make_recommend(num_clients=num_clients, seed=seed)
    train, val, test = ds.split_clients(seed=seed)
    feat_dim = ds.clients[0].x.shape[1]
    C = ds.num_classes
    rows = {}

    # ---- SELF non-parametric baselines
    rows.update(_self_baselines(test, support_frac, C))

    # ---- SELF: LR / NN trained per-client from scratch (100 steps)
    for name, mk in (("LR-self", rec_lr), ("NN-self", rec_nn)):
        model = mk(feat_dim, C)
        loss_fn, eval_fn = classification_loss(model.apply)
        opt = adam(1e-2)

        @jax.jit
        def train_client(theta, x, y, opt_state):
            def body(carry, _):
                p, st = carry
                g = jax.grad(loss_fn)(p, (x, y))
                p, st = opt.update(p, g, st)
                return (p, st), None
            (p, _), _ = jax.lax.scan(body, (theta, opt_state), None,
                                     length=100)
            return p
        t1s, t4s = [], []
        for c in test:
            n_sup = max(1, int(support_frac * c.n))
            theta = model.init(jax.random.PRNGKey(seed))
            p = train_client(theta, jnp.asarray(c.x[:n_sup]),
                             jnp.asarray(c.y[:n_sup]), opt.init(theta))
            logits = model.apply(p, jnp.asarray(c.x[n_sup:]))
            t1s.append(_topk(logits, c.y[n_sup:], 1))
            t4s.append(_topk(logits, c.y[n_sup:], 4))
        rows[name] = {"top1": float(np.mean(t1s)), "top4": float(np.mean(t4s))}
        print(f"table3,{name},top1={rows[name]['top1']:.4f},"
              f"top4={rows[name]['top4']:.4f}", flush=True)

    # ---- MIXED: unified NN trained across clients, fine-tuned per client
    model = rec_nn(feat_dim, C)
    loss_fn, eval_fn = classification_loss(model.apply)
    theta = model.init(jax.random.PRNGKey(seed))
    opt = adam(1e-3)
    st = opt.init(theta)
    rng = np.random.RandomState(seed)
    upd = jax.jit(lambda p, s, x, y: opt.update(p, jax.grad(loss_fn)(p, (x, y)), s))
    for _ in range(rounds * 4):
        tb = sample_task_batch(train, 1, 0.8, 64, 1, rng)
        theta, st = upd(theta, st, jnp.asarray(tb.support_x[0]),
                        jnp.asarray(tb.support_y[0]))
    t1s, t4s = [], []
    ft = jax.jit(lambda p, x, y: _finetune(p, x, y, loss_fn))
    for c in test:
        n_sup = max(1, int(support_frac * c.n))
        p = ft(theta, jnp.asarray(c.x[:n_sup]), jnp.asarray(c.y[:n_sup]))
        logits = model.apply(p, jnp.asarray(c.x[n_sup:]))
        t1s.append(_topk(logits, c.y[n_sup:], 1))
        t4s.append(_topk(logits, c.y[n_sup:], 4))
    rows["NN-unified"] = {"top1": float(np.mean(t1s)),
                          "top4": float(np.mean(t4s))}
    print(f"table3,NN-unified,top1={rows['NN-unified']['top1']:.4f},"
          f"top4={rows['NN-unified']['top4']:.4f}", flush=True)

    # ---- META: FedMeta MAML/Meta-SGD x LR/NN (100-step local adaptation
    # budget, paper's META setting)
    for mname in ("maml", "meta-sgd"):
        for arch_name, mk in (("LR", rec_lr), ("NN", rec_nn)):
            model = mk(feat_dim, C)
            loss_fn, eval_fn = classification_loss(model.apply)
            algo = make_algorithm(mname, loss_fn, eval_fn, inner_lr=0.01)
            tr = FederatedTrainer(algo, adam(1e-3), train,
                                  clients_per_round=4,
                                  support_frac=support_frac,
                                  support_size=48, query_size=16, seed=seed)
            state = tr.init(jax.random.PRNGKey(seed), model.init)
            state = tr.run(state, rounds)
            t1s, t4s = [], []
            for c in test:
                n_sup = max(1, int(support_frac * c.n))
                sup = (jnp.asarray(c.x[:n_sup]), jnp.asarray(c.y[:n_sup]))
                # paper §4.3: META models are locally trained with 100 steps
                theta_u = algo.adapt(state["phi"], sup, steps=100)
                logits = model.apply(theta_u, jnp.asarray(c.x[n_sup:]))
                t1s.append(_topk(logits, c.y[n_sup:], 1))
                t4s.append(_topk(logits, c.y[n_sup:], 4))
            key = f"{mname}+{arch_name}"
            rows[key] = {"top1": float(np.mean(t1s)),
                         "top4": float(np.mean(t4s))}
            print(f"table3,{key},top1={rows[key]['top1']:.4f},"
                  f"top4={rows[key]['top4']:.4f}", flush=True)

    if json_out:
        with open(json_out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _finetune(theta, x, y, loss_fn, steps: int = 100, lr: float = 1e-2):
    opt = adam(lr)

    def body(carry, _):
        p, st = carry
        g = jax.grad(loss_fn)(p, (x, y))
        p, st = opt.update(p, g, st)
        return (p, st), None

    (p, _), _ = jax.lax.scan(body, (theta, opt.init(theta)), None,
                             length=steps)
    return p
