"""Paper Figure 2 (bottom row): fairness — the distribution of final
per-client accuracies across test clients, FedAvg vs FedMeta."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import run_fedavg, run_fedmeta
from benchmarks.table2_leaf import SETUPS


def _dist_stats(accs):
    a = np.asarray(accs)
    return {"mean": float(a.mean()), "std": float(a.std()),
            "p10": float(np.percentile(a, 10)),
            "p90": float(np.percentile(a, 90)),
            "frac_below_50": float((a < 0.5).mean())}


def run(dataset: str = "femnist", rounds: int = 150, seed: int = 0,
        json_out: str | None = None):
    su = SETUPS[dataset]
    ds = su["data"]()
    splits = ds.split_clients(seed=seed)
    model = su["model"]()
    kw = dict(rounds=rounds, clients_per_round=su["clients_per_round"],
              support_frac=0.2, support_size=su["support_size"],
              query_size=su["query_size"], seed=seed)
    rows = {}
    r = run_fedavg(model, splits, local_lr=su["local_lr"], **kw)
    rows["fedavg"] = _dist_stats(r["per_client"])
    for method in ("maml", "meta-sgd"):
        r = run_fedmeta(method, model, splits, inner_lr=su["inner_lr"],
                        outer_lr=su["outer_lr"], **kw)
        rows[method] = _dist_stats(r["per_client"])
    for m, s in rows.items():
        print(f"fairness,{dataset},{m},mean={s['mean']:.4f},"
              f"std={s['std']:.4f},p10={s['p10']:.4f}", flush=True)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows
