"""Paper Figure 3: system overhead (communication bytes + client FLOPs)
to reach a target validation accuracy, per method. Validates the paper's
headline claim: FedMeta needs 2.82x-4.33x less communication than FedAvg.
"""
from __future__ import annotations

import json

from benchmarks.common import run_fedavg, run_fedmeta
from benchmarks.table2_leaf import SETUPS


def run(dataset: str = "sent140", target_acc: float = 0.70,
        max_rounds: int = 300, seed: int = 0, json_out: str | None = None,
        eval_every: int = 10):
    su = SETUPS[dataset]
    ds = su["data"]()
    splits = ds.split_clients(seed=seed)
    model = su["model"]()
    kw = dict(rounds=max_rounds, clients_per_round=su["clients_per_round"],
              support_frac=0.2, support_size=su["support_size"],
              query_size=su["query_size"], seed=seed, eval_every=eval_every,
              target_acc=target_acc)
    rows = []
    runs = [
        ("fedavg", lambda: run_fedavg(model, splits, local_lr=su["local_lr"],
                                      **kw)),
        ("fedavg(meta)", lambda: run_fedavg(model, splits,
                                            local_lr=su["local_lr"],
                                            meta_eval=True, **kw)),
        ("maml", lambda: run_fedmeta("maml", model, splits,
                                     inner_lr=su["inner_lr"],
                                     outer_lr=su["outer_lr"], **kw)),
        ("fomaml", lambda: run_fedmeta("fomaml", model, splits,
                                       inner_lr=su["inner_lr"],
                                       outer_lr=su["outer_lr"], **kw)),
        ("meta-sgd", lambda: run_fedmeta("meta-sgd", model, splits,
                                         inner_lr=su["inner_lr"],
                                         outer_lr=su["outer_lr"], **kw)),
    ]
    for name, fn in runs:
        r = fn()
        rt = r["rounds_to_target"]
        # comm bytes to target = rounds * clients * 2 * phi_bytes
        per_round = r["comm"]["comm_MB"] / r["comm"]["rounds"]
        flops_per_round = (r["comm"]["client_GFLOPs"] / r["comm"]["rounds"]
                           if r["comm"]["rounds"] else 0.0)
        row = {"dataset": dataset, "method": r["method"],
               "target_acc": target_acc, "rounds_to_target": rt,
               "comm_MB_to_target": round(per_round * rt, 2) if rt else None,
               "client_GFLOPs_to_target":
                   round(flops_per_round * rt, 2) if rt else None,
               "final_acc": round(r["test_acc"], 4)}
        rows.append(row)
        print(f"fig3,{dataset},{r['method']},target={target_acc},"
              f"rounds={rt},comm_MB={row['comm_MB_to_target']},"
              f"GFLOPs={row['client_GFLOPs_to_target']}", flush=True)
    base = next((x for x in rows if x["method"] == "fedavg"), None)
    for row in rows:
        if base and row["comm_MB_to_target"] and base["comm_MB_to_target"]:
            row["comm_reduction_vs_fedavg"] = round(
                base["comm_MB_to_target"] / row["comm_MB_to_target"], 2)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows
