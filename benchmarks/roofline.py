"""Roofline analysis (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI per chip.

Three terms per (arch x shape), single-pod 256-chip mesh:
  compute    = HLO_FLOPs      / (chips * 197e12)
  memory     = HLO_bytes      / (chips * 819e9)
  collective = collective_bytes / (chips * 50e9)

Methodology — why probes: XLA's cost_analysis counts a while/scan body
ONCE, not x trip-count, so the full dry-run artifact (layer scan + client
scan) under-reports FLOPs/bytes. We therefore lower *scan-free* probe
programs (layers unrolled, clients unrolled) at full tensor dimensions
but reduced (client, layer-rep) counts, and linearly extrapolate:

  train:  cost(C, R) = a + C*(h + R*l)    probes (1,1), (2,1), (1,2)
  serve:  cost(R)    = a + R*l            probes R=1, R=2

Collective bytes come from the probes' post-SPMD HLO (scan-free => every
collective visible with its true multiplicity). A calibration matmul
determines whether cost_analysis reports per-partition or global numbers
on this backend (flops_scale).
"""
# Must precede any jax import (same contract as dryrun.py).
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import math          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                      # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import INPUT_SHAPES, InputShape, get_config, list_archs  # noqa: E402
from repro.configs.base import ModelConfig   # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (input_specs, make_decode_step,  # noqa: E402
                                make_prefill_step, make_train_step,
                                resolve_serving_config)
from repro.models import init_lm             # noqa: E402
from repro.sharding.rules import param_pspecs, state_pspecs  # noqa: E402

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip


# ---------------------------------------------------------- param counts

def param_counts(cfg: ModelConfig):
    """(total_params, active_params) analytically from the config."""
    from repro.models.lm import layer_groups
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def attn_params():
        if cfg.attention == "mla":
            nope, rp, R = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
            q = (d * cfg.q_lora_rank + cfg.q_lora_rank * H * (nope + rp)
                 if cfg.q_lora_rank else d * H * (nope + rp))
            return (q + d * R + d * rp + R * H * nope * 2 + H * nope * d)
        return d * H * hd + 2 * d * Kv * hd + H * hd * d

    def mlp_params(width=None):
        w = width or ff
        mult = 3 if cfg.mlp_act == "swiglu" else 2
        return mult * d * w

    def mamba_params():
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        return d * (2 * d_in + 2 * N + cfg.ssm_heads) + d_in * d

    total = V * d * (1 if cfg.tie_embeddings else 2)
    active = total
    for i in range(cfg.num_layers):
        kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
        mix = attn_params() if kind == "attn" else mamba_params()
        total += mix
        active += mix
        if ff == 0:
            continue
        if cfg.num_experts > 0 and cfg.is_moe_layer(i):
            total += cfg.num_experts * mlp_params() + d * cfg.num_experts
            active += cfg.num_experts_per_tok * mlp_params()
            if cfg.num_shared_experts:
                shared = mlp_params(ff * cfg.num_shared_experts)
                total += shared
                active += shared
        else:
            total += mlp_params()
            active += mlp_params()
    if cfg.is_encoder_decoder:
        enc = cfg.num_encoder_layers * (attn_params() + mlp_params())
        dec_cross = cfg.num_layers * attn_params()
        total += enc + dec_cross
        active += enc + dec_cross
    return total, active


# ----------------------------------------------------------- calibration

def calibrate_flops_scale(mesh) -> float:
    """Compare cost_analysis flops of a sharded matmul vs analytic global
    flops -> multiplier that converts reported flops to GLOBAL flops."""
    n = 2048
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    sh = NamedSharding(mesh, P("data", "model"))
    fn = jax.jit(lambda x, y: x @ y, in_shardings=(sh, sh))
    compiled = fn.lower(a, a).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    reported = float(ca.get("flops", 0.0))
    analytic = 2.0 * n * n * n
    return analytic / reported if reported else 1.0


# ----------------------------------------------------------- probe infra

def _measure(fn, args, mesh):
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "coll_by_type": coll["bytes_by_type"]}


def _probe_cfg(cfg: ModelConfig, reps: int) -> ModelConfig:
    from repro.models.lm import layer_groups
    lead, period, n_reps = layer_groups(cfg)
    P_ = len(period)
    return dataclasses.replace(
        cfg, num_layers=cfg.first_k_dense + P_ * reps,
        num_encoder_layers=min(cfg.num_encoder_layers, reps))


def _adam_cost(cfg: ModelConfig, chips: int):
    """Analytic per-device cost of the outer Adam step (the C-independent
    intercept `a` of the train cost model): reads θ, g, m, v; writes θ,
    m, v (f32 states, bf16 params); ~12 flops/param."""
    n_total, _ = param_counts(cfg)
    n_dev = n_total / chips
    return {"flops": 12.0 * n_dev,
            "bytes": (4 * 4 + 2 * 2) * n_dev + 4 * 4 * n_dev,
            "coll": 0.0}


def probe_train(cfg: ModelConfig, shape: InputShape, mesh, algo="fomaml"):
    """Two probes (C=1, R in {1,2}), remat off (probes measure the
    algorithmic cost; the dry-run proves remat'd memory separately):
      cost(1, R) = a + h + R*l  ->  l, (a+h); a estimated analytically
      total(C)   = a + C*(h + n_reps*l)
    """
    from repro.models.lm import layer_groups
    _, period, n_reps = layer_groups(cfg)
    S = shape.seqs_per_client
    chips = int(np.prod(mesh.devices.shape))
    out = {}
    for R in (1, 2):
        pcfg = _probe_cfg(cfg, R)
        pshape = dataclasses.replace(shape, global_batch=S,
                                     clients_per_round=1)
        step, init_state, _, _ = make_train_step(
            pcfg, algo_name=algo, scan_clients=False, unroll_layers=True,
            remat=False)
        state_sds = jax.eval_shape(lambda i=init_state: i(jax.random.PRNGKey(0)))
        pspec = param_pspecs(state_sds["phi"]["theta"], mesh)
        sspec = state_pspecs(state_sds, pspec, mesh)
        spec = input_specs(pcfg, pshape, mesh)
        fn = jax.jit(step, in_shardings=(
            jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                         is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: NamedSharding(mesh, s), spec["pspec"],
                         is_leaf=lambda x: isinstance(x, P))))
        out[R] = _measure(fn, (state_sds, spec["batch"]), mesh)

    total = {}
    C_full = shape.global_batch // S   # single-pod: G=1
    a = _adam_cost(cfg, chips)
    for key in ("flops", "bytes", "coll"):
        l = out[2][key] - out[1][key]
        a_plus_h = out[1][key] - l
        h = max(0.0, a_plus_h - a[key])
        total[key] = max(0.0, a[key] + C_full * (h + n_reps * l))
    total["probes"] = {str(k): v for k, v in out.items()}
    return total


def probe_serve(cfg: ModelConfig, shape: InputShape, mesh,
                param_mode: str = "train", cache_seq_shard: bool = False):
    from repro.models.lm import layer_groups
    _, period, n_reps = layer_groups(cfg)
    out = {}
    for R in (1, 2):
        pcfg = resolve_serving_config(_probe_cfg(cfg, R), shape)
        spec = input_specs(pcfg, shape, mesh,
                           cache_seq_shard=cache_seq_shard)
        if shape.kind == "prefill":
            step = make_prefill_step(pcfg, unroll_layers=True)
            params_sds = jax.eval_shape(
                lambda c=pcfg: init_lm(jax.random.PRNGKey(0), c))
            pspec = param_pspecs(params_sds, mesh, mode=param_mode)
            fn = jax.jit(step, in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                             is_leaf=lambda x: isinstance(x, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), spec["pspec"],
                             is_leaf=lambda x: isinstance(x, P))))
            args = (params_sds, spec["batch"])
        else:
            scfg = spec["serving_cfg"]
            step = make_decode_step(scfg, unroll_layers=True)
            params_sds = jax.eval_shape(
                lambda c=scfg: init_lm(jax.random.PRNGKey(0), c))
            pspec = param_pspecs(params_sds, mesh, mode=param_mode)
            nm = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                        is_leaf=lambda x: isinstance(x, P))
            fn = jax.jit(step, in_shardings=(nm(pspec),
                                             nm(spec["pspec"]["cache"]),
                                             nm(spec["pspec"]["tokens"])))
            args = (params_sds, spec["batch"]["cache"],
                    spec["batch"]["tokens"])
        out[R] = _measure(fn, args, mesh)

    total = {}
    for key in ("flops", "bytes", "coll"):
        l = out[2][key] - out[1][key]
        a = out[1][key] - l
        total[key] = max(0.0, a + n_reps * l)
    total["probes"] = {str(k): v for k, v in out.items()}
    return total


# -------------------------------------------------------------- analysis

def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Analytic useful FLOPs: 6*N_active*D train (FOMAML: support pass +
    query pass), 2*N_active*D prefill/decode."""
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens          # two grad passes over half
                                              # the tokens each = 6*N*D
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: 1 token/stream


def analyze_pair(arch: str, shape_name: str, *, flops_scale: float,
                 mesh=None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    chips = int(np.prod(mesh.devices.shape))
    if shape.kind == "train":
        tot = probe_train(cfg, shape, mesh)
    else:
        tot = probe_serve(cfg, shape, mesh)
    flops_g = tot["flops"] * flops_scale
    bytes_g = tot["bytes"] * flops_scale       # same partition convention
    coll_g = tot["coll"] * chips               # HLO shapes are per-device
    terms = {
        "compute_s": flops_g / (chips * PEAK_FLOPS),
        "memory_s": bytes_g / (chips * HBM_BW),
        "collective_s": coll_g / (chips * ICI_BW),
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {"arch": arch, "shape": shape_name, "chips": chips,
            "hlo_flops": flops_g, "hlo_bytes": bytes_g,
            "collective_bytes": coll_g, **terms,
            "dominant": dominant.replace("_s", ""),
            "model_flops": mf,
            "useful_flops_ratio": mf / flops_g if flops_g else None,
            "probes": tot["probes"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default="results/roofline")
    args = ap.parse_args()

    mesh = make_production_mesh()
    scale = calibrate_flops_scale(mesh)
    print(f"# flops_scale (cost_analysis -> global) = {scale:.3f}",
          flush=True)
    pairs = ([(a, s) for a in list_archs() for s in INPUT_SHAPES]
             if args.all else [(args.arch, args.shape)])
    os.makedirs(args.json, exist_ok=True)
    for arch, shape in pairs:
        if arch == "seamless-m4t-medium" and shape == "long_500k":
            print(f"roofline,{arch},{shape},SKIPPED", flush=True)
            continue
        try:
            rec = analyze_pair(arch, shape, flops_scale=scale, mesh=mesh)
            rec["flops_scale"] = scale
            print(f"roofline,{arch},{shape},"
                  f"compute={rec['compute_s']:.3e},"
                  f"memory={rec['memory_s']:.3e},"
                  f"collective={rec['collective_s']:.3e},"
                  f"dominant={rec['dominant']},"
                  f"useful={rec['useful_flops_ratio']:.3f}" if
                  rec["useful_flops_ratio"] else "n/a", flush=True)
        except Exception as e:
            import traceback
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
            print(f"roofline,{arch},{shape},ERROR,{rec['error'][:120]}",
                  flush=True)
        with open(os.path.join(args.json, f"{arch}__{shape}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
