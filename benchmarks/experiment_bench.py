"""Experiment-plane smoke bench: runs the FedMeta-vs-FedAvg comparison
(`repro.federated.experiment.run_comparison`) on the femnist + sent140
synthetic datasets plus the production recommendation scenario
(local-head vs global-head, DESIGN.md §13) and reports the
comm-to-target-accuracy reductions.

``dry=True`` (the run.py default) keeps rounds/pools tiny so the whole
thing finishes in CI; ``dry=False`` runs the committed-artifact scale.
Comparison JSONs go to ``results/experiments/``; the bench summary to
``json_out``.
"""
from __future__ import annotations

import json
import time

from repro.federated.experiment import default_plan, run_comparison

DATASETS = ("femnist", "sent140", "recommend")


def run(dry: bool = True, json_out: str | None = None,
        out_dir: str | None = None, datasets=DATASETS, log=print):
    # dry smokes must not land next to the committed full-run artifacts
    if out_dir is None:
        out_dir = "results/experiments-smoke" if dry else \
            "results/experiments"
    summary = {}
    for dataset in datasets:
        # fine eval grids at full scale: comm-to-target crossings are
        # read off the eval grid (sustained over plan.sustain_evals
        # consecutive evals), and a coarse grid quantizes away real
        # differences — e.g. Meta-SGD's 2x-sized phi needs a <2x-rounds
        # crossing to show its byte advantage. sent140 evals are cheap
        # (every round); femnist's FedAvg(Meta) eval finetunes every
        # val client, so every-2 keeps the run tractable on CPU.
        # sent140 pins the repo's fig3 target (0.70): synthetic FedAvg
        # plateaus at ~0.687 within a few rounds, so a derived shared
        # target cannot discriminate; FedMeta reaches 0.70 in a few
        # rounds while FedAvg never does (reduction = lower bound)
        # recommend (scenario plane): derived shared target; the size
        # asymmetry (FedMeta 40-way local head vs FedAvg 120-way global
        # head) shows up in bytes even at equal rounds
        full = {"femnist": dict(rounds=100, eval_every=2),
                "sent140": dict(rounds=60, eval_every=2, target_acc=0.70),
                "recommend": dict(rounds=60, eval_every=2)}
        over = (dict(rounds=4, eval_every=2, num_clients=24,
                     name=f"{dataset}_smoke") if dry else full[dataset])
        plan = default_plan(dataset, **over)
        t0 = time.time()
        out = run_comparison(plan, out_dir=out_dir, log=log)
        # lower-bound reductions (FedAvg never reached the target; the
        # denominator is its full-run spend) render as ">=x" strings so
        # the summary cannot over-claim a measured ratio
        reductions = {
            m: (f">={row['comm_reduction_vs_fedavg']}"
                if row.get("comm_reduction_is_lower_bound")
                else row.get("comm_reduction_vs_fedavg"))
            for m, row in (out.get("comm_to_target") or {}).items()
            if row and m not in ("fedavg",)}
        summary[dataset] = {
            "seconds": round(time.time() - t0, 1),
            "target_acc": out["target_acc"],
            "comm_reduction_vs_fedavg": reductions,
            "test_acc": {m: round(r["test_acc"], 4)
                         for m, r in out["methods"].items()},
            "artifact": out.get("path"),
        }
    if json_out:
        with open(json_out, "w") as f:
            json.dump(summary, f, indent=1)
    return summary
