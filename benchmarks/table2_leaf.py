"""Paper Table 2 / Figure 2: accuracy on (synthetic) LEAF datasets for
FedAvg, FedAvg(Meta), FedMeta(MAML/FOMAML/Meta-SGD), across support
fractions. Scaled-down CPU reproduction; claims validated directionally:
FedMeta > FedAvg(Meta) > FedAvg, fast convergence (EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import json

from repro.data import make_femnist, make_sent140, make_shakespeare
from repro.models.paper import char_lstm, femnist_cnn, sent_lstm

from benchmarks.common import run_fedavg, run_fedmeta

# (dataset builder, model builder, hyperparams) — lrs follow paper Table 4
# shape; rounds scaled to CPU budget.
SETUPS = {
    "femnist": dict(
        data=lambda: make_femnist(num_clients=100, mean_samples=60, seed=0),
        model=lambda: femnist_cnn(num_classes=62, hidden=128),
        inner_lr=0.01, outer_lr=1e-3, local_lr=1e-3,
        clients_per_round=4, support_size=16, query_size=16),
    "shakespeare": dict(
        data=lambda: make_shakespeare(num_clients=48, mean_samples=150,
                                      seed=0),
        model=lambda: char_lstm(vocab=70, hidden=64, embed_dim=8),
        inner_lr=0.1, outer_lr=1e-2, local_lr=1e-3,
        clients_per_round=8, support_size=24, query_size=24),
    "sent140": dict(
        data=lambda: make_sent140(num_clients=100, seed=0),
        model=lambda: sent_lstm(vocab=2000, hidden=32, embed_dim=16),
        inner_lr=0.01, outer_lr=1e-3, local_lr=1e-3,
        clients_per_round=8, support_size=16, query_size=16),
}

METHODS = ("fedavg", "fedavg(meta)", "maml", "fomaml", "meta-sgd")


def run(dataset: str = "sent140", rounds: int = 150,
        support_fracs=(0.2,), methods=METHODS, seed: int = 0,
        json_out: str | None = None):
    su = SETUPS[dataset]
    ds = su["data"]()
    splits = ds.split_clients(seed=seed)
    model = su["model"]()
    rows = []
    for p in support_fracs:
        kw = dict(rounds=rounds, clients_per_round=su["clients_per_round"],
                  support_frac=p, support_size=su["support_size"],
                  query_size=su["query_size"], seed=seed)
        for method in methods:
            if method == "fedavg":
                r = run_fedavg(model, splits, local_lr=su["local_lr"], **kw)
            elif method == "fedavg(meta)":
                r = run_fedavg(model, splits, local_lr=su["local_lr"],
                               meta_eval=True, **kw)
            else:
                r = run_fedmeta(method, model, splits,
                                inner_lr=su["inner_lr"],
                                outer_lr=su["outer_lr"], **kw)
            row = {"dataset": dataset, "support_frac": p,
                   "method": r["method"], "test_acc": round(r["test_acc"], 4),
                   "rounds": rounds, "seconds": round(r["seconds"], 1),
                   "comm_MB": round(r["comm"]["comm_MB"], 2)}
            rows.append(row)
            print(f"table2,{dataset},{r['method']},p={p},"
                  f"acc={row['test_acc']},comm_MB={row['comm_MB']},"
                  f"s={row['seconds']}", flush=True)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows
