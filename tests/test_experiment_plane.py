"""Experiment plane + FedAvg parity + evaluation-path regression tests.

Covers the PR-3 invariants: FedAvg round-loop comm accounting
(upload bytes = m * bytes(θ) * rounds — full model both ways), the
query-count-weighted §4.1 evaluation vs hand-computed values, the
packed-trainer example path (phi_tree, never state["phi"]), per-step
finetune minibatches, per-round history, and comm-to-target-accuracy
monotonicity.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classification_loss, make_algorithm
from repro.data.federated import (ClientData, FederatedDataset,
                                  sample_task_batch)
from repro.federated.comm import CommTracker
from repro.federated.experiment import (ExperimentPlan, comm_to_target,
                                        run_comparison)
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import (FederatedTrainer, evaluate_global,
                                    evaluate_meta)
from repro.optim import adam
from repro.utils.pytree import tree_bytes


def _tiny_dataset(num_clients=12, seed=0, feat=4, classes=2):
    rng = np.random.RandomState(seed)
    mu = rng.normal(0, 1, (classes, feat))
    clients = []
    for _ in range(num_clients):
        n = rng.randint(10, 24)
        y = rng.randint(0, classes, (n,))
        x = mu[y] + rng.normal(0, 0.3, (n, feat))
        clients.append(ClientData(x.astype(np.float32), y.astype(np.int64)))
    return FederatedDataset(clients, num_classes=classes, name="tiny")


class _TinyModel:
    name = "tiny-linear"

    @staticmethod
    def init(key):
        k, _ = jax.random.split(key)
        return {"w": jax.random.normal(k, (4, 2)) * 0.1,
                "b": jnp.zeros((2,))}

    @staticmethod
    def apply(params, x):
        return x @ params["w"] + params["b"]


def _loss_eval():
    return classification_loss(_TinyModel.apply)


def _fedavg(ds, **kw):
    loss_fn, eval_fn = _loss_eval()
    args = dict(local_lr=0.05, local_steps=3, train_clients=ds.clients,
                clients_per_round=4, support_frac=0.5, support_size=8,
                query_size=8, seed=0)
    args.update(kw)
    return FedAvgTrainer(loss_fn, eval_fn, **args)


# ---- FedAvg round loop + comm accounting --------------------------------

def test_fedavg_run_comm_invariants():
    ds = _tiny_dataset()
    fa = _fedavg(ds)
    state = fa.init(jax.random.PRNGKey(0), _TinyModel.init)
    rounds = 5
    state = fa.run(state, rounds, eval_every=2, eval_clients=ds.clients[:4])
    theta_bytes = tree_bytes(state["theta"])
    m = fa.clients_per_round
    # FedAvg ships the FULL model both ways every round
    assert fa.comm.upload_bytes == rounds * m * theta_bytes
    assert fa.comm.download_bytes == rounds * m * theta_bytes
    assert fa.comm.total_bytes == 2 * rounds * m * theta_bytes
    # history: one record per round, eval fields only on eval rounds
    assert len(fa.history) == rounds
    assert [r["round"] for r in fa.history] == [1, 2, 3, 4, 5]
    assert all("train_loss" in r and "accuracy" in r for r in fa.history)
    eval_rounds = [r["round"] for r in fa.history if "eval_acc" in r]
    assert eval_rounds == [2, 4, 5]
    # cumulative comm recorded per round
    comms = [r["comm_MB"] for r in fa.history]
    assert all(b > a for a, b in zip(comms, comms[1:]))
    assert fa.history[-1]["upload_MB"] == pytest.approx(
        fa.comm.upload_bytes / 1e6)


def test_fedavg_chunked_matches_vmap():
    ds = _tiny_dataset()
    loss_fn, eval_fn = _loss_eval()
    theta = _TinyModel.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(3)
    tb = sample_task_batch(ds.clients, 6, 0.5, 8, 8, rng)
    batches = (jnp.asarray(np.stack([tb.support_x] * 2, axis=1)),
               jnp.asarray(np.stack([tb.support_y] * 2, axis=1)))
    w = jnp.asarray(tb.weight)
    full = _fedavg(ds).round_step({"theta": theta}, batches, w)
    # chunk that does NOT divide m=6 exercises zero-weight padding
    chunked = _fedavg(ds, client_chunk=4).round_step(
        {"theta": theta}, batches, w)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(chunked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fedavg_weighted_aggregation():
    """weights concentrate on client 0 -> the round returns client 0's
    locally trained model, not the uniform average."""
    ds = _tiny_dataset()
    fa = _fedavg(ds)
    theta = _TinyModel.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(3)
    tb = sample_task_batch(ds.clients, 3, 0.5, 8, 8, rng)
    batches = (jnp.asarray(tb.support_x[:, None]),
               jnp.asarray(tb.support_y[:, None]))
    w = jnp.asarray([1.0, 0.0, 0.0])
    out = fa.round_step({"theta": theta}, batches, w)["theta"]
    solo = fa.local_train(theta, jax.tree.map(lambda x: x[0], batches))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(solo)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---- §4.1 query-count-weighted evaluation -------------------------------

def test_weighted_eval_vs_hand_computed():
    """Clients with constant labels and known sizes: the fake evaluator
    'predicts' each client's constant label as its accuracy, so the
    §4.1 accuracy must equal sum(n_q * acc) / sum(n_q) regardless of
    the (random) client order in the eval batch."""
    sizes_labels = [(10, 1), (4, 0), (6, 1)]   # support_frac=0.5
    clients = [ClientData(np.zeros((n, 4), np.float32),
                          np.full((n,), lab, np.int64))
               for n, lab in sizes_labels]
    # n_sup = round(0.5*n) -> query counts 5, 2, 3
    expect_acc = (5 * 1 + 2 * 0 + 3 * 1) / (5 + 2 + 3)       # 0.8
    unweighted = (1 + 0 + 1) / 3

    def fake_evaluator(_params, support, query):
        accs = jnp.mean(query[1].astype(jnp.float32), axis=1)
        return accs, 1.0 - accs    # loss complements acc

    loss_fn, eval_fn = _loss_eval()
    acc, per_client, loss = evaluate_global(
        eval_fn, {"w": jnp.zeros((4, 2))}, clients, support_frac=0.5,
        support_size=4, query_size=4, seed=0, evaluator=fake_evaluator)
    assert acc == pytest.approx(expect_acc)
    assert acc != pytest.approx(unweighted)
    assert loss == pytest.approx(1.0 - expect_acc)
    assert sorted(per_client.tolist()) == [0.0, 1.0, 1.0]

    algo = make_algorithm("fomaml", loss_fn, eval_fn, inner_lr=0.05)
    acc_m, _, loss_m = evaluate_meta(
        algo, {"theta": None}, clients, support_frac=0.5, support_size=4,
        query_size=4, seed=0, evaluator=fake_evaluator)
    assert acc_m == pytest.approx(expect_acc)
    assert loss_m == pytest.approx(1.0 - expect_acc)


def test_task_batch_query_counts():
    ds = _tiny_dataset()
    rng = np.random.RandomState(0)
    tb = sample_task_batch(ds.clients, 4, 0.5, 8, 8, rng)
    assert tb.query_count is not None and tb.query_count.shape == (4,)
    assert (tb.query_count >= 1).all()
    # counts are the TRUE query sizes, not the resampled fixed shape
    ns = sorted(c.n for c in ds.clients)
    assert tb.query_count.max() <= ns[-1]


# ---- finetune: per-step seeded minibatches ------------------------------

def test_finetune_per_step_minibatches():
    ds = _tiny_dataset()
    fa = _fedavg(ds, local_optimizer="sgd", local_lr=0.1,
                 finetune_batch_size=4)
    theta = _TinyModel.init(jax.random.PRNGKey(2))
    rng = np.random.RandomState(1)
    tb = sample_task_batch(ds.clients, 1, 0.5, 8, 8, rng)
    support = (jnp.asarray(tb.support_x[0]), jnp.asarray(tb.support_y[0]))
    a = fa.finetune(theta, support, steps=3)
    b = fa.finetune(theta, support, steps=3)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # and it is NOT the old broadcast-one-batch behavior
    broadcast = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (3,) + x.shape), support)
    old = fa.local_train(theta, broadcast)
    assert any(not np.allclose(np.asarray(la), np.asarray(lo))
               for la, lo in zip(jax.tree.leaves(a), jax.tree.leaves(old)))


# ---- packed-trainer example path ----------------------------------------

def test_packed_trainer_example_path():
    ds = _tiny_dataset(num_clients=10)
    loss_fn, eval_fn = _loss_eval()
    algo = make_algorithm("fomaml", loss_fn, eval_fn, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(0.01), ds.clients[:6],
                          clients_per_round=3, support_frac=0.5,
                          support_size=8, query_size=8, packed=True)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    state = tr.run(state, 2, eval_every=1, eval_clients=ds.clients[6:])
    # state["phi"] is a FLAT buffer on the packed pipeline...
    assert jnp.ndim(state["phi"]) == 1
    # ...and phi_tree is the example-facing accessor that always works
    acc, per_client, loss = evaluate_meta(
        algo, tr.phi_tree(state), ds.clients[6:], support_frac=0.5,
        support_size=8, query_size=8)
    assert 0.0 <= acc <= 1.0 and np.isfinite(loss)
    assert len(tr.history) == 2
    assert all("eval_acc" in r for r in tr.history)


def test_federated_trainer_history_every_round():
    ds = _tiny_dataset(num_clients=10)
    loss_fn, eval_fn = _loss_eval()
    algo = make_algorithm("fomaml", loss_fn, eval_fn, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(0.01), ds.clients[:6],
                          clients_per_round=3, support_frac=0.5,
                          support_size=8, query_size=8)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr.run(state, 5, eval_every=3, eval_clients=ds.clients[6:])
    assert [r["round"] for r in tr.history] == [1, 2, 3, 4, 5]
    assert all("query_loss" in r and "comm_MB" in r for r in tr.history)
    assert [r["round"] for r in tr.history if "eval_acc" in r] == [3, 5]


# ---- comm-to-target metric ----------------------------------------------

def _mk_history(accs, mb_per_round=2.0):
    hist = []
    for i, acc in enumerate(accs):
        rec = {"round": i + 1, "comm_MB": mb_per_round * (i + 1),
               "upload_MB": mb_per_round * (i + 1) / 2,
               "download_MB": mb_per_round * (i + 1) / 2,
               "client_GFLOPs": 0.1 * (i + 1)}
        if acc is not None:
            rec["eval_acc"] = acc
        hist.append(rec)
    return hist


def test_comm_to_target_monotone_in_target():
    hist = _mk_history([None, 0.3, None, 0.5, None, 0.7])
    rows = [comm_to_target(hist, t) for t in (0.1, 0.3, 0.4, 0.5, 0.69)]
    assert all(r is not None for r in rows)
    mbs = [r["comm_MB"] for r in rows]
    assert all(b >= a for a, b in zip(mbs, mbs[1:]))
    assert comm_to_target(hist, 0.71) is None
    assert comm_to_target(hist, 0.3)["rounds"] == 2


def test_comm_to_target_uses_first_crossing():
    hist = _mk_history([0.2, 0.6, 0.4, 0.8])
    assert comm_to_target(hist, 0.5)["rounds"] == 2


def test_comm_to_target_sustained_ignores_noise_spike():
    hist = _mk_history([0.2, 0.6, 0.4, 0.7, 0.8])
    # a single noisy 0.6 eval must not count with sustain=2; the first
    # window holding >= 0.5 is rounds (4, 5), charged at its last round
    assert comm_to_target(hist, 0.5, sustain=2)["rounds"] == 5
    assert comm_to_target(hist, 0.75, sustain=2) is None
    # sustain larger than the eval list degrades to min over all evals
    assert comm_to_target(hist, 0.1, sustain=99)["rounds"] == 5

    from repro.federated.experiment import _sustained_best
    assert _sustained_best(hist, 1) == 0.8
    assert _sustained_best(hist, 2) == pytest.approx(0.7)


def test_method_overrides():
    from repro.federated.experiment import make_trainer
    loss_fn, eval_fn = _loss_eval()
    ds = _tiny_dataset()
    plan = ExperimentPlan(
        dataset="tiny", inner_lr=0.1, local_steps=2,
        method_overrides={"fomaml": {"inner_lr": 0.05},
                          "fedavg": {"local_steps": 7}},
        data_fn=lambda n, s: _tiny_dataset(n, s), model_fn=lambda: _TinyModel)
    assert make_trainer(plan, "fomaml", loss_fn, eval_fn,
                        ds.clients).algo.inner_lr == 0.05
    assert make_trainer(plan, "maml", loss_fn, eval_fn,
                        ds.clients).algo.inner_lr == 0.1
    assert make_trainer(plan, "fedavg", loss_fn, eval_fn,
                        ds.clients).local_steps == 7
    assert plan.to_json()["method_overrides"] == plan.method_overrides


def test_shared_sampling_stream_parity(monkeypatch):
    """The experiment plane's core invariant: FederatedTrainer and
    FedAvgTrainer under the same seed consume IDENTICAL task-sampling
    streams — same clients, same support/query splits, every round.
    Both run() loops draw through the shared TaskStream
    (data.federated), so that call site is patched for the round draws;
    measure_flops still draws directly from each trainer module."""
    import repro.data.federated as dfed
    import repro.federated.fedavg as fav
    import repro.federated.server as srv
    from repro.data.federated import sample_task_batch as real

    logs = {"meta": [], "avg": []}

    def recorder(key):
        def wrapped(clients, m, *a, **kw):
            tb = real(clients, m, *a, **kw)
            logs[key].append((np.asarray(tb.support_x).tobytes(),
                              np.asarray(tb.query_x).tobytes(),
                              np.asarray(tb.weight).tobytes()))
            return tb
        return wrapped

    ds = _tiny_dataset()
    loss_fn, eval_fn = _loss_eval()
    common = dict(clients_per_round=4, support_frac=0.5, support_size=8,
                  query_size=8, seed=7)

    monkeypatch.setattr(srv, "sample_task_batch", recorder("meta"))
    monkeypatch.setattr(dfed, "sample_task_batch", recorder("meta"))
    algo = make_algorithm("fomaml", loss_fn, eval_fn, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(0.01), ds.clients, **common)
    st = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr.measure_flops(st)
    tr.run(st, 3)

    monkeypatch.setattr(fav, "sample_task_batch", recorder("avg"))
    monkeypatch.setattr(dfed, "sample_task_batch", recorder("avg"))
    fa = FedAvgTrainer(loss_fn, eval_fn, local_lr=0.05,
                       train_clients=ds.clients, **common)
    st = fa.init(jax.random.PRNGKey(0), _TinyModel.init)
    fa.measure_flops(st)
    fa.run(st, 3)

    assert len(logs["meta"]) == len(logs["avg"]) == 4  # flops probe + 3
    assert logs["meta"] == logs["avg"]


# ---- full comparison smoke ----------------------------------------------

def test_run_comparison_smoke(tmp_path):
    plan = ExperimentPlan(
        dataset="tiny", methods=("fedavg", "fedavg(meta)", "fomaml",
                                 "reptile"),
        rounds=3, eval_every=1, num_clients=12, clients_per_round=4,
        support_frac=0.5, support_size=8, query_size=8, inner_lr=0.1,
        outer_lr=0.05, local_lr=0.05, local_steps=2,
        data_fn=lambda n, s: _tiny_dataset(num_clients=n, seed=s),
        model_fn=lambda: _TinyModel)
    out = run_comparison(plan, out_dir=str(tmp_path), log=None)
    assert os.path.exists(out["path"])
    with open(out["path"]) as f:
        loaded = json.load(f)
    assert set(loaded["methods"]) == set(plan.methods)
    for m in plan.methods:
        hist = loaded["methods"][m]["history"]
        assert len(hist) == 3
        assert all("comm_MB" in r and "upload_MB" in r for r in hist)
        assert all("eval_acc" in r for r in hist)      # eval_every=1
    assert loaded["target_acc"] is not None
    assert set(loaded["comm_to_target"]) == set(plan.methods)
    # FedMeta and FedAvg methods were fed the SAME sampling stream:
    # identical per-round weighted training accuracy is too strong (the
    # client procedures differ), but comm accounting must agree on
    # rounds and the per-round download of a same-sized model
    fa = loaded["methods"]["fedavg"]["comm"]
    fm = loaded["methods"]["fomaml"]["comm"]
    assert fa["rounds"] == fm["rounds"] == 3
    assert fa["download_MB"] == pytest.approx(fm["download_MB"])


# ---- async round engine through the plane (DESIGN.md §12) ----------------

def _tiny_plan(**overrides):
    base = dict(
        dataset="tiny", methods=("fedavg", "fomaml"), rounds=4,
        eval_every=2, num_clients=12, clients_per_round=4,
        support_frac=0.5, support_size=8, query_size=8, inner_lr=0.1,
        outer_lr=0.05, local_lr=0.05, local_steps=2, pipeline="packed",
        data_fn=lambda n, s: _tiny_dataset(num_clients=n, seed=s),
        model_fn=lambda: _TinyModel)
    base.update(overrides)
    return ExperimentPlan(**base)


def test_comparison_pipelined_bit_identical():
    """run_comparison on the pipelined path (prefetch + deferred
    metrics + fused-K) must reproduce the depth-0 comparison record —
    histories AND comm-to-target table — bit for bit."""
    sync = run_comparison(_tiny_plan(), save=False)
    piped = run_comparison(
        _tiny_plan(prefetch_depth=2, flush_every=4, fuse_rounds=2),
        save=False)
    for m in ("fedavg", "fomaml"):
        assert piped["methods"][m]["history"] == sync["methods"][m]["history"]
        assert piped["methods"][m]["comm"] == sync["methods"][m]["comm"]
    assert piped["comm_to_target"] == sync["comm_to_target"]
    assert piped["target_acc"] == sync["target_acc"]
    assert piped["plan"]["prefetch_depth"] == 2   # knob is serialized


def test_committed_artifacts_comm_to_target_stable():
    """The committed comparison artifacts pin the depth-0 behavior:
    recomputing every comm-to-target row from the stored histories must
    reproduce the stored table exactly — the engine refactor may not
    shift what the experiment plane would emit."""
    art_dir = os.path.join(os.path.dirname(__file__), "..",
                           "results", "experiments")
    # *_compare.json is the comparison-artifact naming convention;
    # other schemas (e.g. the §14 robustness sweep) live alongside
    paths = [os.path.join(art_dir, f) for f in sorted(os.listdir(art_dir))
             if f.endswith("_compare.json")]
    assert paths, "committed experiment artifacts are missing"
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        sustain = rec["plan"]["sustain_evals"]
        for m, row in rec["comm_to_target"].items():
            got = comm_to_target(rec["methods"][m]["history"],
                                 rec["target_acc"], sustain=sustain)
            if row is None:
                assert got is None, (path, m)
            else:
                pinned = {k: v for k, v in row.items()
                          if not k.startswith("comm_reduction")}
                assert got == pinned, (path, m)


def test_committed_compression_artifact_bytes_advantage():
    """The §17 acceptance pin, from the committed codec-axis artifact:
    every variant reaches the pinned target (accuracy inside the clean
    noise band by the sustain rule), upload accounting is codec-true
    (re-derivable from the stored comm fields), and at least one codec
    reaches the target at ≥3× fewer true transmitted upload bytes than
    the bf16 baseline path — compounding on bf16's own 2× over f32."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "experiments", "compression_femnist.json")
    assert os.path.exists(path), "committed compression artifact missing"
    with open(path) as f:
        rec = json.load(f)
    target, sustain = rec["target_acc"], rec["sustain_evals"]
    assert rec["baseline"] == "bf16"
    rows = {}
    for label, v in rec["variants"].items():
        row = v["comm_to_target"]
        assert row is not None, f"{label} missed the pinned target"
        # the stored row re-derives from the stored history (the same
        # pure-function pin as the *_compare.json artifacts)
        assert comm_to_target(v["history"], target,
                              sustain=sustain) == row, label
        rows[label] = row
        # upload accounting is codec-true: cumulative upload bytes are
        # rounds · m · per-client-bytes for the variant's wire format
        m = 4                                     # femnist registry m
        per_round = v["comm"]["upload_MB"] / v["comm"]["rounds"] / m
        if label == "f32":
            assert per_round * 1e6 == pytest.approx(
                v["comm"]["phi_MB"] * 1e6)
        elif label == "bf16":
            assert per_round * 1e6 == pytest.approx(
                v["comm"]["phi_MB"] * 1e6 / 2)
        else:
            assert v["comm"]["codec"] == label
            assert per_round < v["comm"]["phi_MB"] / 2   # beats bf16/rd
    ratios = rec["upload_to_target_ratio_vs_bf16"]
    assert max(ratios.get("int8+ef", 0.0),
               ratios.get("topk0.05+ef", 0.0)) >= 3.0, ratios
    for label, ratio in ratios.items():
        assert ratio == pytest.approx(
            rows["bf16"]["upload_MB"] / rows[label]["upload_MB"],
            rel=0.01), label
