"""Correctness of the paper's algorithms (Algorithm 1).

Analytic check: quadratic loss L_c(θ) = 0.5‖θ − c‖². One inner step gives
θ_u = (1−α)θ + α·c_s, so:
  MAML   g = (1−α)(θ_u − c_q)
  FOMAML g = θ_u − c_q
  Meta-SGD ∂L/∂α = −(θ_u − c_q) ∘ (θ − c_s)   (elementwise)
Also: finite-difference validation on a real MLP, and server-round
invariants (weighted aggregation, order invariance of the client scan).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_algorithm
from repro.core.fedmeta import federated_meta_step
from repro.optim import adam, sgd


def quad_loss(params, batch):
    return 0.5 * jnp.sum(jnp.square(params["w"] - batch))


def quad_eval(params, batch):
    return quad_loss(params, batch), {"accuracy": jnp.zeros(())}


@pytest.fixture
def quad_setup(rng):
    theta = {"w": jnp.asarray(rng.normal(0, 1, (5,)), jnp.float32)}
    c_s = jnp.asarray(rng.normal(0, 1, (5,)), jnp.float32)
    c_q = jnp.asarray(rng.normal(0, 1, (5,)), jnp.float32)
    return theta, c_s, c_q


def test_maml_analytic(quad_setup):
    theta, c_s, c_q = quad_setup
    alpha = 0.1
    algo = make_algorithm("maml", quad_loss, quad_eval, inner_lr=alpha)
    g, _ = algo.client_grad({"theta": theta}, c_s, c_q)
    theta_u = (1 - alpha) * theta["w"] + alpha * c_s
    expect = (1 - alpha) * (theta_u - c_q)
    np.testing.assert_allclose(np.asarray(g["theta"]["w"]),
                               np.asarray(expect), rtol=1e-6, atol=1e-6)


def test_fomaml_analytic(quad_setup):
    theta, c_s, c_q = quad_setup
    alpha = 0.1
    algo = make_algorithm("fomaml", quad_loss, quad_eval, inner_lr=alpha)
    g, _ = algo.client_grad({"theta": theta}, c_s, c_q)
    theta_u = (1 - alpha) * theta["w"] + alpha * c_s
    np.testing.assert_allclose(np.asarray(g["theta"]["w"]),
                               np.asarray(theta_u - c_q),
                               rtol=1e-6, atol=1e-6)


def test_metasgd_alpha_gradient_analytic(quad_setup):
    theta, c_s, c_q = quad_setup
    algo = make_algorithm("meta-sgd", quad_loss, quad_eval, inner_lr=0.1)
    alpha = {"w": jnp.full((5,), 0.07, jnp.float32)}
    phi = {"theta": theta, "alpha": alpha}
    g, _ = algo.client_grad(phi, c_s, c_q)
    theta_u = theta["w"] - alpha["w"] * (theta["w"] - c_s)
    expect_alpha = -(theta_u - c_q) * (theta["w"] - c_s)
    expect_theta = (1 - alpha["w"]) * (theta_u - c_q)
    np.testing.assert_allclose(np.asarray(g["alpha"]["w"]),
                               np.asarray(expect_alpha), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(g["theta"]["w"]),
                               np.asarray(expect_theta), rtol=1e-6, atol=1e-6)


def test_maml_finite_differences(rng):
    """Second-order meta-gradient vs central finite differences on a
    nonlinear model (tanh MLP, 2 inner steps)."""
    W = jnp.asarray(rng.normal(0, 0.5, (3, 3)), jnp.float32)
    b = jnp.zeros((3,), jnp.float32)
    theta = {"W": W, "b": b}
    xs = jnp.asarray(rng.normal(0, 1, (8, 3)), jnp.float32)
    ys = jnp.asarray(rng.normal(0, 1, (8, 3)), jnp.float32)
    xq = jnp.asarray(rng.normal(0, 1, (8, 3)), jnp.float32)
    yq = jnp.asarray(rng.normal(0, 1, (8, 3)), jnp.float32)

    def loss(params, batch):
        x, y = batch
        pred = jnp.tanh(x @ params["W"]) + params["b"]
        return jnp.mean(jnp.square(pred - y))

    def ev(params, batch):
        return loss(params, batch), {"accuracy": jnp.zeros(())}

    algo = make_algorithm("maml", loss, ev, inner_lr=0.05, inner_steps=2)
    g, _ = algo.client_grad({"theta": theta}, (xs, ys), (xq, yq))

    def meta_loss_flat(w_flat):
        th = {"W": w_flat[:9].reshape(3, 3), "b": w_flat[9:]}
        th_u = algo.adapt({"theta": th}, (xs, ys))
        # adapt() stops gradients, but for FD evaluation values are enough
        return float(loss(th_u, (xq, yq)))

    w0 = np.concatenate([np.asarray(W).ravel(), np.asarray(b)])
    eps = 1e-3
    fd = np.zeros_like(w0)
    for i in range(len(w0)):
        wp, wm = w0.copy(), w0.copy()
        wp[i] += eps
        wm[i] -= eps
        fd[i] = (meta_loss_flat(wp) - meta_loss_flat(wm)) / (2 * eps)
    got = np.concatenate([np.asarray(g["theta"]["W"]).ravel(),
                          np.asarray(g["theta"]["b"])])
    np.testing.assert_allclose(got, fd, rtol=2e-2, atol=2e-3)


def test_server_round_weighted_aggregation(quad_setup):
    """Server update equals optimizer step on the weighted mean of client
    grads; vmap and scan client execution agree exactly."""
    theta, _, _ = quad_setup
    rng = np.random.RandomState(1)
    m = 4
    sup = jnp.asarray(rng.normal(0, 1, (m, 5)), jnp.float32)
    qry = jnp.asarray(rng.normal(0, 1, (m, 5)), jnp.float32)
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    algo = make_algorithm("maml", quad_loss, quad_eval, inner_lr=0.1)
    opt = sgd(1.0)
    phi = {"theta": theta}

    outs = {}
    for axis in ("vmap", "scan"):
        new_phi, _, _ = federated_meta_step(
            algo, opt, phi, opt.init(phi), sup, qry, w, client_axis=axis)
        outs[axis] = np.asarray(new_phi["theta"]["w"])
    np.testing.assert_allclose(outs["vmap"], outs["scan"], rtol=1e-6,
                               atol=1e-6)

    # manual weighted mean of analytic grads, lr=1 SGD
    alpha = 0.1
    ws = np.asarray(w / w.sum())
    gs = np.stack([
        (1 - alpha) * (((1 - alpha) * np.asarray(theta["w"])
                        + alpha * np.asarray(sup[i])) - np.asarray(qry[i]))
        for i in range(m)])
    expect = np.asarray(theta["w"]) - (ws[:, None] * gs).sum(0)
    np.testing.assert_allclose(outs["vmap"], expect, rtol=1e-6, atol=1e-6)


def test_client_order_invariance(quad_setup):
    """Meta-gradient mean is invariant to client ordering (DESIGN.md §8)."""
    theta, _, _ = quad_setup
    rng = np.random.RandomState(2)
    sup = jnp.asarray(rng.normal(0, 1, (6, 5)), jnp.float32)
    qry = jnp.asarray(rng.normal(0, 1, (6, 5)), jnp.float32)
    algo = make_algorithm("meta-sgd", quad_loss, quad_eval, inner_lr=0.1)
    phi = algo.init_state(jax.random.PRNGKey(0), lambda k: theta)
    opt = adam(1e-2)
    perm = rng.permutation(6)
    a, _, _ = federated_meta_step(algo, opt, phi, opt.init(phi), sup, qry,
                                  client_axis="scan")
    b, _, _ = federated_meta_step(algo, opt, phi, opt.init(phi), sup[perm],
                                  qry[perm], client_axis="scan")
    np.testing.assert_allclose(np.asarray(a["theta"]["w"]),
                               np.asarray(b["theta"]["w"]), rtol=1e-5,
                               atol=1e-6)


def test_reptile_direction(quad_setup):
    """Reptile pseudo-gradient points from θ toward the adapted params."""
    theta, c_s, c_q = quad_setup
    algo = make_algorithm("reptile", quad_loss, quad_eval, inner_lr=0.1,
                          inner_steps=3)
    g, _ = algo.client_grad({"theta": theta}, c_s, c_q)
    # after steps toward c_s then c_q, θ_k is strictly closer to c_s than θ
    movement = np.asarray(g["theta"]["w"])
    toward = np.asarray(theta["w"] - c_s)
    assert np.dot(movement, toward) > 0
