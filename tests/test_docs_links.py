"""Markdown link hygiene for the repo docs (README.md, DESIGN.md, ...).

Relative links must point at files/directories that exist in the repo,
and intra-doc anchors (``#section``) must match a real heading of the
target document — a renamed DESIGN.md section or moved artifact breaks
CI here instead of silently rotting in the README.
"""
import os
import re

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md", "PAPER.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)


def _anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (lowercase, spaces to dashes,
    punctuation dropped; § and similar symbols are stripped)."""
    slug = heading.strip().lower().replace(" ", "-")
    return re.sub(r"[^\w\-]", "", slug, flags=re.UNICODE)


def _doc_anchors(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {_anchor(h) for h in HEADING_RE.findall(f.read())}


def _links(path: str):
    with open(path, encoding="utf-8") as f:
        return LINK_RE.findall(f.read())


@pytest.mark.parametrize("doc", [d for d in DOCS
                                 if os.path.exists(os.path.join(ROOT, d))])
def test_relative_links_resolve(doc):
    doc_path = os.path.join(ROOT, doc)
    bad = []
    for link in _links(doc_path):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, anchor = link.partition("#")
        base = doc_path if not target else os.path.normpath(
            os.path.join(os.path.dirname(doc_path), target))
        if target and not os.path.exists(base):
            bad.append(f"{link}: missing file {target}")
            continue
        if anchor:
            if not base.endswith(".md"):
                continue
            if _anchor(anchor) not in _doc_anchors(base):
                bad.append(f"{link}: no heading for #{anchor} in "
                           f"{os.path.relpath(base, ROOT)}")
    assert not bad, f"{doc}: dead links:\n  " + "\n  ".join(bad)


def test_readme_exists():
    assert os.path.exists(os.path.join(ROOT, "README.md")), \
        "README.md is part of the documented surface (PR 5)"
