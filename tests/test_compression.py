"""Bytes-on-the-wire plane (DESIGN.md §17).

Contracts: (1) every compression kernel matches its pure-jnp oracle —
int8 quantization bitwise on the payload, top-k exactly; (2) the int8
round-trip error obeys the half-step bound s/2 per coordinate; (3)
error-feedback residuals telescope — the sum of dequantized uploads
plus the final residual equals the sum of the true (corrected)
gradients, so no gradient mass is ever lost to quantization; (4) with
every knob off the trainer histories are bit-identical across all four
FedMeta algorithms and FedAvg (pipelined == sync through the new
staging tail); (5) checkpoint resume replays EF state bit-identically;
(6) the fused DP path pins against `privacy.dp_aggregate`'s clipping
and its σ_effective = z·S/m accounting (hand-checked by output
variance); (7) bf16 optimizer state tracks f32 within a pinned
tolerance; (8) the CommTracker reports codec-true upload bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classification_loss, make_algorithm
from repro.core.fedmeta import init_packed_state, make_packed_meta_train_step
from repro.federated import (CompressionConfig, DPConfig, dp_aggregate,
                             dp_clip_factors)
from repro.federated.async_engine import StalenessConfig
from repro.federated.faults import FaultConfig
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import FederatedTrainer
from repro.kernels.meta_update import ops as mu_ops
from repro.kernels.meta_update.compress import (int8_aggregate_ref,
                                                int8_encode_ref,
                                                int8_row_norms, int8_scales,
                                                topk_aggregate_ref,
                                                topk_densify, topk_encode,
                                                topk_row_norms)
from repro.optim import adam
from repro.utils.flat import plane_for
from tests.test_async_engine import (ALGOS, EVAL, LOSS_FN, EVAL_FN, TRAIN,
                                     _TinyModel, _fedmeta_history,
                                     _no_prefetch_threads)

IMPLS = ("xla", "pallas_interpret")


def _block(m=5, n=4096, seed=0, zero_row=None):
    rng = np.random.RandomState(seed)
    G = rng.normal(0, 1, (m, n)).astype(np.float32)
    if zero_row is not None:
        G[zero_row] = 0.0
    return jnp.asarray(G)


def _weights(m=5, seed=1):
    rng = np.random.RandomState(seed)
    w = rng.uniform(0.1, 1.0, m).astype(np.float32)
    return jnp.asarray(w / w.sum())


# ---- int8 codec: kernel vs oracle ---------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
def test_int8_encode_matches_oracle(impl):
    """Payload bitwise-identical to the oracle; scales/residual to f32
    reduction-order tolerance (the scale is one jnp.max row reduce —
    jit vs eager may differ in the last ulp)."""
    G = _block(zero_row=2)                 # an all-zero row must be safe
    q_r, s_r, r_r = int8_encode_ref(G)
    q_k, s_k, r_k = mu_ops.int8_encode(G, impl=impl)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    assert q_k.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r), atol=1e-6)
    # zero row: scale 0, zero payload, zero residual (no NaN from 1/s)
    assert float(s_r[2]) == 0.0
    assert not np.any(np.asarray(q_r[2]))
    assert not np.any(np.asarray(r_r[2]))
    assert np.all(np.isfinite(np.asarray(r_k)))


def test_int8_roundtrip_error_bound():
    """|s·q − g| ≤ s/2 per coordinate (round-to-nearest of g/s), and the
    residual IS that round-trip error."""
    G = _block()
    q, s, resid = int8_encode_ref(G)
    deq = s[:, None] * q.astype(jnp.float32)
    bound = np.broadcast_to(np.asarray(s)[:, None] / 2 + 1e-6, G.shape)
    np.testing.assert_array_less(np.abs(np.asarray(deq - G)), bound)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(G - deq),
                               atol=1e-7)


@pytest.mark.parametrize("impl", IMPLS)
def test_int8_aggregate_matches_oracle(impl):
    """Fused dequantize-and-aggregate == oracle == the dense math
    Σ w_u·s_u·q_u computed on a materialized f32 block."""
    G, w = _block(), _weights()
    q, s, _ = int8_encode_ref(G)
    out = mu_ops.int8_aggregate(q, s, w, impl=impl)
    ref = int8_aggregate_ref(q, s, w)
    dense = jnp.einsum("u,un->n", w, s[:, None] * q.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=1e-6)


def test_int8_row_norms_match_decoded():
    G = _block(zero_row=1)
    q, s, _ = int8_encode_ref(G)
    deq = s[:, None] * q.astype(jnp.float32)
    want = jnp.sqrt(jnp.sum(deq * deq, axis=1))
    np.testing.assert_allclose(np.asarray(int8_row_norms(q, s)),
                               np.asarray(want), rtol=1e-5)


# ---- top-k codec ---------------------------------------------------------

def test_topk_encode_exact():
    """The selected coordinates are exactly the k largest magnitudes,
    values are carried exactly (f32), and densify + residual
    reconstructs G bit-cleanly."""
    G = _block(m=3, n=2048)
    k = 100
    vals, idx, resid = topk_encode(G, k)
    assert vals.shape == (3, k) and idx.dtype == jnp.int32
    for u in range(3):
        g = np.asarray(G[u])
        got = set(np.asarray(idx[u]).tolist())
        # |g| threshold at the k-th largest magnitude: everything
        # strictly above it must be selected
        kth = np.sort(np.abs(g))[-k]
        assert {i for i in range(len(g)) if abs(g[i]) > kth} <= got
        np.testing.assert_array_equal(np.asarray(vals[u]),
                                      g[np.asarray(idx[u])])
    dense = topk_densify(vals, idx, G.shape[1])
    np.testing.assert_allclose(np.asarray(dense + resid), np.asarray(G),
                               atol=1e-6)


def test_topk_cast_error_lands_in_residual():
    """With bf16 wire values the residual absorbs the cast error too:
    densify(decode) + residual still equals G exactly (error feedback
    sees exactly what the wire carries)."""
    G = _block(m=2, n=1024)
    vals, idx, resid = topk_encode(G, 64, val_dtype=jnp.bfloat16)
    assert vals.dtype == jnp.bfloat16
    dense = topk_densify(vals, idx, G.shape[1])
    np.testing.assert_allclose(np.asarray(dense + resid), np.asarray(G),
                               atol=1e-6)


@pytest.mark.parametrize("impl", IMPLS)
def test_topk_aggregate_matches_oracle(impl):
    G, w = _block(), _weights()
    vals, idx, _ = topk_encode(G, 128)
    n = G.shape[1]
    out = mu_ops.topk_aggregate(vals, idx, w, n, impl=impl)
    ref = topk_aggregate_ref(vals, idx, w, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(topk_row_norms(vals)),
        np.asarray(jnp.sqrt(jnp.sum(topk_densify(vals, idx, n) ** 2,
                                    axis=1))), rtol=1e-5)


# ---- error feedback telescopes ------------------------------------------

@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_error_feedback_telescopes(codec):
    """Over T rounds of one client: Σ_t decode(encode(g_t + e_t)) + e_T
    == Σ_t g_t — quantization error is deferred, never lost."""
    rng = np.random.RandomState(7)
    n, T = 1024, 8
    e = jnp.zeros((1, n), jnp.float32)
    sum_g = np.zeros(n, np.float64)
    sum_dec = np.zeros(n, np.float64)
    for t in range(T):
        g = jnp.asarray(rng.normal(0, 1, (1, n)), jnp.float32)
        sum_g += np.asarray(g[0], np.float64)
        corrected = g + e
        if codec == "int8":
            q, s, e = int8_encode_ref(corrected)
            dec = s[:, None] * q.astype(jnp.float32)
        else:
            vals, idx, e = topk_encode(corrected, 64)
            dec = topk_densify(vals, idx, n)
        sum_dec += np.asarray(dec[0], np.float64)
    np.testing.assert_allclose(sum_dec + np.asarray(e[0], np.float64),
                               sum_g, atol=1e-4)


# ---- config surface ------------------------------------------------------

def test_compression_config_surface():
    assert CompressionConfig("int8").label() == "int8+ef"
    assert CompressionConfig("int8", error_feedback=False).label() == "int8"
    assert CompressionConfig("topk", topk_frac=0.05).label() == "topk0.05+ef"
    assert CompressionConfig("topk", topk_frac=0.1).k_for(1000) == 100
    assert CompressionConfig("topk", topk_frac=1e-9).k_for(10) == 1
    # §17 wire-format bytes: payload + side information over n_real
    assert CompressionConfig("int8").upload_bytes(1000) == 1004
    assert CompressionConfig("topk", topk_frac=0.05).upload_bytes(
        1000) == 50 * 8
    assert CompressionConfig("topk", topk_frac=0.05).upload_bytes(
        1000, val_itemsize=2) == 50 * 6
    with pytest.raises(ValueError, match="unknown codec"):
        CompressionConfig("gzip")
    with pytest.raises(ValueError, match="topk_frac"):
        CompressionConfig("topk", topk_frac=0.0)
    with pytest.raises(ValueError, match="clip_norm"):
        DPConfig(clip_norm=0.0)
    with pytest.raises(ValueError, match="noise_multiplier"):
        DPConfig(noise_multiplier=-1.0)


def test_trainer_knob_validation():
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    kw = dict(train_clients=TRAIN, clients_per_round=4, support_frac=0.5,
              support_size=8, query_size=8, seed=0)
    with pytest.raises(ValueError, match="packed"):
        FederatedTrainer(algo, adam(1e-3), packed=False,
                         compression=CompressionConfig("int8"), **kw)
    with pytest.raises(ValueError, match="packed"):
        FederatedTrainer(algo, adam(1e-3), packed=False,
                         dp=DPConfig(), **kw)
    for clash in (dict(staleness=StalenessConfig(delay=1, fraction=0.34,
                                                 discount=0.5)),
                  dict(faults=FaultConfig(dropout=0.25, seed=1)),
                  dict(aggregator="trimmed", trim=1),
                  dict(fuse_rounds=2)):
        with pytest.raises(ValueError):
            FederatedTrainer(algo, adam(1e-3), packed=True,
                             compression=CompressionConfig("int8"),
                             **clash, **kw)


# ---- off-knob bitwise identity ------------------------------------------

@pytest.mark.parametrize("algo_name", ALGOS)
def test_compression_off_bitwise_identity(algo_name):
    """With compression/dp absent the new staging tail stays empty:
    pipelined == sync bit-for-bit, and passing the knobs explicitly as
    None changes nothing (no default-argument drift)."""
    sync = _fedmeta_history(algo_name, packed=True)
    off = _fedmeta_history(algo_name, packed=True, compression=None,
                           dp=None, prefetch_depth=2, flush_every=4)
    assert off == sync
    assert _no_prefetch_threads()


def test_fedavg_unaffected_bitwise():
    def run(**kw):
        tr = FedAvgTrainer(LOSS_FN, EVAL_FN, local_lr=1e-2, local_steps=2,
                           train_clients=TRAIN, clients_per_round=4,
                           support_frac=0.5, support_size=8, query_size=8,
                           seed=0, **kw)
        state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
        tr.run(state, 6, eval_every=3, eval_clients=EVAL)
        return tr.history

    assert run(prefetch_depth=2, flush_every=3) == run()


# ---- compressed training end-to-end -------------------------------------

@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_compressed_run_pipelined_bit_identical(codec):
    """Compression (+EF) composes with the async engine: prefetched
    history == sync history, and the comm summary reports codec-true
    upload bytes (10-param model: int8 = 14 B/client, topk k=1 = 8 B)."""
    cfg = CompressionConfig(codec, topk_frac=0.1)
    sync = _fedmeta_history("fomaml", packed=True, compression=cfg)
    piped = _fedmeta_history("fomaml", packed=True, compression=cfg,
                             prefetch_depth=2, flush_every=4)
    assert piped == sync
    last = sync[-1]
    assert last["codec"] == cfg.label()
    per_client = cfg.upload_bytes(10)          # n_real of _TinyModel
    assert last["upload_MB"] * 1e6 == pytest.approx(
        last["rounds"] * 4 * per_client)
    # download leg stays dense φ
    assert last["download_MB"] * 1e6 == pytest.approx(
        last["rounds"] * 4 * 40)


def test_compressed_dp_pipelined_bit_identical():
    """int8 + EF + DP clip + noise, prefetched == sync (the noise key is
    a pure function of the round index)."""
    kw = dict(packed=True, compression=CompressionConfig("int8"),
              dp=DPConfig(clip_norm=0.5, noise_multiplier=0.3, seed=3))
    assert _fedmeta_history("fomaml", prefetch_depth=2, flush_every=4,
                            **kw) == _fedmeta_history("fomaml", **kw)


def test_ef_state_in_checkpoint_resume(tmp_path):
    """Kill-and-resume with EF residuals: the stitched history equals
    the uninterrupted run record-for-record — EF state rides the
    checkpoint payload and replays bit-identically."""
    from repro.checkpoint.io import latest_step, load_server_state

    def make(ckpt=None):
        algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
        kw = dict(checkpoint_dir=str(ckpt), checkpoint_every=3) if ckpt \
            else {}
        return FederatedTrainer(algo, adam(1e-3), TRAIN, 4,
                                support_frac=0.5, support_size=8,
                                query_size=8, seed=0, packed=True,
                                compression=CompressionConfig("int8"), **kw)

    full = make()
    state = full.init(jax.random.PRNGKey(0), _TinyModel.init)
    assert state["ef"].shape == (len(TRAIN), 1024)   # one row per client
    state = full.run(state, 9, eval_every=3, eval_clients=EVAL)
    assert np.any(np.asarray(state["ef"]))           # residuals accrued

    tr1 = make(tmp_path)
    s1 = tr1.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr1.run(s1, 6, eval_every=3, eval_clients=EVAL)
    assert latest_step(str(tmp_path)) == 6
    payload = load_server_state(str(tmp_path))
    assert "ef" in payload["state"]                  # EF rides the payload

    tr2 = make(tmp_path)
    tr2.init(jax.random.PRNGKey(0), _TinyModel.init)
    s2, start = tr2.resume()
    assert start == 6
    tr2.run(s2, 9, eval_every=3, eval_clients=EVAL, start_round=start)
    assert tr2.history == full.history


# ---- DP: fused path vs privacy oracle + σ hand-check --------------------

def test_dp_clip_matches_dp_aggregate():
    """Noise off: the fused clip-as-weight-scale aggregate equals
    `privacy.dp_aggregate`'s clip-then-weighted-mean to f32 tolerance."""
    G, w = _block(m=4, n=1024, seed=3), _weights(m=4)
    S = 0.7
    fused = mu_ops.weighted_aggregate(
        G, w * dp_clip_factors(
            jnp.sqrt(jnp.sum(G * G, axis=1)), S), impl="xla")
    oracle = dp_aggregate({"g": G}, w, jax.random.PRNGKey(0),
                          clip_norm=S, noise_multiplier=0.0)["g"]
    np.testing.assert_allclose(np.asarray(fused), np.asarray(oracle),
                               atol=1e-6)


def test_sigma_effective_hand_check():
    """σ_effective = noise_multiplier · S / m: DPConfig.sigma pins the
    formula and `dp_aggregate`'s output on zero gradients is pure noise
    whose empirical std matches it (satellite: the accounting surface
    is hand-checked, not just self-consistent)."""
    z, S, m = 1.3, 0.9, 6
    assert DPConfig(clip_norm=S, noise_multiplier=z).sigma(m) == \
        pytest.approx(z * S / m)
    G = jnp.zeros((m, 50_000), jnp.float32)
    w = jnp.ones((m,), jnp.float32) / m
    out = dp_aggregate({"g": G}, w, jax.random.PRNGKey(42),
                       clip_norm=S, noise_multiplier=z)["g"]
    assert float(jnp.std(out)) == pytest.approx(z * S / m, rel=0.05)
    assert float(jnp.mean(out)) == pytest.approx(0.0, abs=3 * z * S / m /
                                                 np.sqrt(50_000))


def test_dp_noise_leaves_padding_zero():
    """The fused step masks noise to the REAL coordinates: φ's alignment
    padding stays exactly zero through a noisy DP run (the packed
    plane's padding invariant)."""
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                          support_size=8, query_size=8, seed=0, packed=True,
                          dp=DPConfig(clip_norm=0.5, noise_multiplier=1.0,
                                      seed=9))
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    state = tr.run(state, 4)
    phi = np.asarray(state["phi"])
    assert phi.shape == (1024,)
    assert not np.any(phi[10:])                  # n_real = 10


# ---- quantized optimizer state ------------------------------------------

def test_bf16_opt_state_pinned_tolerance():
    """fused-Adam with bf16 m/v (dequantized in-kernel) tracks the f32
    run within a pinned tolerance over 10 packed steps, and the state
    really is stored in bf16 (half the optimizer-state bytes)."""
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    rng = np.random.RandomState(0)
    sup = (jnp.asarray(rng.normal(0, 1, (4, 8, 4)), jnp.float32),
           jnp.asarray(rng.randint(0, 2, (4, 8))))
    qry = (jnp.asarray(rng.normal(0, 1, (4, 8, 4)), jnp.float32),
           jnp.asarray(rng.randint(0, 2, (4, 8))))
    phi = algo.init_state(jax.random.PRNGKey(0), _TinyModel.init)
    plane = plane_for(phi)

    def run(state_dtype):
        opt = adam(1e-2, state_dtype=state_dtype)
        step = make_packed_meta_train_step(algo, opt, plane, impl="xla")
        state = init_packed_state(opt, plane, phi)
        for _ in range(10):
            state, _ = step(state, sup, qry)
        return state

    f32, bf16 = run(jnp.float32), run(jnp.bfloat16)
    assert bf16["opt"]["m"].dtype == jnp.bfloat16
    assert bf16["opt"]["v"].dtype == jnp.bfloat16
    assert f32["opt"]["m"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(bf16["phi"]),
                               np.asarray(f32["phi"]), atol=5e-3)
