"""Async round engine invariants (DESIGN.md §12).

The engine ships on one invariant: with staleness off, any pipelined
configuration (prefetch_depth > 0, deferred flushes, fused-K blocks)
produces BIT-IDENTICAL history — metrics, comm, eval fields — to the
synchronous loop under the same seed. Plus: the staleness discount rule
against a hand-computed aggregate, and prefetcher shutdown (no leaked
threads) when either side of the pipeline raises.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classification_loss, make_algorithm
from repro.core.fedmeta import init_packed_state, make_packed_meta_train_step
from repro.data.federated import ClientData, TaskStream, stack_task_batches
from repro.federated.async_engine import (PREFETCH_THREAD_NAME,
                                          AsyncRoundEngine, Prefetcher,
                                          StalenessConfig, plan_blocks)
from repro.federated.comm import CommTracker
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import FederatedTrainer
from repro.optim import adam, sgd
from repro.utils.flat import plane_for

ALGOS = ("maml", "fomaml", "meta-sgd", "reptile")


def _tiny_clients(num=12, seed=0, feat=4, classes=2):
    rng = np.random.RandomState(seed)
    mu = rng.normal(0, 1, (classes, feat))
    clients = []
    for _ in range(num):
        n = rng.randint(10, 24)
        y = rng.randint(0, classes, (n,))
        x = mu[y] + rng.normal(0, 0.3, (n, feat))
        clients.append(ClientData(x.astype(np.float32), y.astype(np.int64)))
    return clients


class _TinyModel:
    @staticmethod
    def init(key):
        k, _ = jax.random.split(key)
        return {"w": jax.random.normal(k, (4, 2)) * 0.1,
                "b": jnp.zeros((2,))}

    @staticmethod
    def apply(params, x):
        return x @ params["w"] + params["b"]


LOSS_FN, EVAL_FN = classification_loss(_TinyModel.apply)
TRAIN = _tiny_clients()
EVAL = _tiny_clients(6, seed=1)


def _fedmeta_history(algo_name, *, packed, rounds=6, eval_every=3, **kw):
    algo = make_algorithm(algo_name, LOSS_FN, EVAL_FN, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                          support_size=8, query_size=8, seed=0,
                          packed=packed, **kw)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr.run(state, rounds, eval_every=eval_every, eval_clients=EVAL)
    return tr.history


def _no_prefetch_threads():
    return all(t.name != PREFETCH_THREAD_NAME for t in threading.enumerate())


# ---- bit-identity: pipelined == synchronous -----------------------------

@pytest.mark.parametrize("packed", [False, True],
                         ids=["tree", "packed"])
@pytest.mark.parametrize("algo_name", ALGOS)
def test_pipelined_history_bit_identical(algo_name, packed):
    """prefetch_depth>0 + deferred flushes == the synchronous loop,
    record for record (float equality, not allclose), for all four
    FedMeta algorithms on both parameter representations."""
    sync = _fedmeta_history(algo_name, packed=packed)
    piped = _fedmeta_history(algo_name, packed=packed, prefetch_depth=2,
                             flush_every=4)
    assert piped == sync
    assert _no_prefetch_threads()


def test_fused_k_history_bit_identical():
    """lax.scan-over-rounds blocks (fused-K) == per-round stepping,
    including an eval round that does not divide the block size and a
    flush only at exit."""
    sync = _fedmeta_history("fomaml", packed=True, rounds=7, eval_every=3)
    fused = _fedmeta_history("fomaml", packed=True, rounds=7, eval_every=3,
                             fuse_rounds=3, prefetch_depth=1, flush_every=0)
    assert fused == sync


def test_fedavg_pipelined_history_bit_identical():
    def run(**kw):
        tr = FedAvgTrainer(LOSS_FN, EVAL_FN, local_lr=1e-2, local_steps=2,
                           train_clients=TRAIN, clients_per_round=4,
                           support_frac=0.5, support_size=8, query_size=8,
                           seed=0, **kw)
        state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
        tr.run(state, 6, eval_every=3, eval_clients=EVAL)
        return tr.history

    assert run(prefetch_depth=2, flush_every=3) == run()
    assert _no_prefetch_threads()


def test_plan_blocks():
    assert plan_blocks(5, 0, 1) == [1] * 5
    assert plan_blocks(10, 4, 3) == [3, 1, 3, 1, 2]   # eval rounds 4, 8
    assert plan_blocks(6, 2, 8) == [2, 2, 2]          # evals cap blocks
    assert plan_blocks(7, 3, 2) == [2, 1, 2, 1, 1]
    assert sum(plan_blocks(97, 10, 8)) == 97


# ---- staleness-aware aggregation ----------------------------------------

def test_staleness_discount_hand_check():
    """The γ^s rule, against a hand-built aggregate: round 1's straggler
    row must arrive in round 2 weighted by its ORIGINAL round-1 weight
    times discount**delay, renormalized over the aggregated rows."""
    cfg = StalenessConfig(delay=1, fraction=0.34, discount=0.5)
    assert cfg.num_stragglers(3) == 1
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    phi = algo.init_state(jax.random.PRNGKey(0), _TinyModel.init)
    plane = plane_for(phi)
    opt = sgd(0.1)
    step = make_packed_meta_train_step(algo, opt, plane, staleness=cfg)
    state = init_packed_state(opt, plane, phi, staleness=cfg,
                              clients_per_round=3)

    rng = np.random.RandomState(3)
    stream = TaskStream(TRAIN, 3, 0.5, 8, 8, rng)
    tb1, tb2 = stream.next(), stream.next()

    def args(tb):
        return ((jnp.asarray(tb.support_x), jnp.asarray(tb.support_y)),
                (jnp.asarray(tb.query_x), jnp.asarray(tb.query_y)),
                jnp.asarray(tb.weight))

    def rows(tb, phi_tree):
        return np.stack([
            np.asarray(plane.pack(algo.client_grad(
                phi_tree, (tb.support_x[i], tb.support_y[i]),
                (tb.query_x[i], tb.query_y[i]))[0]))
            for i in range(3)])

    sel1 = (jnp.asarray([1], jnp.int32), jnp.asarray([0, 2], jnp.int32))
    sel2 = (jnp.asarray([0], jnp.int32), jnp.asarray([1, 2], jnp.int32))

    # round 1: straggler row 1 is withheld; warmup slot has weight 0
    g1 = rows(tb1, phi)
    w1 = tb1.weight / tb1.weight.sum()
    exp1 = (w1[0] * g1[0] + w1[2] * g1[2]) / (w1[0] + w1[2])
    state1, _ = step(state, *args(tb1), sel1)
    flat0 = np.asarray(plane.pack(phi))
    np.testing.assert_allclose(np.asarray(state1["phi"]),
                               flat0 - 0.1 * exp1, rtol=1e-5, atol=1e-7)

    # round 2: row 1 of round 1 arrives at weight w1[1] * γ^1, fresh
    # rows are computed against the ADVANCED φ; renormalize over rows
    phi1 = plane.unpack(state1["phi"])
    g2 = rows(tb2, phi1)
    w2 = tb2.weight / tb2.weight.sum()
    gamma = cfg.discount ** cfg.delay
    num = w2[1] * g2[1] + w2[2] * g2[2] + gamma * w1[1] * g1[1]
    exp2 = num / (w2[1] + w2[2] + gamma * w1[1])
    state2, _ = step(state1, *args(tb2), sel2)
    np.testing.assert_allclose(
        np.asarray(state2["phi"]), np.asarray(state1["phi"]) - 0.1 * exp2,
        rtol=1e-5, atol=1e-7)
    # the new straggler (row 0 of round 2) sits in the ring buffer
    np.testing.assert_allclose(np.asarray(state2["stale"]["G"][0, 0]), g2[0],
                               rtol=1e-5, atol=1e-7)
    assert np.isclose(float(state2["stale"]["w"][0, 0]), w2[0])


def test_staleness_off_is_bitwise_noop():
    """fraction=0 staleness must not change the trajectory: every round
    aggregates m fresh rows at their full weights."""
    base = _fedmeta_history("fomaml", packed=True)
    zero = _fedmeta_history(
        "fomaml", packed=True,
        staleness=StalenessConfig(delay=1, fraction=0.0, discount=0.5))
    assert [{k: v for k, v in r.items()} for r in zero] == base


def test_staleness_jitter_off_bitwise_identical(monkeypatch):
    """jitter=False must be bitwise-identical to the pre-jitter
    fixed-delay behavior: same rng draw pattern (no extra randint),
    same step path. Pinned by monkeypatching `pick` back to the legacy
    implementation and comparing record-for-record."""
    cfg = StalenessConfig(delay=2, fraction=0.34, discount=0.5)
    assert cfg.jitter is False
    off = _fedmeta_history("fomaml", packed=True, staleness=cfg)

    def legacy_pick(self, m, rng):
        k = self.num_stragglers(m)
        perm = rng.permutation(m)
        return (np.sort(perm[:k]).astype(np.int32),
                np.sort(perm[k:]).astype(np.int32))

    monkeypatch.setattr(StalenessConfig, "pick", legacy_pick)
    legacy = _fedmeta_history("fomaml", packed=True, staleness=cfg)
    assert off == legacy


def test_staleness_jitter_hand_check():
    """Jittered staleness against an independent reference simulator:
    per-straggler delays d ∈ [0, delay], arrival at round r+d with
    weight w·γ^d (d=0 joins its own round like a fresh row), weights
    renormalized over the rows aggregated that round — including a
    round where TWO earlier stragglers (d=2 and d=1) arrive together."""
    cfg = StalenessConfig(delay=2, fraction=0.34, discount=0.5, jitter=True)
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    phi0 = algo.init_state(jax.random.PRNGKey(0), _TinyModel.init)
    plane = plane_for(phi0)
    opt = sgd(0.1)
    step = make_packed_meta_train_step(algo, opt, plane, staleness=cfg)
    state = init_packed_state(opt, plane, phi0, staleness=cfg,
                              clients_per_round=3)
    assert set(state["stale"]) == {"G", "w", "c", "d"}

    rng = np.random.RandomState(3)
    stream = TaskStream(TRAIN, 3, 0.5, 8, 8, rng)
    tbs = stream.take(5)
    # (straggler, fresh, delays) per round — exercises d=1, d=0
    # (immediate join), d=2, and a double arrival in round 5
    sels = [([1], [0, 2], [1]), ([0], [1, 2], [0]), ([2], [0, 1], [2]),
            ([0], [1, 2], [1]), ([1], [0, 2], [0])]

    def rows(tb, phi_tree):
        return [np.asarray(plane.pack(algo.client_grad(
            phi_tree, (tb.support_x[i], tb.support_y[i]),
            (tb.query_x[i], tb.query_y[i]))[0])) for i in range(3)]

    # ---- independent reference: pending-arrival list, no ring buffer
    flat = np.asarray(plane.pack(phi0))
    expected = []
    pending = []   # (arrive_round, weight*gamma^d, gradient row)
    for r, (tb, (strag, fresh, delays)) in enumerate(zip(tbs, sels), start=1):
        g = rows(tb, plane.unpack(jnp.asarray(flat)))
        w = tb.weight / tb.weight.sum()
        agg = [(w[i], g[i]) for i in fresh]
        for j, d in zip(strag, delays):
            if d == 0:
                agg.append((w[j], g[j]))
            else:
                pending.append((r + d, cfg.discount ** d * w[j], g[j]))
        agg += [(pw, pg) for (ar, pw, pg) in pending if ar == r]
        pending = [p for p in pending if p[0] != r]
        tot = sum(pw for pw, _ in agg)
        flat = flat - 0.1 * sum(pw * pg for pw, pg in agg) / tot
        expected.append(flat.copy())

    # ---- the jitted step, same schedule
    for tb, (strag, fresh, delays) in zip(tbs, sels):
        sel = (jnp.asarray(strag, jnp.int32), jnp.asarray(fresh, jnp.int32),
               jnp.asarray(delays, jnp.int32))
        state, _ = step(state,
                        (jnp.asarray(tb.support_x), jnp.asarray(tb.support_y)),
                        (jnp.asarray(tb.query_x), jnp.asarray(tb.query_y)),
                        jnp.asarray(tb.weight), sel)
    np.testing.assert_allclose(np.asarray(state["phi"]), expected[-1],
                               rtol=1e-5, atol=1e-7)


def test_staleness_jitter_runs_through_trainer():
    """The trainer wires the 3-tuple pick through staging/prefetch; a
    jittered run completes and (generically) diverges from fixed-delay."""
    fixed = _fedmeta_history(
        "fomaml", packed=True,
        staleness=StalenessConfig(delay=2, fraction=0.34, discount=0.5))
    jit = _fedmeta_history(
        "fomaml", packed=True, prefetch_depth=2,
        staleness=StalenessConfig(delay=2, fraction=0.34, discount=0.5,
                                  jitter=True))
    assert len(jit) == len(fixed)
    assert jit != fixed
    assert _no_prefetch_threads()


def test_staleness_validation():
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    with pytest.raises(ValueError):
        FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                         support_size=8, query_size=8,
                         staleness=StalenessConfig())       # needs packed
    with pytest.raises(ValueError):
        FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                         support_size=8, query_size=8, packed=True,
                         client_axis="chunked", client_chunk=2,
                         staleness=StalenessConfig())       # needs vmap
    with pytest.raises(ValueError):
        StalenessConfig(delay=0)
    with pytest.raises(ValueError):
        StalenessConfig(fraction=1.0)


# ---- prefetcher lifecycle ----------------------------------------------

def test_step_exception_shuts_down_prefetcher():
    """A step that raises mid-run must not leak the prefetch thread,
    and the rounds completed before the failure must still be flushed
    to history."""
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                          support_size=8, query_size=8, seed=0, packed=True,
                          prefetch_depth=3, flush_every=0)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    real_step, calls = tr._step, []

    def boom(st, *a):
        calls.append(1)
        if len(calls) == 3:
            raise RuntimeError("client exploded")
        return real_step(st, *a)

    tr._step = boom
    with pytest.raises(RuntimeError, match="client exploded"):
        tr.run(state, 10)
    assert _no_prefetch_threads()
    assert [r["round"] for r in tr.history] == [1, 2]  # flushed on exit


def test_producer_exception_propagates_and_joins():
    """An exception raised while sampling/staging on the background
    thread re-raises at the consumer and the thread exits."""
    def produce(k):
        if produce.calls == 1:
            raise ValueError("bad sample")
        produce.calls += 1
        return k

    produce.calls = 0
    pf = Prefetcher(produce, [1, 1, 1], depth=2)
    assert pf.get() == 1
    with pytest.raises(ValueError, match="bad sample"):
        pf.get()
    pf.close()
    assert not pf.alive


def test_engine_defers_flush_to_cadence():
    """flush_every batches history materialization without changing the
    records; flush_every=0 drains only at exit."""
    comm = CommTracker(phi_bytes=1000, clients_per_round=2)
    history, seen = [], []

    def stage(k):
        return jnp.float32(k)

    def step(state, staged):
        return state + 1, {"loss": jnp.float32(state)}

    engine = AsyncRoundEngine(stage=stage, step=step, comm=comm,
                              history=history, flush_every=3)
    engine.run(0, 7, log=lambda rec: seen.append(rec["round"]))
    assert [r["round"] for r in history] == list(range(1, 8))
    assert [r["loss"] for r in history] == [float(i) for i in range(7)]
    assert history[-1]["comm_MB"] == comm.summary()["comm_MB"]
    assert seen == list(range(1, 8))


def test_stack_task_batches_round_axis():
    rng = np.random.RandomState(0)
    stream = TaskStream(TRAIN, 4, 0.5, 8, 8, rng)
    tbs = stream.take(3)
    stacked = stack_task_batches(tbs)
    assert stacked.support_x.shape == (3, 4, 8, 4)
    np.testing.assert_array_equal(stacked.weight[1], tbs[1].weight)
