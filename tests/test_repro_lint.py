"""Invariant-plane tests: the repro-lint rules against fixture snippets
(positive AND negative per rule family), the disable-comment policy,
the baseline contract, and the gate itself — the full repo lints clean.

Fixtures are source *strings* fed to `lint_source`; `relpath` selects
scoping (determinism rules only fire in DET_CRITICAL modules)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint_paths, lint_source
# rule modules register themselves on import; lint_source doesn't
# auto-import them the way lint_paths does
from repro.analysis import (rules_determinism,  # noqa: F401
                            rules_pallas, rules_rng, rules_threading)

REPO = Path(__file__).resolve().parents[1]
DET_PATH = "src/repro/federated/fixture.py"     # determinism-critical
PLAIN_PATH = "src/repro/fixture.py"             # not det-critical


def lint(src, relpath=PLAIN_PATH, rules=None):
    return lint_source(textwrap.dedent(src), relpath=relpath,
                       rules=rules)


def rule_ids(violations):
    return {v.rule for v in violations}


# ---- RNG discipline ------------------------------------------------------

class TestRngRules:
    def test_bare_numpy_draw_flagged(self):
        vs = lint("""
            import numpy as np
            def sample():
                return np.random.permutation(10)
        """, rules=["rng-bare"])
        assert rule_ids(vs) == {"rng-bare"}

    def test_seeded_stream_clean(self):
        vs = lint("""
            import numpy as np
            def sample(seed):
                rng = np.random.RandomState(seed)
                return rng.permutation(10)
        """, rules=["rng-bare", "rng-unseeded"])
        assert not vs

    def test_stdlib_random_flagged(self):
        assert rule_ids(lint("import random\n")) == {"rng-stdlib"}
        assert rule_ids(lint("from random import shuffle\n")) \
            == {"rng-stdlib"}

    def test_numpy_random_import_not_confused_with_stdlib(self):
        assert not lint("import numpy.random\n", rules=["rng-stdlib"])

    def test_unseeded_constructors_flagged(self):
        vs = lint("""
            import numpy as np
            a = np.random.RandomState()
            b = np.random.default_rng()
        """, rules=["rng-unseeded"])
        assert len(vs) == 2 and rule_ids(vs) == {"rng-unseeded"}

    def test_time_derived_seed_flagged(self):
        vs = lint("""
            import time
            import numpy as np
            rng = np.random.RandomState(int(time.time()))
        """, rules=["rng-time-seed"])
        assert rule_ids(vs) == {"rng-time-seed"}

    def test_seed_assignment_from_wallclock_flagged(self):
        vs = lint("""
            import time
            base_seed = int(time.time_ns())
        """, rules=["rng-time-seed"])
        assert rule_ids(vs) == {"rng-time-seed"}

    def test_explicit_seed_clean(self):
        vs = lint("""
            import numpy as np
            rng = np.random.RandomState(1234)
            gen = np.random.default_rng(np.random.SeedSequence(7))
        """, rules=["rng-bare", "rng-unseeded", "rng-time-seed"])
        assert not vs


# ---- Determinism ---------------------------------------------------------

class TestDeterminismRules:
    def test_wallclock_in_critical_module_flagged(self):
        vs = lint("""
            import time
            def stamp():
                return time.time()
        """, relpath=DET_PATH, rules=["det-wallclock"])
        assert rule_ids(vs) == {"det-wallclock"}

    def test_interval_timers_stay_legal(self):
        vs = lint("""
            import time
            def elapsed(t0):
                return time.perf_counter() - t0
            def deadline():
                return time.monotonic() + 5.0
        """, relpath=DET_PATH, rules=["det-wallclock"])
        assert not vs

    def test_wallclock_outside_critical_scope_ignored(self):
        vs = lint("import time\nt = time.time()\n",
                  relpath="benchmarks/bench_fixture.py",
                  rules=["det-wallclock"])
        assert not vs

    def test_serving_plane_is_det_critical(self):
        # the serving engine (DESIGN.md §18) ships under the
        # src/repro/federated/ DET_CRITICAL prefix — pin that a
        # refactor of the scoping can't silently drop it
        vs = lint("import time\nt = time.time()\n",
                  relpath="src/repro/federated/serving.py",
                  rules=["det-wallclock"])
        assert rule_ids(vs) == {"det-wallclock"}
        assert (REPO / "src/repro/federated/serving.py").exists()

    def test_set_iteration_into_accumulator_flagged(self):
        vs = lint("""
            def total(weights):
                acc = 0.0
                for w in set(weights):
                    acc += w
                return acc
        """, relpath=DET_PATH, rules=["det-unordered-iter"])
        assert rule_ids(vs) == {"det-unordered-iter"}

    def test_sum_over_dict_values_flagged(self):
        vs = lint("""
            def total(per_client):
                return sum(per_client.values())
        """, relpath=DET_PATH, rules=["det-unordered-iter"])
        assert rule_ids(vs) == {"det-unordered-iter"}

    def test_sorted_wrapper_clean(self):
        vs = lint("""
            def total(per_client):
                acc = 0.0
                for k in sorted(per_client.keys()):
                    acc += per_client[k]
                return acc + sum(sorted(per_client.values()))
        """, relpath=DET_PATH, rules=["det-unordered-iter"])
        assert not vs


# ---- Thread safety -------------------------------------------------------

_POOL_FIXTURE = """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self._done = 0
            self._t = threading.Thread(target=self._work)

        def _work(self):
            {write}
"""


class TestThreadRules:
    def test_unguarded_worker_write_flagged(self):
        vs = lint(_POOL_FIXTURE.format(write="self._done = 1"),
                  rules=["thread-unguarded-write"])
        assert rule_ids(vs) == {"thread-unguarded-write"}

    def test_locked_worker_write_clean(self):
        write = "with self._lock:\n                self._done = 1"
        vs = lint(_POOL_FIXTURE.format(write=write),
                  rules=["thread-unguarded-write"])
        assert not vs

    def test_worker_class_without_lock_flagged(self):
        vs = lint("""
            import threading
            class P:
                def start(self):
                    threading.Thread(target=self._run).start()
                def _run(self):
                    self._err = ValueError("x")
        """, rules=["thread-unguarded-write"])
        assert rule_ids(vs) == {"thread-unguarded-write"}
        assert "no lock attribute" in vs[0].message

    def test_init_is_exempt(self):
        vs = lint("""
            import threading
            class P:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0
                    self._t = threading.Thread(target=self._work)
                def _work(self):
                    with self._lock:
                        self._n += 1
        """, rules=["thread-unguarded-write"])
        assert not vs

    def test_blocking_call_under_lock_flagged(self):
        vs = lint("""
            import threading
            class R:
                def __init__(self):
                    self._lock = threading.Lock()
                def get(self, ev):
                    with self._lock:
                        ev.wait()
        """, rules=["thread-lock-order"])
        assert rule_ids(vs) == {"thread-lock-order"}

    def test_nested_foreign_lock_flagged(self):
        vs = lint("""
            import threading
            class R:
                def __init__(self):
                    self._lock = threading.Lock()
                def swap(self, other):
                    with self._lock:
                        with other._lock:
                            pass
        """, rules=["thread-lock-order"])
        assert rule_ids(vs) == {"thread-lock-order"}

    def test_wait_outside_lock_clean(self):
        vs = lint("""
            import threading
            class R:
                def __init__(self):
                    self._lock = threading.Lock()
                def get(self, ev):
                    with self._lock:
                        hit = True
                    ev.wait()
                    return hit
        """, rules=["thread-lock-order"])
        assert not vs


# ---- Pallas contracts ----------------------------------------------------

class TestPallasRules:
    def test_index_map_arity_mismatch_flagged(self):
        vs = lint("""
            from jax.experimental import pallas as pl
            def call(x, k, s):
                return pl.pallas_call(
                    k, grid=(4, 4),
                    in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_shape=s)(x)
        """, rules=["pallas-grid-mismatch"])
        assert rule_ids(vs) == {"pallas-grid-mismatch"}

    def test_block_shape_vs_index_rank_flagged(self):
        vs = lint("""
            from jax.experimental import pallas as pl
            def call(x, k, s):
                return pl.pallas_call(
                    k, grid=(4, 4),
                    in_specs=[pl.BlockSpec((1, 8, 128),
                                           lambda i, j: (i, j))],
                    out_shape=s)(x)
        """, rules=["pallas-grid-mismatch"])
        assert rule_ids(vs) == {"pallas-grid-mismatch"}

    def test_defaulted_closure_params_tolerated(self):
        # the `lambda i, j, G=G:` closure-capture idiom from the
        # attention kernels: extra defaulted params are legal
        vs = lint("""
            from jax.experimental import pallas as pl
            def call(x, k, s, G):
                grid = (4, 4)
                return pl.pallas_call(
                    k, grid=grid,
                    in_specs=[pl.BlockSpec(
                        (8, 128), lambda i, j, G=G: (i * G, j))],
                    out_shape=s)(x)
        """, rules=["pallas-grid-mismatch"])
        assert not vs

    def test_aliased_operand_read_after_call_flagged(self):
        vs = lint("""
            from jax.experimental import pallas as pl
            def step(x, k, s):
                out = pl.pallas_call(
                    k, grid=(1,), input_output_aliases={0: 0},
                    out_shape=s)(x)
                return out + x
        """, rules=["pallas-alias-reuse"])
        assert rule_ids(vs) == {"pallas-alias-reuse"}

    def test_aliased_operand_not_reused_clean(self):
        vs = lint("""
            from jax.experimental import pallas as pl
            def step(x, k, s):
                out = pl.pallas_call(
                    k, grid=(1,), input_output_aliases={0: 0},
                    out_shape=s)(x)
                return out
        """, rules=["pallas-alias-reuse"])
        assert not vs

    def test_missing_ref_oracle_flagged(self, tmp_path):
        pkg = tmp_path / "src" / "repro" / "kernels" / "foo"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "ops.py").write_text("def op(x):\n    return x\n")
        report = lint_paths([str(tmp_path)], root=str(tmp_path),
                            rules=["pallas-missing-ref"])
        assert rule_ids(report.violations) == {"pallas-missing-ref"}

    def test_ref_wired_into_ops_clean(self, tmp_path):
        pkg = tmp_path / "src" / "repro" / "kernels" / "foo"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "ref.py").write_text("def op_ref(x):\n    return x\n")
        (pkg / "ops.py").write_text(
            "from repro.kernels.foo import ref\n")
        report = lint_paths([str(tmp_path)], root=str(tmp_path),
                            rules=["pallas-missing-ref"])
        assert report.clean


# ---- Disable comments & baseline ----------------------------------------

class TestDisablePolicy:
    def test_reasoned_disable_suppresses(self):
        vs = lint("import random"
                  "  # repro-lint: disable=rng-stdlib (fixture)\n")
        assert not vs

    def test_standalone_disable_covers_next_line(self):
        vs = lint("# repro-lint: disable=rng-stdlib (fixture)\n"
                  "import random\n")
        assert not vs

    def test_bare_disable_is_itself_a_violation(self):
        # string split so this *test file's* physical line doesn't
        # itself match the directive regex when the repo gate runs
        vs = lint("import random  # repro-lint: "
                  "disable=rng-stdlib\n")
        # reasonless disable: flagged AND the rule still fires
        assert rule_ids(vs) == {"lint-bad-disable", "rng-stdlib"}

    def test_disable_scoped_to_named_rule(self):
        vs = lint("""
            import random  # repro-lint: disable=rng-bare (wrong rule)
        """)
        assert rule_ids(vs) == {"rng-stdlib"}


class TestBaselineAndGate:
    def test_baseline_suppresses_only_outside_strict(self, tmp_path):
        src = tmp_path / "mod.py"
        src.write_text("import random\n")
        bl = tmp_path / "bl.json"
        bl.write_text(json.dumps(
            [{"rule": "rng-stdlib", "path": "mod.py", "line": 1}]))
        lax = lint_paths([str(src)], root=str(tmp_path),
                         baseline=str(bl))
        assert lax.clean and lax.baseline_suppressed == 1
        strict = lint_paths([str(src)], root=str(tmp_path),
                            baseline=str(bl), strict=True)
        assert not strict.clean
        assert "lint-baseline-nonempty" in rule_ids(strict.violations)

    def test_syntax_error_reported_not_raised(self, tmp_path):
        src = tmp_path / "broken.py"
        src.write_text("def f(:\n")
        report = lint_paths([str(src)], root=str(tmp_path))
        assert rule_ids(report.violations) == {"lint-parse-error"}

    def test_full_repo_lints_clean_strict(self):
        """THE gate: whole tree, strict mode, shipped (empty) baseline."""
        paths = [str(REPO / p)
                 for p in ("src", "examples", "benchmarks", "tests")
                 if (REPO / p).is_dir()]
        report = lint_paths(paths, root=str(REPO),
                            baseline=str(REPO /
                                         ".repro-lint-baseline.json"),
                            strict=True)
        assert report.clean, "\n".join(
            v.format() for v in report.violations)
        assert report.files > 50

    def test_shipped_baseline_is_empty(self):
        entries = json.loads(
            (REPO / ".repro-lint-baseline.json").read_text())
        assert entries == []

    def test_cli_entrypoint(self):
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint", "--strict"],
            cwd=str(REPO), env=env, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "0 violation(s)" in proc.stdout
