"""Execution-path equivalence for the meta step.

vmap / scan / chunked / sharded client axes (incl. non-divisor chunk
sizes), the packed parameter plane (xla and pallas_interpret kernels),
and the fused client-plane inner loop (``client_plane=True``, all four
algorithms) must all produce the same φ and the same weighted metrics
after a round. Also covers the fused inner-update plane kernel (values
and custom VJP), the fused outer-Adam and weighted-aggregation kernels
against their jnp oracles, FlatPlane pack/unpack round-tripping, and
bit-identity of the ``adapt`` deployment path between the tree and
packed inner loops. None of this needs the optional `hypothesis`
dependency, so kernel equivalence stays covered even when
test_kernels_meta_update is skipped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_algorithm
from repro.core.fedmeta import (federated_meta_step, init_packed_state,
                                make_packed_meta_train_step)
from repro.kernels.meta_update import ops as mu_ops
from repro.kernels.meta_update.aggregate import (weighted_aggregate_flat,
                                                 weighted_aggregate_ref)
from repro.optim import adam, sgd
from repro.optim.fused_adam import adam_flat_update
from repro.utils.flat import ALIGN, FlatPlane, plane_for


def quad_loss(params, batch):
    return 0.5 * jnp.sum(jnp.square(params["w"] - batch))


def quad_eval(params, batch):
    return quad_loss(params, batch), {"accuracy": jnp.zeros(())}


def _one_device_mesh():
    """shard_map runs unchanged on a 1-device mesh, so the sharded axis
    (padding, psum, local aggregation) is exercised on any host; the CI
    multi-device job re-runs this file with 4 forced host devices."""
    return jax.make_mesh((jax.device_count(),), ("clients",))


def _make_round(rng, algo_name, m=5):
    theta = {"w": jnp.asarray(rng.normal(0, 1, (7,)), jnp.float32),
             "b": jnp.asarray(rng.normal(0, 1, (3,)), jnp.float32)}
    sup = jnp.asarray(rng.normal(0, 1, (m, 7)), jnp.float32)
    qry = jnp.asarray(rng.normal(0, 1, (m, 7)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 3.0, (m,)), jnp.float32)
    algo = make_algorithm(algo_name, quad2_loss, quad2_eval, inner_lr=0.1,
                          inner_steps=2)
    phi = algo.init_state(jax.random.PRNGKey(0), lambda k: theta)
    return algo, phi, sup, qry, w


def quad2_loss(params, batch):
    return (0.5 * jnp.sum(jnp.square(params["w"] - batch))
            + 0.1 * jnp.sum(params["b"] * batch[:3].sum()))


def quad2_eval(params, batch):
    return quad2_loss(params, batch), {"accuracy": jnp.zeros(())}


def _assert_phi_close(out_phi, ref_phi):
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        out_phi, ref_phi)


@pytest.fixture
def round_setup(rng):
    m = 5
    theta = {"w": jnp.asarray(rng.normal(0, 1, (7,)), jnp.float32)}
    sup = jnp.asarray(rng.normal(0, 1, (m, 7)), jnp.float32)
    qry = jnp.asarray(rng.normal(0, 1, (m, 7)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 3.0, (m,)), jnp.float32)
    algo = make_algorithm("meta-sgd", quad_loss, quad_eval, inner_lr=0.1)
    phi = algo.init_state(jax.random.PRNGKey(0), lambda k: theta)
    return algo, phi, sup, qry, w


# chunk sizes: divisor, non-divisor, and chunk > m (single padded chunk)
@pytest.mark.parametrize("axis,chunk", [
    ("scan", None), ("chunked", 1), ("chunked", 2), ("chunked", 3),
    ("chunked", 5), ("chunked", 8),
])
def test_client_axis_equivalence(round_setup, axis, chunk):
    algo, phi, sup, qry, w = round_setup
    opt = adam(1e-2)
    ref_phi, _, ref_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    out_phi, _, out_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis=axis,
        client_chunk=chunk)
    for k in ("theta", "alpha"):
        np.testing.assert_allclose(np.asarray(out_phi[k]["w"]),
                                   np.asarray(ref_phi[k]["w"]),
                                   rtol=1e-5, atol=1e-6)
    # every path reports the same weighted metrics (scan used to take an
    # unweighted mean)
    np.testing.assert_allclose(float(out_met["query_loss"]),
                               float(ref_met["query_loss"]), rtol=1e-5)


@pytest.mark.parametrize("axis,chunk", [
    ("vmap", None), ("scan", None), ("chunked", 2), ("chunked", 3),
])
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_packed_plane_matches_tree(round_setup, axis, chunk, impl):
    algo, phi, sup, qry, w = round_setup
    opt = adam(1e-2)
    ref_phi, _, ref_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(
        algo, opt, plane, client_axis=axis, client_chunk=chunk, impl=impl)
    state, met = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    for k in ("theta", "alpha"):
        np.testing.assert_allclose(np.asarray(out_phi[k]["w"]),
                                   np.asarray(ref_phi[k]["w"]),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(met["query_loss"]),
                               float(ref_met["query_loss"]), rtol=1e-5)


def test_packed_bf16_block_close_to_f32(round_setup):
    """The reduced-precision gradient block tracks the exact pipeline to
    bf16 tolerance (f32 accumulation in the aggregation)."""
    algo, phi, sup, qry, w = round_setup
    opt = adam(1e-2)
    ref_phi, _, _ = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(algo, opt, plane,
                                       block_dtype=jnp.bfloat16)
    state, _ = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    np.testing.assert_allclose(np.asarray(out_phi["theta"]["w"]),
                               np.asarray(ref_phi["theta"]["w"]),
                               rtol=5e-2, atol=5e-3)


def test_packed_plane_non_adam_falls_back(round_setup):
    """Non-Adam outer optimizers run on the plane via the generic path."""
    algo, phi, sup, qry, w = round_setup
    opt = sgd(0.5, momentum=0.9)
    ref_phi, _, _ = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(algo, opt, plane)
    state, _ = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    np.testing.assert_allclose(np.asarray(out_phi["theta"]["w"]),
                               np.asarray(ref_phi["theta"]["w"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("wd", [0.0, 0.01])
def test_fused_adam_kernel_matches_xla(rng, wd):
    N = 2 * ALIGN
    phi = jnp.asarray(rng.normal(0, 1, (N,)), jnp.float32)
    g = jnp.asarray(rng.normal(0, 1, (N,)), jnp.float32)
    m = jnp.asarray(rng.normal(0, 0.1, (N,)), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(0, 0.1, (N,))), jnp.float32)
    step = jnp.asarray(3, jnp.int32)
    ref = adam_flat_update(phi, g, m, v, step, lr=1e-3, wd=wd, impl="xla")
    out = adam_flat_update(phi, g, m, v, step, lr=1e-3, wd=wd,
                           impl="pallas_interpret")
    for r, o, name in zip(ref, out, ("phi", "m", "v", "step")):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-6, atol=1e-7, err_msg=name)


def test_fused_adam_multi_step_bias_correction(rng):
    """Several fused steps track the per-leaf tree Adam exactly."""
    N = ALIGN
    tree = {"a": jnp.asarray(rng.normal(0, 1, (300,)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (20, 30)), jnp.float32)}
    plane = plane_for(tree)
    assert plane.n_padded == N
    opt = adam(3e-3)
    tree_state = opt.init(tree)
    flat = plane.pack(tree)
    m = v = jnp.zeros((N,), jnp.float32)
    step = jnp.zeros((), jnp.int32)
    for t in range(4):
        g_tree = jax.tree.map(
            lambda x: jnp.asarray(np.random.RandomState(t).normal(
                0, 1, x.shape), jnp.float32), tree)
        tree_out, tree_state = opt.update(tree_out if t else tree,
                                          g_tree, tree_state)
        flat, m, v, step = adam_flat_update(
            flat, plane.pack(g_tree), m, v, step, lr=3e-3, impl="xla")
    unpacked = plane.unpack(flat)
    for k in tree:
        np.testing.assert_allclose(np.asarray(unpacked[k]),
                                   np.asarray(tree_out[k]),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("m", [1, 3, 16])
def test_weighted_aggregation_kernel_matches_ref(rng, m):
    N = 2 * ALIGN
    gs = jnp.asarray(rng.normal(0, 1, (m, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, (m,)), jnp.float32)
    ref = weighted_aggregate_ref(gs, w)
    out = weighted_aggregate_flat(gs, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_flat_plane_roundtrip(rng):
    tree = {"w": jnp.asarray(rng.normal(0, 1, (13, 7)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (11,)), jnp.bfloat16),
            "s": jnp.asarray(1.5, jnp.float32)}
    plane = FlatPlane.from_tree(tree)
    assert plane.n_padded % ALIGN == 0
    out = plane.unpack(plane.pack(tree))
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        assert out[k].shape == tree[k].shape
        np.testing.assert_allclose(
            np.asarray(out[k], np.float32), np.asarray(tree[k], np.float32),
            rtol=1e-2 if tree[k].dtype == jnp.bfloat16 else 1e-7)
    # batch pack
    batch = jax.tree.map(lambda x: jnp.stack([x, x + 1]), tree)
    packed = plane.pack_batch(batch)
    assert packed.shape == (2, plane.n_padded)
    np.testing.assert_allclose(np.asarray(packed[0]),
                               np.asarray(plane.pack(tree)), rtol=1e-6)


def test_plane_for_is_cached(rng):
    t1 = {"w": jnp.zeros((4, 4), jnp.float32)}
    t2 = {"w": jnp.ones((4, 4), jnp.float32)}
    assert plane_for(t1) is plane_for(t2)


def test_unpack_ad_matches_unpack_and_grad(rng):
    tree = {"w": jnp.asarray(rng.normal(0, 1, (13, 7)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (11,)), jnp.float32)}
    plane = plane_for(tree)
    flat = plane.pack(tree)
    out = plane.unpack_ad(flat)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(plane.unpack(flat)[k]))

    def f_ad(x):
        t = plane.unpack_ad(x)
        return jnp.sum(jnp.sin(t["w"])) + jnp.sum(t["b"] ** 2)

    def f_plain(x):
        t = plane.unpack(x)
        return jnp.sum(jnp.sin(t["w"])) + jnp.sum(t["b"] ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(f_ad)(flat)),
                               np.asarray(jax.grad(f_plain)(flat)),
                               rtol=1e-6, atol=1e-7)
    # second order (reverse-over-reverse) composes through the custom rule
    def meta(x):
        g = jax.grad(f_ad)(x)
        return jnp.sum(jnp.cos(plane.unpack_ad(x - 0.1 * g)["w"]))

    def meta_plain(x):
        g = jax.grad(f_plain)(x)
        return jnp.sum(jnp.cos(plane.unpack(x - 0.1 * g)["w"]))

    np.testing.assert_allclose(np.asarray(jax.grad(meta)(flat)),
                               np.asarray(jax.grad(meta_plain)(flat)),
                               rtol=1e-5, atol=1e-6)


# ---- fused inner-update plane kernel ------------------------------------

@pytest.mark.parametrize("alpha_kind", ["scalar", "shared", "per_client"])
def test_inner_update_plane_kernel_matches_ref(rng, alpha_kind):
    C, N = 3, 2 * ALIGN
    T = jnp.asarray(rng.normal(0, 1, (C, N)), jnp.float32)
    G = jnp.asarray(rng.normal(0, 1, (C, N)), jnp.float32)
    alpha = {"scalar": 0.05,
             "shared": jnp.asarray(rng.uniform(0, 0.1, (N,)), jnp.float32),
             "per_client": jnp.asarray(rng.uniform(0, 0.1, (C, N)),
                                       jnp.float32)}[alpha_kind]
    ref = mu_ops.inner_update(T, alpha, G, impl="xla")
    out = mu_ops.inner_update(T, alpha, G, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("alpha_kind", ["scalar", "shared", "per_client"])
def test_inner_update_plane_custom_vjp(rng, alpha_kind):
    """The kernel's custom VJP matches autodiff through the jnp oracle —
    this is what second-order MAML/Meta-SGD differentiate through."""
    C, N = 2, ALIGN
    T = jnp.asarray(rng.normal(0, 1, (C, N)), jnp.float32)
    G = jnp.asarray(rng.normal(0, 1, (C, N)), jnp.float32)
    alpha = {"scalar": 0.07,
             "shared": jnp.asarray(rng.uniform(0, 0.1, (N,)), jnp.float32),
             "per_client": jnp.asarray(rng.uniform(0, 0.1, (C, N)),
                                       jnp.float32)}[alpha_kind]

    def make_f(impl):
        def f(*args):
            if alpha_kind == "scalar":
                t, g = args
                return jnp.sum(jnp.sin(
                    mu_ops.inner_update(t, alpha, g, impl=impl)))
            t, a, g = args
            return jnp.sum(jnp.sin(mu_ops.inner_update(t, a, g, impl=impl)))
        return f

    args = (T, G) if alpha_kind == "scalar" else (T, alpha, G)
    argnums = tuple(range(len(args)))
    ref = jax.grad(make_f("xla"), argnums=argnums)(*args)
    out = jax.grad(make_f("pallas_interpret"), argnums=argnums)(*args)
    for r, o in zip(ref, out):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


# ---- client-plane inner loop & sharded axis -----------------------------

ALGOS = ["maml", "fomaml", "meta-sgd", "reptile"]


@pytest.mark.parametrize("algo_name", ALGOS)
@pytest.mark.parametrize("axis,chunk", [
    ("vmap", None), ("scan", None), ("chunked", 2), ("sharded", None),
])
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_client_plane_matches_tree(rng, algo_name, axis, chunk, impl):
    """The fused flat inner loop reproduces the tree round for every
    algorithm, on every client axis, under both kernel impls."""
    algo, phi, sup, qry, w = _make_round(rng, algo_name)
    opt = adam(1e-2)
    ref_phi, _, ref_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(
        algo, opt, plane, client_axis=axis, client_chunk=chunk, impl=impl,
        client_plane=True, mesh=_one_device_mesh())
    state, met = step(init_packed_state(opt, plane, phi), sup, qry, w)
    _assert_phi_close(plane.unpack(state["phi"]), ref_phi)
    np.testing.assert_allclose(float(met["query_loss"]),
                               float(ref_met["query_loss"]), rtol=1e-5)


@pytest.mark.parametrize("pipeline", ["tree", "packed", "packed_plane"])
def test_sharded_axis_matches_vmap(rng, pipeline):
    """client_axis="sharded" (shard_map + psum-reduced partials) produces
    the identical round for every pipeline, including a non-divisor
    client count (zero-weight padding)."""
    m = 5                                    # never divisible by >1 devs
    algo, phi, sup, qry, w = _make_round(rng, "meta-sgd", m=m)
    opt = adam(1e-2)
    ref_phi, _, ref_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    mesh = _one_device_mesh()
    if pipeline == "tree":
        out_phi, _, met = federated_meta_step(
            algo, opt, phi, opt.init(phi), sup, qry, w,
            client_axis="sharded", mesh=mesh)
    else:
        plane = plane_for(phi)
        step = make_packed_meta_train_step(
            algo, opt, plane, client_axis="sharded", mesh=mesh,
            client_plane=(pipeline == "packed_plane"))
        state, met = step(init_packed_state(opt, plane, phi), sup, qry, w)
        out_phi = plane.unpack(state["phi"])
    _assert_phi_close(out_phi, ref_phi)
    np.testing.assert_allclose(float(met["query_loss"]),
                               float(ref_met["query_loss"]), rtol=1e-5)


def test_sharded_with_local_chunking(rng):
    """client_chunk composes with the sharded axis (scan of chunks inside
    each device's shard)."""
    algo, phi, sup, qry, w = _make_round(rng, "fomaml", m=6)
    opt = adam(1e-2)
    ref_phi, _, _ = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(
        algo, opt, plane, client_axis="sharded", client_chunk=2,
        mesh=_one_device_mesh())
    state, _ = step(init_packed_state(opt, plane, phi), sup, qry, w)
    _assert_phi_close(plane.unpack(state["phi"]), ref_phi)


def test_client_plane_bf16_block(rng):
    """The reduced-precision gradient block works through the client
    plane too (G rows cast before aggregation, f32 accumulation)."""
    algo, phi, sup, qry, w = _make_round(rng, "fomaml")
    opt = adam(1e-2)
    ref_phi, _, _ = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(
        algo, opt, plane, client_plane=True, block_dtype=jnp.bfloat16)
    state, _ = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    np.testing.assert_allclose(np.asarray(out_phi["theta"]["w"]),
                               np.asarray(ref_phi["theta"]["w"]),
                               rtol=5e-2, atol=5e-3)


def test_metasgd_integer_seeds_differ():
    """Integer seeds must produce distinct α initializations (the seed
    used to be silently replaced by PRNGKey(0))."""
    algo = make_algorithm("meta-sgd", quad2_loss, quad2_eval, inner_lr=0.1)
    init = lambda k: {"w": jnp.zeros((7,), jnp.float32)}   # noqa: E731
    a0 = algo.init_state(0, init)["alpha"]["w"]
    a1 = algo.init_state(1, init)["alpha"]["w"]
    assert not np.array_equal(np.asarray(a0), np.asarray(a1))
    # int seed k and PRNGKey(k) agree
    a0k = algo.init_state(jax.random.PRNGKey(0), init)["alpha"]["w"]
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a0k))


# ---- deployment path: adapt must be bit-identical -----------------------

@pytest.mark.parametrize("algo_name", ALGOS)
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_adapt_packed_bit_identical(rng, algo_name, impl):
    """paper §3.2: the deployed adapted θ must be bit-identical between
    the tree inner loop and the packed/fused inner loop, for all four
    algorithms, with both inner loops under the same impl. (Comparing
    across impls is 1 ulp apart on CPU: XLA contracts θ − α∘g into an
    FMA whenever it compiles the expression as one program, while the
    eager per-leaf path rounds the product first.)"""
    algo, phi, sup, qry, w = _make_round(rng, algo_name)
    mu_ops.set_default_impl(impl)
    try:
        theta_tree = algo.adapt(phi, sup[0], steps=3)
    finally:
        mu_ops.set_default_impl("xla")
    theta_flat = algo.adapt_packed(phi, sup[0], steps=3, impl=impl)
    for k in theta_tree:
        np.testing.assert_array_equal(np.asarray(theta_tree[k]),
                                      np.asarray(theta_flat[k]),
                                      err_msg=f"{algo_name}/{impl}/{k}")
