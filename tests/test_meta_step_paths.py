"""Execution-path equivalence for the meta step.

vmap / scan / chunked client axes (incl. non-divisor chunk sizes) and
the packed parameter plane (xla and pallas_interpret kernels) must all
produce the same φ and the same weighted metrics after a round. Also
covers the fused outer-Adam and weighted-aggregation kernels against
their jnp oracles, and FlatPlane pack/unpack round-tripping. None of
this needs the optional `hypothesis` dependency, so kernel equivalence
stays covered even when test_kernels_meta_update is skipped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_algorithm
from repro.core.fedmeta import (federated_meta_step, init_packed_state,
                                make_packed_meta_train_step)
from repro.kernels.meta_update.aggregate import (weighted_aggregate_flat,
                                                 weighted_aggregate_ref)
from repro.optim import adam, sgd
from repro.optim.fused_adam import adam_flat_update
from repro.utils.flat import ALIGN, FlatPlane, plane_for


def quad_loss(params, batch):
    return 0.5 * jnp.sum(jnp.square(params["w"] - batch))


def quad_eval(params, batch):
    return quad_loss(params, batch), {"accuracy": jnp.zeros(())}


@pytest.fixture
def round_setup(rng):
    m = 5
    theta = {"w": jnp.asarray(rng.normal(0, 1, (7,)), jnp.float32)}
    sup = jnp.asarray(rng.normal(0, 1, (m, 7)), jnp.float32)
    qry = jnp.asarray(rng.normal(0, 1, (m, 7)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 3.0, (m,)), jnp.float32)
    algo = make_algorithm("meta-sgd", quad_loss, quad_eval, inner_lr=0.1)
    phi = algo.init_state(jax.random.PRNGKey(0), lambda k: theta)
    return algo, phi, sup, qry, w


# chunk sizes: divisor, non-divisor, and chunk > m (single padded chunk)
@pytest.mark.parametrize("axis,chunk", [
    ("scan", None), ("chunked", 1), ("chunked", 2), ("chunked", 3),
    ("chunked", 5), ("chunked", 8),
])
def test_client_axis_equivalence(round_setup, axis, chunk):
    algo, phi, sup, qry, w = round_setup
    opt = adam(1e-2)
    ref_phi, _, ref_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    out_phi, _, out_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis=axis,
        client_chunk=chunk)
    for k in ("theta", "alpha"):
        np.testing.assert_allclose(np.asarray(out_phi[k]["w"]),
                                   np.asarray(ref_phi[k]["w"]),
                                   rtol=1e-5, atol=1e-6)
    # every path reports the same weighted metrics (scan used to take an
    # unweighted mean)
    np.testing.assert_allclose(float(out_met["query_loss"]),
                               float(ref_met["query_loss"]), rtol=1e-5)


@pytest.mark.parametrize("axis,chunk", [
    ("vmap", None), ("scan", None), ("chunked", 2), ("chunked", 3),
])
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_packed_plane_matches_tree(round_setup, axis, chunk, impl):
    algo, phi, sup, qry, w = round_setup
    opt = adam(1e-2)
    ref_phi, _, ref_met = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(
        algo, opt, plane, client_axis=axis, client_chunk=chunk, impl=impl)
    state, met = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    for k in ("theta", "alpha"):
        np.testing.assert_allclose(np.asarray(out_phi[k]["w"]),
                                   np.asarray(ref_phi[k]["w"]),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(met["query_loss"]),
                               float(ref_met["query_loss"]), rtol=1e-5)


def test_packed_bf16_block_close_to_f32(round_setup):
    """The reduced-precision gradient block tracks the exact pipeline to
    bf16 tolerance (f32 accumulation in the aggregation)."""
    algo, phi, sup, qry, w = round_setup
    opt = adam(1e-2)
    ref_phi, _, _ = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(algo, opt, plane,
                                       block_dtype=jnp.bfloat16)
    state, _ = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    np.testing.assert_allclose(np.asarray(out_phi["theta"]["w"]),
                               np.asarray(ref_phi["theta"]["w"]),
                               rtol=5e-2, atol=5e-3)


def test_packed_plane_non_adam_falls_back(round_setup):
    """Non-Adam outer optimizers run on the plane via the generic path."""
    algo, phi, sup, qry, w = round_setup
    opt = sgd(0.5, momentum=0.9)
    ref_phi, _, _ = federated_meta_step(
        algo, opt, phi, opt.init(phi), sup, qry, w, client_axis="vmap")
    plane = plane_for(phi)
    step = make_packed_meta_train_step(algo, opt, plane)
    state, _ = step(init_packed_state(opt, plane, phi), sup, qry, w)
    out_phi = plane.unpack(state["phi"])
    np.testing.assert_allclose(np.asarray(out_phi["theta"]["w"]),
                               np.asarray(ref_phi["theta"]["w"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("wd", [0.0, 0.01])
def test_fused_adam_kernel_matches_xla(rng, wd):
    N = 2 * ALIGN
    phi = jnp.asarray(rng.normal(0, 1, (N,)), jnp.float32)
    g = jnp.asarray(rng.normal(0, 1, (N,)), jnp.float32)
    m = jnp.asarray(rng.normal(0, 0.1, (N,)), jnp.float32)
    v = jnp.asarray(np.abs(rng.normal(0, 0.1, (N,))), jnp.float32)
    step = jnp.asarray(3, jnp.int32)
    ref = adam_flat_update(phi, g, m, v, step, lr=1e-3, wd=wd, impl="xla")
    out = adam_flat_update(phi, g, m, v, step, lr=1e-3, wd=wd,
                           impl="pallas_interpret")
    for r, o, name in zip(ref, out, ("phi", "m", "v", "step")):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-6, atol=1e-7, err_msg=name)


def test_fused_adam_multi_step_bias_correction(rng):
    """Several fused steps track the per-leaf tree Adam exactly."""
    N = ALIGN
    tree = {"a": jnp.asarray(rng.normal(0, 1, (300,)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (20, 30)), jnp.float32)}
    plane = plane_for(tree)
    assert plane.n_padded == N
    opt = adam(3e-3)
    tree_state = opt.init(tree)
    flat = plane.pack(tree)
    m = v = jnp.zeros((N,), jnp.float32)
    step = jnp.zeros((), jnp.int32)
    for t in range(4):
        g_tree = jax.tree.map(
            lambda x: jnp.asarray(np.random.RandomState(t).normal(
                0, 1, x.shape), jnp.float32), tree)
        tree_out, tree_state = opt.update(tree_out if t else tree,
                                          g_tree, tree_state)
        flat, m, v, step = adam_flat_update(
            flat, plane.pack(g_tree), m, v, step, lr=3e-3, impl="xla")
    unpacked = plane.unpack(flat)
    for k in tree:
        np.testing.assert_allclose(np.asarray(unpacked[k]),
                                   np.asarray(tree_out[k]),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("m", [1, 3, 16])
def test_weighted_aggregation_kernel_matches_ref(rng, m):
    N = 2 * ALIGN
    gs = jnp.asarray(rng.normal(0, 1, (m, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, (m,)), jnp.float32)
    ref = weighted_aggregate_ref(gs, w)
    out = weighted_aggregate_flat(gs, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_flat_plane_roundtrip(rng):
    tree = {"w": jnp.asarray(rng.normal(0, 1, (13, 7)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (11,)), jnp.bfloat16),
            "s": jnp.asarray(1.5, jnp.float32)}
    plane = FlatPlane.from_tree(tree)
    assert plane.n_padded % ALIGN == 0
    out = plane.unpack(plane.pack(tree))
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        assert out[k].shape == tree[k].shape
        np.testing.assert_allclose(
            np.asarray(out[k], np.float32), np.asarray(tree[k], np.float32),
            rtol=1e-2 if tree[k].dtype == jnp.bfloat16 else 1e-7)
    # batch pack
    batch = jax.tree.map(lambda x: jnp.stack([x, x + 1]), tree)
    packed = plane.pack_batch(batch)
    assert packed.shape == (2, plane.n_padded)
    np.testing.assert_allclose(np.asarray(packed[0]),
                               np.asarray(plane.pack(tree)), rtol=1e-6)


def test_plane_for_is_cached(rng):
    t1 = {"w": jnp.zeros((4, 4), jnp.float32)}
    t2 = {"w": jnp.ones((4, 4), jnp.float32)}
    assert plane_for(t1) is plane_for(t2)
