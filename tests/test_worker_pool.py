"""Worker pool + population plane invariants (DESIGN.md §15, tentpole
part 2).

Covers: the shared retry loop, pool lifecycle with K>1 workers (no
leaked threads, whatever fails), `WorkerPoolError` semantics mirroring
`PrefetchError` (label + chained cause), per-task timeouts and
dead-pool detection; the deterministic unreliability model and the
deadline/over-selection arithmetic against hand-computed arrivals;
circuit-breaker state transitions; and the trainer end to end — comm
accounting (download charges selected, upload charges arrived), the
all-failed guard skip, bare-pool bit-identity, prefetched-population
determinism, and checkpoint/resume carrying breaker + participation.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import latest_step
from repro.core import classification_loss, make_algorithm
from repro.data.federated import assemble_task_batch
from repro.federated.async_engine import (PREFETCH_THREAD_NAME,
                                          WORKER_THREAD_NAME, WorkerPool,
                                          WorkerPoolError, call_with_retry)
from repro.federated.comm import CommTracker
from repro.federated.population import (CircuitBreaker, UnreliabilityConfig,
                                        plan_round)
from repro.federated.server import FederatedTrainer
from repro.optim import adam
from tests.test_async_engine import EVAL, TRAIN, _TinyModel

LOSS_FN, EVAL_FN = classification_loss(_TinyModel.apply)


def _no_pool_threads():
    return all(not t.name.startswith((WORKER_THREAD_NAME,
                                      PREFETCH_THREAD_NAME))
               for t in threading.enumerate())


def _pop_trainer(**kw):
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    return FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                            support_size=8, query_size=8, seed=0,
                            packed=True, **kw)


# ---- the shared retry loop ----------------------------------------------

def test_call_with_retry():
    err, out, n = call_with_retry(lambda: 42, max_retries=3, backoff=0)
    assert (err, out, n) == (None, 42, 1)

    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    err, out, n = call_with_retry(flaky, max_retries=5, backoff=0)
    assert (err, out, n) == (None, "ok", 3)

    boom = RuntimeError("permanent")

    def dead():
        raise boom

    err, out, n = call_with_retry(dead, max_retries=2, backoff=0)
    assert err is boom and out is None and n == 3

    stop = threading.Event()
    stop.set()
    assert call_with_retry(lambda: 1, max_retries=0, backoff=0,
                           stop=stop) is None


def test_call_with_retry_backoff_schedule(monkeypatch):
    """backoff · 2^attempt between attempts — the PR-6 schedule."""
    import repro.federated.async_engine as ae
    sleeps = []
    monkeypatch.setattr(ae.time, "sleep", sleeps.append)

    def dead():
        raise OSError("x")

    call_with_retry(dead, max_retries=3, backoff=0.1)
    assert sleeps == pytest.approx([0.1, 0.2, 0.4])


# ---- worker pool lifecycle ----------------------------------------------

def test_pool_map_in_order_k4():
    pool = WorkerPool(lambda i: i * i, workers=4)
    try:
        assert pool.map(range(20)) == [i * i for i in range(20)]
        assert pool.map([]) == []
    finally:
        pool.close()
    assert not pool.alive
    assert _no_pool_threads()


def test_pool_transient_failure_retries():
    calls, lock = {}, threading.Lock()

    def flaky(i):
        with lock:
            calls[i] = calls.get(i, 0) + 1
            if i == 2 and calls[i] < 3:
                raise OSError("transient")
        return i

    pool = WorkerPool(flaky, workers=2, max_retries=3, retry_backoff=0.0)
    try:
        assert pool.map([1, 2, 3]) == [1, 2, 3]
    finally:
        pool.close()
    assert calls[2] == 3
    assert _no_pool_threads()


def test_pool_permanent_failure_names_label_and_chains_cause():
    def dead(i):
        if i == 7:
            raise ValueError("shard corrupt")
        return i

    pool = WorkerPool(dead, workers=3, max_retries=1, retry_backoff=0.0)
    try:
        with pytest.raises(WorkerPoolError, match=r"7.*round 5") as ei:
            pool.map([1, 7, 3], label="round 5")
        assert isinstance(ei.value.__cause__, ValueError)
        assert "shard corrupt" in str(ei.value.__cause__)
        assert "2 attempt(s)" in str(ei.value)
    finally:
        pool.close()
    assert _no_pool_threads()


def test_pool_task_timeout():
    release = threading.Event()

    def stuck(i):
        if i == 1:
            release.wait(5.0)
        return i

    pool = WorkerPool(stuck, workers=2, task_timeout=0.2)
    try:
        with pytest.raises(WorkerPoolError, match="task timeout"):
            pool.map([0, 1], label="round 9")
    finally:
        release.set()
        pool.close()
    assert _no_pool_threads()


def test_pool_dead_pool_raises_instead_of_deadlocking():
    pool = WorkerPool(lambda i: i, workers=2)
    pool.close()                      # workers are gone
    with pytest.raises(WorkerPoolError):
        pool.map([1])
    assert _no_pool_threads()


# ---- deterministic unreliability ----------------------------------------

def test_unreliability_deterministic_and_validated():
    u = UnreliabilityConfig(fail_rate=0.5, chronic_frac=0.2, seed=1)
    assert u.draw(3, 7) == u.draw(3, 7)
    assert u.client_profile(3) == u.client_profile(3)
    # chronic clients fail every round
    chronics = [c for c in range(200) if u.client_profile(c)[0]]
    assert 10 < len(chronics) < 80          # ~20% of 200
    for c in chronics[:5]:
        assert all(u.draw(c, r)[0] for r in range(5))
    # all-fail / never-fail extremes
    dead = UnreliabilityConfig(fail_rate=1.0, seed=2)
    assert all(dead.draw(c, 0)[0] for c in range(20))
    alive = UnreliabilityConfig(fail_rate=0.0, seed=2)
    assert not any(alive.draw(c, 0)[0] for c in range(20))
    with pytest.raises(ValueError, match="fail_rate"):
        UnreliabilityConfig(fail_rate=1.5)
    with pytest.raises(ValueError, match="chronic_frac"):
        UnreliabilityConfig(chronic_frac=-0.1)
    # disjoint per-(client, round) streams actually vary latency
    lats = {round(u.draw(0, r)[1], 6) for r in range(5)}
    assert len(lats) == 5


def test_plan_round_hand_check():
    """m=4, over_select=0.25 → 5 candidates; stub latencies/failures →
    hand-computed arrived/late/surplus sets and renormalized weights."""
    class Stub:
        # candidate: (failed, latency)
        table = {10: (False, 3.0), 11: (True, 1.0), 12: (False, 1.0),
                 13: (False, 9.0), 14: (False, 2.0)}

        def draw(self, client, round_):
            return self.table[client]

    plan = plan_round([10, 11, 12, 13, 14], 1, Stub(), deadline=5.0, m=4)
    np.testing.assert_array_equal(plan.candidates, [10, 11, 12, 13, 14])
    # on time: 12 (1.0) < 14 (2.0) < 10 (3.0); 13 misses the 5.0
    # deadline; 11 failed outright — 3 arrivals, shortfall of 1
    np.testing.assert_array_equal(plan.arrived, [12, 14, 10])
    np.testing.assert_array_equal(plan.failed, [11])
    np.testing.assert_array_equal(plan.late, [13])
    np.testing.assert_array_equal(plan.surplus, [])
    assert np.isnan(plan.latencies[1]) and plan.latencies[2] == 1.0

    # surplus: everyone on time, first m in latency order win the race
    class Fast:
        def draw(self, client, round_):
            return (False, float(client))

    p2 = plan_round([5, 4, 3, 2, 1], 1, Fast(), deadline=None, m=4)
    np.testing.assert_array_equal(p2.arrived, [1, 2, 3, 4])
    np.testing.assert_array_equal(p2.surplus, [5])

    # latency tie: candidate position breaks it
    class Tie:
        def draw(self, client, round_):
            return (False, 1.0)

    p3 = plan_round([9, 8, 7], 1, Tie(), deadline=2.0, m=2)
    np.testing.assert_array_equal(p3.arrived, [9, 8])
    np.testing.assert_array_equal(p3.surplus, [7])

    # no unreliability model: candidate order, zero latency
    p4 = plan_round([6, 5, 4], 1, None, deadline=1.0, m=2)
    np.testing.assert_array_equal(p4.arrived, [6, 5])
    np.testing.assert_array_equal(p4.surplus, [4])
    np.testing.assert_array_equal(p4.failed, [])

    # the shortfall renormalizes over arrivals via the assembler
    from repro.data.federated import ClientData
    rng = np.random.RandomState(0)
    shards = [ClientData(rng.normal(0, 1, (n, 4)).astype(np.float32),
                         rng.randint(0, 2, n).astype(np.int64))
              for n in (12, 18, 30)]
    tb = assemble_task_batch(shards, 4, 0.5, 8, 8,
                             np.random.RandomState(1))
    np.testing.assert_allclose(tb.weight, [0.2, 0.3, 0.5, 0.0], rtol=1e-6)


# ---- circuit breaker ----------------------------------------------------

def test_circuit_breaker_transitions():
    b = CircuitBreaker(threshold=3, cooldown=4)
    assert b.state(5, 1) == "closed"
    b.record_failure(5, 1)
    b.record_failure(5, 2)
    assert b.state(5, 3) == "closed" and b.blocked(3) == set()
    b.record_failure(5, 3)                      # third consecutive: trip
    assert b.state(5, 4) == "open"
    assert b.blocked(4) == {5} and b.blocked(7) == {5}
    assert b.state(5, 8) == "half_open" and b.blocked(8) == set()
    # half-open trial fails once -> re-trips immediately
    b.record_failure(5, 8)
    assert b.state(5, 9) == "open" and b.blocked(9) == {5}
    # cooldown again, then the trial succeeds -> fully closed
    assert b.state(5, 13) == "half_open"
    b.record_success(5)
    assert b.state(5, 13) == "closed"
    b.record_failure(5, 14)
    b.record_failure(5, 15)
    assert b.state(5, 16) == "closed"           # count was reset

    # a success between failures resets the consecutive count
    b2 = CircuitBreaker(threshold=2, cooldown=3)
    b2.record_failure(1, 1)
    b2.record_success(1)
    b2.record_failure(1, 2)
    assert b2.state(1, 3) == "closed"

    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)


def test_circuit_breaker_state_roundtrip():
    b = CircuitBreaker(threshold=2, cooldown=5)
    b.record_failure(3, 1)
    b.record_failure(3, 2)                      # open
    b.record_failure(8, 2)
    d = b.state_dict()
    b2 = CircuitBreaker(threshold=2, cooldown=5)
    b2.load_state(d)
    for r in range(3, 10):
        assert b2.state(3, r) == b.state(3, r)
        assert b2.blocked(r) == b.blocked(r)
    assert b2.state_dict() == d


# ---- comm accounting ----------------------------------------------------

def test_comm_tracker_participation_accounting():
    c = CommTracker(phi_bytes=100, clients_per_round=4,
                    flops_per_client=10.0)
    c.record_round(5, 3, 0)       # round 1: 5 selected, 3 arrived
    c.record_round(5, 4, 1)       # round 2 (staged ahead of tick)
    c.tick()
    assert c.download_bytes == 5 * 100        # ALL selected pay download
    assert c.upload_bytes == 3 * 100          # only ARRIVED upload
    assert c.total_flops == 3 * 10.0
    s1 = c.summary_at(1)
    assert (s1["selected"], s1["arrived"], s1["quarantined"]) == (5, 3, 0)
    assert s1["selected_total"] == 5 and s1["arrived_total"] == 3
    assert all(isinstance(s1[k], int) for k in
               ("selected", "arrived", "quarantined", "selected_total",
                "arrived_total"))
    c.tick()
    s2 = c.summary_at(2)
    assert (s2["selected"], s2["arrived"], s2["quarantined"]) == (5, 4, 1)
    assert s2["selected_total"] == 10 and s2["arrived_total"] == 7
    assert s2["download_MB"] == pytest.approx(10 * 100 / 1e6)
    assert s2["upload_MB"] == pytest.approx(7 * 100 / 1e6)
    # empty participation = the classical fixed-cohort accounting
    c0 = CommTracker(phi_bytes=100, clients_per_round=4)
    c0.tick(3)
    assert c0.download_bytes == c0.upload_bytes == 3 * 4 * 100
    assert "selected" not in c0.summary()


# ---- trainer end to end -------------------------------------------------

def test_population_trainer_end_to_end():
    """Over-selection + deadline + unreliability through the pool: the
    aggregator auto-upgrades, every history record carries int
    participation fields, download strictly exceeds upload, and no
    threads leak."""
    tr = _pop_trainer(
        unreliability=UnreliabilityConfig(fail_rate=0.3, latency_mean=1.0,
                                          seed=7),
        over_select=0.5, round_deadline=2.0, pool_workers=2)
    assert tr.aggregator == "masked_mean" and tr.guard
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr.run(state, 6, eval_every=3, eval_clients=EVAL)
    assert _no_pool_threads()
    assert len(tr.history) == 6
    for rec in tr.history:
        assert rec["selected"] == 6            # m·(1+0.5)
        assert isinstance(rec["arrived"], int) and 0 <= rec["arrived"] <= 4
        assert isinstance(rec["quarantined"], int)
    assert tr.comm.download_bytes > tr.comm.upload_bytes
    assert tr.history[-1]["selected_total"] == 36
    assert tr.history[-1]["arrived_total"] == \
        sum(r["arrived"] for r in tr.history)


def test_population_prefetched_history_deterministic():
    """Arrival outcomes are pure functions of (seed, client, round) —
    a prefetched population run equals the synchronous one."""
    def run(**kw):
        tr = _pop_trainer(
            unreliability=UnreliabilityConfig(fail_rate=0.3, seed=7),
            over_select=0.5, round_deadline=2.0, **kw)
        state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
        tr.run(state, 6, eval_every=3, eval_clients=EVAL)
        return tr.history

    sync = run()
    piped = run(prefetch_depth=2, flush_every=2)
    pooled = run(pool_workers=3)
    assert piped == sync and pooled == sync
    assert _no_pool_threads()


def test_bare_pool_is_bit_identical():
    """pool_workers>0 with every population knob off only pre-warms the
    registry cache — the history must equal the no-pool run exactly."""
    def run(**kw):
        tr = _pop_trainer(**kw)
        state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
        tr.run(state, 5, eval_every=0)
        return tr.history

    assert run(pool_workers=3) == run()
    assert _no_pool_threads()


def test_all_failed_round_guard_skips():
    """fail_rate=1.0: every candidate fails, the probe-shaped batch has
    all-zero weights, and the guard skips every round (φ unchanged)."""
    tr = _pop_trainer(
        unreliability=UnreliabilityConfig(fail_rate=1.0, seed=3),
        over_select=0.25)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    phi0 = np.asarray(state["phi"]).copy()
    out = tr.run(state, 3, eval_every=0)
    assert all(rec["skipped"] == 1.0 for rec in tr.history)
    assert all(rec["arrived"] == 0 for rec in tr.history)
    np.testing.assert_array_equal(np.asarray(out["phi"]), phi0)
    # ...and chronic failures trip the breaker into quarantine
    assert len(tr._breaker.blocked(4)) > 0


def test_population_validation():
    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    with pytest.raises(ValueError, match="population"):
        FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                         support_size=8, query_size=8, over_select=0.5)
    with pytest.raises(ValueError, match="over_select"):
        _pop_trainer(over_select=-0.1)
    with pytest.raises(ValueError, match="fuse_rounds"):
        _pop_trainer(over_select=0.5, fuse_rounds=2)
    with pytest.raises(ValueError, match="staleness"):
        from repro.federated.async_engine import StalenessConfig
        _pop_trainer(over_select=0.5, staleness=StalenessConfig())


def _same_history(a, b):
    """Record-for-record equality, NaN-aware (guard-skipped rounds
    carry NaN metrics, and nan != nan would fail dict equality)."""
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        if set(ra) != set(rb):
            return False
        for k in ra:
            va, vb = ra[k], rb[k]
            if isinstance(va, float) and isinstance(vb, float) and \
                    np.isnan(va) and np.isnan(vb):
                continue
            if va != vb:
                return False
    return True


def test_population_checkpoint_resume_bit_identical(tmp_path):
    """Kill-and-resume under the population plane: breaker state and
    the participation log ride the checkpoint, so the stitched history
    (including comm fields) equals the uninterrupted run's."""
    kw = dict(unreliability=UnreliabilityConfig(fail_rate=0.4, seed=11),
              over_select=0.5, round_deadline=2.0,
              breaker_threshold=2, breaker_cooldown=3)

    def full():
        tr = _pop_trainer(**kw)
        state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
        tr.run(state, 9, eval_every=3, eval_clients=EVAL)
        return tr.history

    tr1 = _pop_trainer(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                       **kw)
    state = tr1.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr1.run(state, 6, eval_every=3, eval_clients=EVAL)
    assert latest_step(str(tmp_path)) == 6

    tr2 = _pop_trainer(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                       **kw)
    tr2.init(jax.random.PRNGKey(0), _TinyModel.init)
    state2, start = tr2.resume()
    assert start == 6
    assert len(tr2.comm.participation) == 6    # restored with the rngs
    tr2.run(state2, 9, eval_every=3, eval_clients=EVAL, start_round=start)
    assert _same_history(tr2.history, full())
    assert _no_pool_threads()


def test_step_exception_shuts_down_pool_k3():
    """A step raising mid-run with K=3 pool workers + prefetch must
    leak neither pool nor prefetch threads (the PR-6 leak test,
    extended to K>1)."""
    tr = _pop_trainer(
        unreliability=UnreliabilityConfig(fail_rate=0.2, seed=5),
        over_select=0.5, pool_workers=3, prefetch_depth=2, flush_every=0)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    real_step, calls = tr._step, []

    def boom(st, *a):
        calls.append(1)
        if len(calls) == 3:
            raise RuntimeError("client exploded")
        return real_step(st, *a)

    tr._step = boom
    with pytest.raises(RuntimeError, match="client exploded"):
        tr.run(state, 8)
    assert _no_pool_threads()
    assert tr._pool is None
    assert [r["round"] for r in tr.history] == [1, 2]


def test_pool_shard_failure_surfaces_at_run(monkeypatch):
    """A registry whose shard synthesis fails permanently surfaces as
    WorkerPoolError naming the round — and still shuts the pool down."""
    class Exploding:
        def __len__(self):
            return len(TRAIN)

        def __getitem__(self, i):
            raise OSError("disk gone")

    algo = make_algorithm("fomaml", LOSS_FN, EVAL_FN, inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(1e-3), Exploding(), 4,
                          support_frac=0.5, support_size=8, query_size=8,
                          seed=0, packed=True, over_select=0.25,
                          pool_workers=2, pool_retries=1)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    with pytest.raises(WorkerPoolError, match="round 1") as ei:
        tr.run(state, 3)
    assert isinstance(ei.value.__cause__, OSError)
    assert _no_pool_threads()
