"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np

# `propsweep` re-exports hypothesis when installed, else a
# deterministic seeded sweep — no skip either way.
from propsweep import given, settings, st

from repro.core import make_algorithm, softmax_xent
from repro.core.fedmeta import federated_meta_step
from repro.kernels.attention.ref import mha_reference
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential
from repro.optim import sgd


def quad_loss(params, batch):
    return 0.5 * jnp.sum(jnp.square(params["w"] - batch))


def quad_eval(params, batch):
    return quad_loss(params, batch), {"accuracy": jnp.zeros(())}


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(1e-3, 0.5),
       dim=st.integers(1, 16))
def test_maml_reduces_to_fomaml_as_second_order_vanishes(seed, alpha, dim):
    """For the quadratic task, MAML grad = (1-α)·FOMAML grad exactly —
    the second-order correction is the (1-α) Jacobian factor."""
    r = np.random.RandomState(seed)
    theta = {"w": jnp.asarray(r.normal(0, 1, (dim,)), jnp.float32)}
    c_s = jnp.asarray(r.normal(0, 1, (dim,)), jnp.float32)
    c_q = jnp.asarray(r.normal(0, 1, (dim,)), jnp.float32)
    maml = make_algorithm("maml", quad_loss, quad_eval, inner_lr=alpha)
    fo = make_algorithm("fomaml", quad_loss, quad_eval, inner_lr=alpha)
    g2, _ = maml.client_grad({"theta": theta}, c_s, c_q)
    g1, _ = fo.client_grad({"theta": theta}, c_s, c_q)
    np.testing.assert_allclose(np.asarray(g2["theta"]["w"]),
                               (1 - alpha) * np.asarray(g1["theta"]["w"]),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 6))
def test_aggregation_weight_scale_invariance(seed, m):
    """Scaling all aggregation weights by a constant leaves the round
    unchanged (weights normalize)."""
    r = np.random.RandomState(seed)
    theta = {"w": jnp.asarray(r.normal(0, 1, (4,)), jnp.float32)}
    sup = jnp.asarray(r.normal(0, 1, (m, 4)), jnp.float32)
    qry = jnp.asarray(r.normal(0, 1, (m, 4)), jnp.float32)
    w = jnp.asarray(r.uniform(0.1, 5.0, (m,)), jnp.float32)
    algo = make_algorithm("maml", quad_loss, quad_eval, inner_lr=0.1)
    opt = sgd(1.0)
    phi = {"theta": theta}
    a, _, _ = federated_meta_step(algo, opt, phi, opt.init(phi), sup, qry, w)
    b, _, _ = federated_meta_step(algo, opt, phi, opt.init(phi), sup, qry,
                                  w * 7.3)
    np.testing.assert_allclose(np.asarray(a["theta"]["w"]),
                               np.asarray(b["theta"]["w"]), rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       L=st.sampled_from([16, 32, 64]),
       chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunking_invariance(seed, L, chunk):
    """Chunked SSD equals the sequential recurrence for any chunk size."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.normal(0, 1, (1, L, 2, 4)), jnp.float32)
    dt = jnp.asarray(np.log1p(np.exp(r.normal(-1, 0.5, (1, L, 2)))),
                     jnp.float32)
    A = jnp.asarray(-np.exp(r.normal(0, 0.3, (2,))), jnp.float32)
    Bm = jnp.asarray(r.normal(0, 1, (1, L, 8)), jnp.float32)
    Cm = jnp.asarray(r.normal(0, 1, (1, L, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ssd_chunked_ref(x, dt, A, Bm, Cm, chunk)),
        np.asarray(ssd_sequential(x, dt, A, Bm, Cm)),
        rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 3.0))
def test_attention_softmax_shift_invariance(seed, scale):
    """Adding a constant to all key vectors along a rank-1 direction of q
    leaves attention unchanged iff it shifts all scores equally — check
    softmax shift invariance via explicit score offset."""
    r = np.random.RandomState(seed)
    B, L, H, hd = 1, 8, 2, 16
    q = jnp.asarray(r.normal(0, 1, (B, L, H, hd)), jnp.float32)
    k = jnp.asarray(r.normal(0, 1, (B, L, H, hd)), jnp.float32)
    v = jnp.asarray(r.normal(0, 1, (B, L, H, hd)), jnp.float32)
    base = mha_reference(q, k, v, causal=True)
    # scaling q and k jointly by s and 1/s preserves scores
    out = mha_reference(q * scale, k / scale, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), C=st.integers(2, 32))
def test_xent_uniform_logits(seed, C):
    """Cross entropy of uniform logits is log C for any labels."""
    r = np.random.RandomState(seed)
    labels = jnp.asarray(r.randint(0, C, (7,)), jnp.int32)
    logits = jnp.zeros((7, C), jnp.float32)
    np.testing.assert_allclose(float(softmax_xent(logits, labels)),
                               np.log(C), rtol=1e-5)
