"""Mamba2 SSD kernel: chunked (ref + Pallas) vs exact sequential
recurrence, decode-step consistency, dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd_chunked, ssd_decode_step
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential


def _mk(rng, B, L, nh, hp, N, dtype=jnp.float32):
    x = jnp.asarray(rng.normal(0, 1, (B, L, nh, hp)), dtype)
    dt = jnp.asarray(np.log1p(np.exp(rng.normal(-1, 0.5, (B, L, nh)))),
                     jnp.float32)
    A = jnp.asarray(-np.exp(rng.normal(0, 0.3, (nh,))), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (B, L, N)), dtype)
    Cm = jnp.asarray(rng.normal(0, 1, (B, L, N)), dtype)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("B,L,nh,hp,N,chunk", [
    (1, 32, 2, 8, 16, 8),
    (2, 64, 3, 8, 16, 16),
    (1, 128, 4, 16, 32, 64),
    (2, 64, 1, 4, 8, 64),      # single chunk
])
def test_chunked_matches_sequential(rng, B, L, nh, hp, N, chunk):
    x, dt, A, Bm, Cm = _mk(rng, B, L, nh, hp, N)
    exact = ssd_sequential(x, dt, A, Bm, Cm)
    chunked = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk)
    pallas = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk,
                         impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(exact),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(exact),
                               rtol=1e-4, atol=1e-4)


def test_final_state_matches_decode_chain(rng):
    """Prefill's final state must continue exactly into decode steps."""
    B, L, nh, hp, N, chunk = 1, 32, 2, 4, 8, 8
    x, dt, A, Bm, Cm = _mk(rng, B, L + 4, nh, hp, N)
    y_pre, state = ssd_chunked(x[:, :L], dt[:, :L], A, Bm[:, :L], Cm[:, :L],
                               chunk=chunk, impl="pallas_interpret",
                               return_final_state=True)
    y_ref = ssd_sequential(x, dt, A, Bm, Cm)
    for t in range(L, L + 4):
        y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                     Bm[:, t], Cm[:, t])
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_ref[:, t]),
                                   rtol=1e-4, atol=1e-4)


def test_bf16_inputs(rng):
    x, dt, A, Bm, Cm = _mk(rng, 1, 64, 2, 8, 16, dtype=jnp.bfloat16)
    exact = ssd_sequential(x, dt, A, Bm, Cm)
    pallas = ssd_chunked(x, dt, A, Bm, Cm, chunk=16, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(pallas, np.float32),
                               np.asarray(exact, np.float32),
                               rtol=5e-2, atol=5e-2)
