"""Fused meta-update kernel vs oracle, incl. hypothesis property sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# `propsweep` re-exports hypothesis when installed, else a
# deterministic seeded sweep — no skip either way.
from propsweep import given, settings, st

from repro.kernels.meta_update.ops import meta_update


def _tree(rng, shapes, dtype=jnp.float32):
    return {f"p{i}": jnp.asarray(rng.normal(0, 1, s), dtype)
            for i, s in enumerate(shapes)}


@pytest.mark.parametrize("shapes", [
    [(7,)], [(128, 128)], [(3, 5, 7), (2,), (1000,)],
])
@pytest.mark.parametrize("scalar_alpha", [True, False])
def test_fused_matches_ref(rng, shapes, scalar_alpha):
    theta = _tree(rng, shapes)
    g = _tree(rng, shapes)
    alpha = 0.01 if scalar_alpha else jax.tree.map(
        lambda x: jnp.abs(x) * 0.01, _tree(rng, shapes))
    ref = meta_update(theta, alpha, g, impl="xla")
    out = meta_update(theta, alpha, g, impl="pallas_interpret")
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 4000), lr=st.floats(1e-5, 1.0),
       seed=st.integers(0, 2**31 - 1))
def test_property_inner_update(n, lr, seed):
    """θ' = θ − lr·g exactly, for arbitrary sizes/lrs (property test)."""
    r = np.random.RandomState(seed)
    theta = {"w": jnp.asarray(r.normal(0, 1, (n,)), jnp.float32)}
    g = {"w": jnp.asarray(r.normal(0, 1, (n,)), jnp.float32)}
    out = meta_update(theta, lr, g, impl="pallas_interpret")
    expect = np.asarray(theta["w"]) - lr * np.asarray(g["w"])
    np.testing.assert_allclose(np.asarray(out["w"]), expect,
                               rtol=1e-5, atol=1e-5)
