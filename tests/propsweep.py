"""Property-test shim: real `hypothesis` when installed, otherwise a
deterministic seeded sweep with the same decorator surface.

The three property suites (`test_properties`, `test_privacy`,
`test_kernels_meta_update`) used to `importorskip("hypothesis")` —
three perennial tier-1 skips on hosts without the optional dep. This
module removes them: `from propsweep import given, settings, st`
re-exports hypothesis verbatim when it imports, and otherwise runs the
test body over `max_examples` deterministically-drawn example dicts
(boundary values first, then draws seeded by the test's qualname —
stable across runs and processes, no shared RNG state).

The fallback supports exactly the strategy surface the suites use:
`st.integers(lo, hi)`, `st.floats(lo, hi)`, `st.sampled_from(seq)`.
It does not shrink failures — the failing example dict is in the
assertion message instead. CI exercises both paths (the tier1 job
installs hypothesis; tier1-no-hypothesis runs this fallback).
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:        # deterministic fallback sweep
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """draw(rng, i): example i of a sweep — boundaries first."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: np.random.RandomState, i: int):
            return self._draw(rng, i)

    class st:  # noqa: N801  (mirrors `hypothesis.strategies` alias)
        @staticmethod
        def integers(lo: int, hi: int):
            def draw(rng, i):
                if i == 0:
                    return lo
                if i == 1:
                    return hi
                return int(rng.randint(lo, hi + 1, dtype=np.int64))
            return _Strategy(draw)

        @staticmethod
        def floats(lo: float, hi: float):
            def draw(rng, i):
                if i == 0:
                    return float(lo)
                if i == 1:
                    return float(hi)
                return float(lo + (hi - lo) * rng.random_sample())
            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            elems = list(seq)

            def draw(rng, i):
                if i < len(elems):
                    return elems[i]
                return elems[int(rng.randint(len(elems)))]
            return _Strategy(draw)

    def settings(*, max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._propsweep_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def sweep(*args, **kwargs):
                n = getattr(sweep, "_propsweep_max_examples", 20)
                base = zlib.adler32(fn.__qualname__.encode()) & 0x7FFFFFFF
                for i in range(n):
                    rng = np.random.RandomState((base + i) % 2**31)
                    example = {name: s.draw(rng, i)
                               for name, s in strategies.items()}
                    try:
                        fn(*args, **example, **kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"propsweep example {i}/{n} failed: "
                            f"{example}") from e
                return None

            # hide the strategy params from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            sweep.__signature__ = sig.replace(parameters=params)
            return sweep
        return deco
