"""Crash-safe checkpointing + resumable runs (DESIGN.md §14).

Contracts: (1) `checkpoint/io.py` round-trips real trainer state —
including numpy scalar manifest values (the np.int64 msgpack
regression), bf16 moment buffers, and nested sequences; writes are
atomic (temp names + os.replace, orphaned temps invisible to
discovery) with keep-last-k retention. (2) A killed run resumed from
its latest checkpoint reproduces the uninterrupted run's history
record-for-record — across prefetched and staleness+faults
configurations. (3) The Prefetcher survives transient staging
failures (bounded retry-with-backoff) and surfaces permanent ones as
`PrefetchError` naming the failing round, with the producer traceback
chained — never a silent deadlock.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (latest_step, load_pytree,
                                 load_server_state, save_pytree,
                                 save_server_state)
from repro.core import classification_loss, make_algorithm
from repro.federated.async_engine import (PrefetchError, Prefetcher,
                                          StalenessConfig)
from repro.federated.faults import FaultConfig
from repro.federated.server import FederatedTrainer
from repro.optim import adam
from tests.test_async_engine import (EVAL, TRAIN, _TinyModel,
                                     _no_prefetch_threads)


def _make_trainer(tmp_path=None, **kw):
    algo = make_algorithm("fomaml", *classification_loss(_TinyModel.apply),
                          inner_lr=0.05)
    if tmp_path is not None:
        kw.setdefault("checkpoint_dir", str(tmp_path))
        kw.setdefault("checkpoint_every", 3)
    return FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                            support_size=8, query_size=8, seed=0,
                            packed=True, **kw)


# ---- io round-trip ------------------------------------------------------

def test_numpy_scalar_manifest_roundtrip(tmp_path):
    """np.int64 / np.float32 scalars in the manifest (msgpack can't pack
    numpy scalar types) must round-trip exactly as python scalars."""
    tree = {"round": np.int64(7), "acc": np.float32(0.25),
            "flag": np.bool_(True), "n": 3, "name": "run",
            "nested": ("a", np.int32(2), [np.float64(1.5)])}
    path = str(tmp_path / "ck")
    save_pytree(path, tree)
    back = load_pytree(path)
    assert back["round"] == 7 and isinstance(back["round"], int)
    assert back["acc"] == pytest.approx(0.25)
    assert back["flag"] is True
    assert back["nested"] == ("a", 2, [1.5])


def test_real_trainer_state_roundtrip(tmp_path):
    """The regression that motivated _to_packable: a REAL checkpoint
    payload (train state with np scalar history values, rng tuples,
    comm counters) must survive save/load bit-exactly."""
    tr = _make_trainer(tmp_path)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    state = tr.run(state, 3, eval_every=3, eval_clients=EVAL)
    # history records hold floats; inject np scalars like older numpy
    # call sites produce them
    tr.history[0]["np_step"] = np.int64(1)
    path = tr.save_checkpoint(state, 3)
    assert path.endswith("step_00000003")
    payload = load_server_state(str(tmp_path))
    assert payload["round"] == 3
    assert payload["history"][0]["np_step"] == 1
    np.testing.assert_array_equal(np.asarray(payload["state"]["phi"]),
                                  np.asarray(state["phi"]))
    np.testing.assert_array_equal(
        np.asarray(payload["state"]["opt"]["m"]),
        np.asarray(state["opt"]["m"]))
    assert int(payload["state"]["opt"]["step"]) == 3


def test_bf16_arrays_roundtrip(tmp_path):
    x = jnp.arange(8, dtype=jnp.bfloat16) * jnp.bfloat16(0.5)
    path = str(tmp_path / "bf")
    save_pytree(path, {"x": x, "y": jnp.ones((3,), jnp.float32)})
    back = load_pytree(path)
    assert back["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["x"], np.float32),
                                  np.asarray(x, np.float32))


def test_orphaned_tmp_files_invisible(tmp_path):
    """A crash mid-save leaves temp names / an orphaned npz; discovery
    must see only complete checkpoints (manifest written last)."""
    save_server_state(str(tmp_path), 2, {"a": jnp.ones((2,))})
    # simulate a crash between payload and manifest of step 5
    (tmp_path / "step_00000005.npz").write_bytes(b"torn")
    (tmp_path / "step_00000007.tmp.manifest").write_bytes(b"half")
    (tmp_path / "step_00000007.tmp.npz").write_bytes(b"half")
    assert latest_step(str(tmp_path)) == 2
    back = load_server_state(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(back["a"]), np.ones((2,)))


def test_keep_last_k_retention(tmp_path):
    for step in range(1, 6):
        save_server_state(str(tmp_path), step, {"s": jnp.float32(step)},
                          keep_last=2)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000004.manifest", "step_00000004.npz",
                     "step_00000005.manifest", "step_00000005.npz"]
    assert latest_step(str(tmp_path)) == 5


# ---- kill-and-resume: bit-identical history -----------------------------

CONFIGS = {
    "plain": {},
    "prefetch": dict(prefetch_depth=2, flush_every=2),
    "stale+faults": dict(
        staleness=StalenessConfig(delay=1, fraction=0.34, discount=0.5),
        faults=FaultConfig(dropout=0.25, byzantine=0.25, seed=5),
        aggregator="trimmed", trim=1),
}


@pytest.mark.parametrize("cfg", CONFIGS, ids=list(CONFIGS))
def test_kill_and_resume_bit_identical(tmp_path, cfg):
    """Run 9 rounds uninterrupted; separately run 6 rounds (checkpoints
    at 3 and 6), 'crash', resume in a FRESH trainer and continue to 9.
    The stitched history must equal the uninterrupted one record for
    record — task stream, fault/straggler picks, comm counters and eval
    fields all restored."""
    kw = CONFIGS[cfg]

    def full():
        tr = _make_trainer(**kw)
        state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
        tr.run(state, 9, eval_every=3, eval_clients=EVAL)
        return tr.history

    tr1 = _make_trainer(tmp_path, **kw)
    state = tr1.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr1.run(state, 6, eval_every=3, eval_clients=EVAL)
    assert latest_step(str(tmp_path)) == 6      # and the process "dies"

    tr2 = _make_trainer(tmp_path, **kw)
    tr2.init(jax.random.PRNGKey(0), _TinyModel.init)
    state2, start = tr2.resume()
    assert start == 6
    assert [r["round"] for r in tr2.history] == list(range(1, 7))
    tr2.run(state2, 9, eval_every=3, eval_clients=EVAL, start_round=start)
    assert tr2.history == full()
    assert _no_prefetch_threads()


def test_resume_from_earlier_step(tmp_path):
    """Resuming from a non-latest checkpoint replays the tail
    identically — checkpoints are not just crash recovery but seekable
    run points."""
    tr1 = _make_trainer(tmp_path)
    state = tr1.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr1.run(state, 6)
    reference = list(tr1.history)

    tr2 = _make_trainer(tmp_path)
    tr2.init(jax.random.PRNGKey(0), _TinyModel.init)
    state2, start = tr2.resume(step=3)
    assert start == 3
    tr2.run(state2, 6, start_round=start)
    assert tr2.history == reference


def test_checkpoint_payload_has_partial_history(tmp_path):
    """The engine flushes pending metrics before the checkpoint hook:
    a payload at round 3 of a flush_every=0 run still carries rounds
    1..3 (a killed pipelined run never loses flushed-at-ckpt rounds)."""
    tr = _make_trainer(tmp_path, prefetch_depth=2, flush_every=0)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr.run(state, 4)
    payload = load_server_state(str(tmp_path), 3)
    assert [r["round"] for r in payload["history"]] == [1, 2, 3]


# ---- prefetcher retry ---------------------------------------------------

def test_prefetcher_transient_failure_retries():
    calls = []

    def produce(k):
        calls.append(k)
        if len(calls) in (2, 3):        # block 2 fails twice, then lands
            raise OSError("transient")
        return ("block", len(calls))

    pf = Prefetcher(produce, [1, 1, 1], depth=1, max_retries=2,
                    retry_backoff=0.001)
    try:
        assert pf.get() == ("block", 1)
        assert pf.get() == ("block", 4)     # two failed attempts absorbed
        assert pf.get() == ("block", 5)
    finally:
        pf.close()
    assert not pf.alive


def test_prefetcher_retries_exhausted_names_round():
    def produce(k):
        raise OSError("disk on fire")

    pf = Prefetcher(produce, [1, 1], depth=1, max_retries=1,
                    retry_backoff=0.001, first_round=7)
    with pytest.raises(PrefetchError, match=r"round 7.*max_retries=1"
                                            r".*disk on fire") as ei:
        pf.get()
    pf.close()
    assert isinstance(ei.value.__cause__, OSError)   # traceback survives


def test_prefetcher_dead_producer_get_raises():
    """get() beyond what the producer staged must raise, not deadlock."""
    pf = Prefetcher(lambda k: k, [1], depth=1)
    assert pf.get() == 1
    with pytest.raises(PrefetchError, match="without staging"):
        pf.get()
    pf.close()


def test_trainer_retry_is_deterministic(monkeypatch):
    """A transient staging failure under prefetch_retries must leave the
    run bit-identical to a clean one: staging snapshots/restores the
    seeded streams around the failed attempt, so the retry draws the
    SAME tasks the synchronous run would have."""
    clean = _make_trainer()
    state = clean.init(jax.random.PRNGKey(0), _TinyModel.init)
    clean.run(state, 6)

    tr = _make_trainer(prefetch_depth=2, prefetch_retries=2)
    orig = FederatedTrainer._stage_block
    fails = {"left": 1}

    def flaky(self, stream, dp, k, round_):
        args = orig(self, stream, dp, k, round_)  # consume draws, THEN
        if fails["left"]:                  # fail: the restore path must
            fails["left"] -= 1             # undo the stream advance
            raise OSError("transient staging failure")
        return args

    monkeypatch.setattr(FederatedTrainer, "_stage_block", flaky)
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    tr.run(state, 6)
    assert fails["left"] == 0              # the failure actually fired
    assert tr.history == clean.history
    assert _no_prefetch_threads()
