"""Scenario-plane regression tests (DESIGN.md §13).

Covers the PR-5 invariants: the local-head label view (order/size
preservation → identical sampling streams), the per-method θ-size comm
asymmetry in the recommend scenario, the LM personalization path through
`run_comparison`, fairness-metric math against hand-computed values, and
the committed artifacts' fairness blocks re-derived exactly from their
stored per-client accuracies (mirroring the PR-4 depth-0 stability pin).
"""
import json
import os

import numpy as np
import pytest

from repro.data.federated import ClientData, FederatedDataset
from repro.data.lm_tasks import make_lm_clients
from repro.data.synth_recommend import (localize_clients, localize_recommend,
                                        make_recommend)
from repro.federated.experiment import (ExperimentPlan, default_plan,
                                        fairness_stats, run_comparison)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                       "experiments")


# ---- local-head label view ----------------------------------------------

def test_localize_clients_mapping():
    ds = make_recommend(num_clients=12, num_services=60, ctx_dim=4,
                        mean_records=40, seed=0)
    local = localize_clients(ds.clients, head_size=40)
    assert len(local) == len(ds.clients)
    for orig, loc in zip(ds.clients, local):
        # order, features and sizes preserved => identical seeded streams
        assert loc.n == orig.n
        np.testing.assert_array_equal(loc.x, orig.x)
        services = np.unique(orig.y)
        # local ids are the rank of the service in the client's sorted
        # service set — a bijection the client can build offline
        np.testing.assert_array_equal(
            np.unique(loc.y), np.arange(len(services)))
        np.testing.assert_array_equal(services[loc.y], orig.y)

    view = localize_recommend(ds, head_size=40)
    assert view.num_classes == 40
    for a, b in zip(view.clients, local):
        np.testing.assert_array_equal(a.y, b.y)


def test_localize_clients_rejects_small_head():
    c = ClientData(np.zeros((5, 2), np.float32),
                   np.array([0, 3, 7, 9, 11], np.int32))
    with pytest.raises(ValueError, match="head_size"):
        localize_clients([c], head_size=3)


def test_dataset_view_contract():
    ds = FederatedDataset([ClientData(np.zeros((3, 2), np.float32),
                                      np.array([0, 1, 0], np.int64))], 2)
    v = ds.view(lambda c: ClientData(c.x, 1 - c.y), num_classes=2)
    np.testing.assert_array_equal(v.clients[0].y, [1, 0, 1])
    with pytest.raises(ValueError, match="preserve client sizes"):
        ds.view(lambda c: ClientData(c.x[:1], c.y[:1]))


# ---- recommend scenario through the plane -------------------------------

def test_recommend_comparison_theta_asymmetry(tmp_path):
    """FedMeta trains the 40-way local head, FedAvg the global-service
    head — the per-method CommTracker must charge different θ bytes, and
    every artifact block must carry fairness fields."""
    plan = default_plan("recommend", methods=("fedavg", "fomaml"),
                        rounds=3, eval_every=1, num_clients=24)
    out = run_comparison(plan, out_dir=str(tmp_path), log=None)
    fa, fm = out["methods"]["fedavg"], out["methods"]["fomaml"]
    # size asymmetry: global head strictly bigger than the local head
    assert fa["comm"]["phi_MB"] > fm["comm"]["phi_MB"]
    # same rounds, same per-round client count -> FedAvg pays strictly
    # more bytes (both legs scale with its bigger θ)
    assert fa["comm"]["rounds"] == fm["comm"]["rounds"] == 3
    assert fa["comm"]["download_MB"] > fm["comm"]["download_MB"]
    assert fa["comm"]["upload_MB"] > fm["comm"]["upload_MB"]
    # per-round history carries the per-method size too
    assert fa["history"][0]["phi_MB"] == pytest.approx(
        fa["comm"]["phi_MB"])
    # Table-3 metrics: the recommend loss adds top-4 to every record
    assert "top4" in fm["history"][0]
    # fairness block on every method, serialized into the artifact
    with open(out["path"]) as f:
        loaded = json.load(f)
    for m in plan.methods:
        fair = loaded["methods"][m]["fairness"]
        assert set(fair) == {"mean", "variance", "deciles", "worst10_mean",
                             "num_clients"}
        assert len(fair["deciles"]) == 9
    assert loaded["plan"]["local_head"] == 40


def test_recommend_views_share_sampling_stream():
    """The FedMeta (local-label) and FedAvg (global-label) views must
    consume identical task streams: same client picks, same support and
    query EXAMPLES every round (only the label space differs)."""
    from repro.data.federated import TaskStream
    ds = make_recommend(num_clients=16, num_services=60, ctx_dim=4,
                        mean_records=40, seed=0)
    local = localize_clients(ds.clients, head_size=40)
    a = TaskStream(ds.clients, 4, 0.5, 8, 8, np.random.RandomState(7))
    b = TaskStream(local, 4, 0.5, 8, 8, np.random.RandomState(7))
    for _ in range(3):
        ta, tb = a.next(), b.next()
        np.testing.assert_array_equal(ta.support_x, tb.support_x)
        np.testing.assert_array_equal(ta.query_x, tb.query_x)
        np.testing.assert_array_equal(ta.weight, tb.weight)
        np.testing.assert_array_equal(ta.query_count, tb.query_count)


# ---- LM personalization through the plane -------------------------------

def test_make_lm_clients_interface():
    ds = make_lm_clients(num_clients=6, mean_seqs=5, seq_len=8, vocab=32,
                         seed=0)
    assert ds.num_classes == 32 and len(ds.clients) == 6
    for c in ds.clients:
        assert c.x.dtype == np.int32 and c.x.shape[1] == 8
        assert (c.x >= 0).all() and (c.x < 32).all()
        assert 5 <= c.n < 10
        np.testing.assert_array_equal(c.y, c.x[:, -1])
    # deterministic under seed
    ds2 = make_lm_clients(num_clients=6, mean_seqs=5, seq_len=8, vocab=32,
                          seed=0)
    np.testing.assert_array_equal(ds.clients[3].x, ds2.clients[3].x)


def test_lm_comparison_smoke():
    """The LM personalization path end-to-end: dialect corpora through
    `run_comparison` on a reduced assigned LM arch, FedMeta vs FedAvg on
    the shared stream, next-token eval accuracy in history."""
    plan = default_plan("lm", methods=("fedavg", "fomaml"), rounds=2,
                        eval_every=1, num_clients=12)
    out = run_comparison(plan, save=False)
    for m in ("fedavg", "fomaml"):
        hist = out["methods"][m]["history"]
        assert len(hist) == 2
        assert all("eval_acc" in r and "comm_MB" in r for r in hist)
        assert np.isfinite(out["methods"][m]["test_loss"])
        assert "fairness" in out["methods"][m]
    # one LM shipped both ways for both methods — same θ size here
    assert out["methods"]["fedavg"]["comm"]["phi_MB"] == pytest.approx(
        out["methods"]["fomaml"]["comm"]["phi_MB"])


# ---- fairness metrics ----------------------------------------------------

def test_fairness_stats_hand_computed():
    accs = [0.1, 0.9, 0.5, 0.3, 0.7, 0.2, 0.8, 0.4, 0.6, 1.0]
    f = fairness_stats(accs)
    a = np.sort(np.asarray(accs))
    assert f["mean"] == pytest.approx(0.55)
    assert f["variance"] == pytest.approx(np.var(accs))
    # worst 10% of 10 clients = the single worst client
    assert f["worst10_mean"] == pytest.approx(0.1)
    assert f["num_clients"] == 10
    assert f["deciles"] == [pytest.approx(np.percentile(a, p))
                            for p in range(10, 100, 10)]
    # non-divisible pool: worst-10% of 25 clients = worst ceil(2.5)=3
    accs25 = [i / 25 for i in range(25)]
    assert fairness_stats(accs25)["worst10_mean"] == pytest.approx(1 / 25)
    # degenerate single client
    g = fairness_stats([0.5])
    assert g["worst10_mean"] == 0.5 and g["variance"] == 0.0


def test_committed_artifacts_fairness_stable():
    """Every committed comparison artifact carries fairness blocks that
    re-derive EXACTLY from its stored per-client accuracies — the same
    pure-function pin as the PR-4 comm-to-target stability test."""
    # *_compare.json only — the §14 robustness artifact has its own
    # schema (pinned in tests/test_faults.py)
    paths = [os.path.join(ART_DIR, f) for f in sorted(os.listdir(ART_DIR))
             if f.endswith("_compare.json")]
    assert paths, "committed experiment artifacts are missing"
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        for m, accs in rec["per_client"].items():
            assert rec["methods"][m]["fairness"] == fairness_stats(accs), \
                (path, m)


def test_committed_recommend_artifact_bytes_advantage():
    """The acceptance pin: the committed recommend artifact shows
    FedMeta strictly below FedAvg on bytes-to-target under the
    per-method θ-size accounting."""
    paths = ["recommend_compare.json"]
    for name in paths:
        path = os.path.join(ART_DIR, name)
        assert os.path.exists(path), "committed recommend artifact missing"
        with open(path) as f:
            rec = json.load(f)
        table = rec["comm_to_target"]
        fa = table["fedavg"] or rec["methods"]["fedavg"]["comm"]
        for m, row in table.items():
            if m in ("fedavg", "fedavg(meta)") or row is None:
                continue
            assert row["comm_MB"] < fa["comm_MB"], (name, m)
        # the size asymmetry is recorded per method
        assert (rec["methods"]["fedavg"]["comm"]["phi_MB"] >
                rec["methods"]["maml"]["comm"]["phi_MB"])
