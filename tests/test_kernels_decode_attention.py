"""Flash-decode Pallas kernel vs oracle: shapes, dtypes, GQA packing,
ragged kv lengths, and equivalence with full-attention decode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ref import mha_reference
from repro.kernels.decode_attention.ops import decode_attention


def _mk(rng, B, C, H, Kv, hd, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(0, 1, (B, H, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, C, Kv, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, C, Kv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,C,H,Kv,hd,bk", [
    (2, 256, 8, 2, 64, 64),     # GQA 4:1
    (1, 512, 4, 4, 128, 128),   # MHA
    (3, 128, 6, 1, 32, 128),    # MQA
])
def test_flash_decode_matches_ref(rng, B, C, H, Kv, hd, bk):
    q, k, v = _mk(rng, B, C, H, Kv, hd)
    kvl = jnp.asarray(rng.randint(1, C + 1, (B,)), jnp.int32)
    a = decode_attention(q, k, v, kvl, impl="xla")
    b = decode_attention(q, k, v, kvl, impl="pallas_interpret", block_k=bk)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-5,
                               atol=2e-5)


def test_flash_decode_bf16(rng):
    q, k, v = _mk(rng, 2, 256, 4, 2, 64, dtype=jnp.bfloat16)
    kvl = jnp.asarray([256, 100], jnp.int32)
    a = decode_attention(q, k, v, kvl, impl="xla")
    b = decode_attention(q, k, v, kvl, impl="pallas_interpret", block_k=64)
    np.testing.assert_allclose(np.asarray(b, np.float32),
                               np.asarray(a, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_flash_decode_equals_last_row_of_full_attention(rng):
    """Decoding token L against a length-L cache equals row L of full
    causal attention."""
    B, L, H, Kv, hd = 1, 128, 4, 2, 32
    q, k, v = _mk(rng, B, L, H, Kv, hd)
    full = mha_reference(q[:, None][:, :, :, :].reshape(B, 1, H, hd),
                         k, v, causal=True, q_offset=L - 1)
    dec = decode_attention(q, k, v, jnp.asarray([L]),
                           impl="pallas_interpret", block_k=64)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 0]),
                               rtol=2e-5, atol=2e-5)
