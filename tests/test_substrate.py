"""Substrate tests: optimizers, checkpoint round-trip, data generators,
communication accounting, FedAvg invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.data import (make_femnist, make_recommend, make_sent140,
                        make_shakespeare, sample_task_batch)
from repro.data.federated import support_query_split
from repro.federated.comm import CommTracker
from repro.federated.fedavg import FedAvgTrainer
from repro.optim import adam, clip_by_global_norm, sgd
from repro.utils.pytree import tree_bytes, tree_size


def test_sgd_step():
    opt = sgd(0.5)
    p = {"w": jnp.asarray([2.0, -2.0])}
    g = {"w": jnp.asarray([1.0, 1.0])}
    p2, st = opt.update(p, g, opt.init(p))
    np.testing.assert_allclose(np.asarray(p2["w"]), [1.5, -2.5])
    assert int(st["step"]) == 1


def test_adam_converges_quadratic():
    opt = adam(0.1)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(p)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(300):
        g = jax.grad(loss)(p)
        p, st = opt.update(p, g, st)
    assert float(loss(p)) < 1e-4


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    total = jnp.sqrt(clipped["a"] ** 2 + clipped["b"] ** 2)
    np.testing.assert_allclose(np.asarray(total), [1.0], rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32), "d": 7},
            "e": [jnp.ones((2,)), {"f": jnp.zeros((1,))}],
            "scalar": 3.5}
    path = os.path.join(tmp_path, "ckpt")
    save_pytree(path, tree)
    back = load_pytree(path)
    assert back["b"]["d"] == 7 and back["scalar"] == 3.5
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["e"][0]),
                                  np.asarray(tree["e"][0]))
    assert isinstance(back["e"], list)


@pytest.mark.parametrize("maker,kw", [
    (make_femnist, dict(num_clients=8, mean_samples=20)),
    (make_shakespeare, dict(num_clients=4, mean_samples=40)),
    (make_sent140, dict(num_clients=8)),
    (make_recommend, dict(num_clients=5, mean_records=50)),
])
def test_dataset_structure(maker, kw):
    ds = maker(seed=3, **kw)
    stats = ds.stats()
    assert stats["clients"] == kw.get("num_clients")
    assert stats["samples"] > 0
    for c in ds.clients:
        assert c.x.shape[0] == c.y.shape[0]
        assert c.y.min() >= 0 and c.y.max() < ds.num_classes
    tr, va, te = ds.split_clients(seed=0)
    assert len(tr) + len(va) + len(te) == stats["clients"]


def test_support_query_disjoint(rng):
    ds = make_sent140(num_clients=3, seed=1)
    c = ds.clients[0]
    (sx, sy), (qx, qy) = support_query_split(c, 0.3, rng)
    assert len(sy) + len(qy) == c.n
    # disjointness by index construction: totals preserved
    assert len(sy) == max(1, min(c.n - 1, int(round(0.3 * c.n))))


def test_task_batch_shapes_and_weights(rng):
    ds = make_femnist(num_clients=6, mean_samples=20, seed=0)
    tb = sample_task_batch(ds.clients, 4, 0.2, 8, 8, rng)
    assert tb.support_x.shape == (4, 8, 28, 28)
    assert tb.query_x.shape == (4, 8, 28, 28)
    np.testing.assert_allclose(tb.weight.sum(), 1.0, rtol=1e-5)
    assert (tb.weight > 0).all()


def test_comm_tracker_accounting():
    phi = {"theta": {"w": jnp.zeros((1000,), jnp.float32)}}
    t = CommTracker.for_state(phi, clients_per_round=10,
                              flops_per_client=1e6)
    assert t.phi_bytes == 4000
    t.tick(5)
    assert t.download_bytes == 5 * 10 * 4000
    assert t.total_bytes == 2 * 5 * 10 * 4000
    assert t.total_flops == 5 * 10 * 1e6


def test_comm_tracker_block_dtype_upload():
    """With a bf16 gradient block, the upload leg counts 2 bytes/param
    (what is actually transmitted) while the download leg stays f32."""
    phi = {"theta": {"w": jnp.zeros((1000,), jnp.float32)}}
    t = CommTracker.for_state(phi, clients_per_round=10,
                              block_dtype=jnp.bfloat16)
    t.tick(1)
    assert t.download_bytes == 10 * 4000
    assert t.upload_bytes == 10 * 2000
    assert t.total_bytes == 10 * 6000
    # f32 block (or no block dtype): symmetric, as before
    t2 = CommTracker.for_state(phi, clients_per_round=10)
    t2.tick(1)
    assert t2.upload_bytes == t2.download_bytes == 10 * 4000


def test_fedavg_identical_clients_fixed_point(rng):
    """If every client holds the same data, one FedAvg round equals plain
    local training (aggregation of identical models is identity)."""
    x = jnp.asarray(rng.normal(0, 1, (4, 3)), jnp.float32)
    y = jnp.asarray(rng.randint(0, 2, (4,)), jnp.int32)

    def apply_fn(p, x):
        return x @ p["w"]

    def loss_fn(p, batch):
        bx, by = batch
        logits = apply_fn(p, bx)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, by[:, None], 1))

    eval_fn = lambda p, b: (loss_fn(p, b), {"accuracy": jnp.zeros(())})
    fa = FedAvgTrainer(loss_fn, eval_fn, local_lr=0.1, local_steps=2,
                       local_optimizer="sgd")
    theta = {"w": jnp.asarray(rng.normal(0, 1, (3, 2)), jnp.float32)}
    single = fa.local_train(
        theta, jax.tree.map(lambda a: jnp.stack([a, a]), (x, y)))
    m = 3
    batch = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None, None],
                                   (m, 2) + a.shape), (x, y))
    avg = fa.round_step({"theta": theta}, batch)["theta"]
    np.testing.assert_allclose(np.asarray(avg["w"]), np.asarray(single["w"]),
                               rtol=1e-5, atol=1e-6)


def test_tree_utils():
    t = {"a": jnp.zeros((3, 4), jnp.float32), "b": jnp.zeros((5,), jnp.bfloat16)}
    assert tree_size(t) == 17
    assert tree_bytes(t) == 3 * 4 * 4 + 5 * 2
