"""Config exactness vs the assigned architecture table, sharding-rule
invariants, EP-MoE numerical equivalence, and launch-path lowering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs, reduced_config

# (layers, d_model, heads, kv, d_ff, vocab, experts, topk) per assignment
ASSIGNED = {
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768, 8, 2),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155, 0, 0),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206, 0, 0),
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400, 160, 6),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064, 0, 0),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280, 0, 0),
    "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936, 0, 0),
    "smollm-360m": (32, 960, 15, 5, 2560, 49152, 0, 0),
    "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000, 0, 0),
}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_config_exactness(arch):
    cfg = get_config(arch)
    L, d, H, Kv, ff, V, E, K = ASSIGNED[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == Kv
    assert cfg.d_ff == ff and cfg.vocab_size == V
    assert cfg.num_experts == E and cfg.num_experts_per_tok == K
    assert cfg.source, "every config must cite its source"


def test_assigned_extras():
    assert get_config("deepseek-v2-236b").kv_lora_rank == 512
    assert get_config("deepseek-v2-236b").num_shared_experts == 2
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("mixtral-8x22b").sliding_window is not None
    assert get_config("qwen2-vl-7b").mrope and get_config("qwen2-vl-7b").qkv_bias
    assert get_config("qwen2.5-3b").qkv_bias
    assert get_config("nemotron-4-340b").mlp_act == "relu2"
    assert get_config("seamless-m4t-medium").is_encoder_decoder
    jamba = get_config("jamba-v0.1-52b")
    assert jamba.layer_pattern.count("attn") == 1    # 1:7 interleave
    assert len(jamba.layer_pattern) == 8


def test_sharding_rules_divisibility_guard():
    """Dims that don't divide the mesh axis stay replicated."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import param_pspecs
    mesh = make_host_mesh(1, 1)
    params = {"wq": jnp.zeros((960, 960)),       # 960 % 1 == 0 -> sharded
              "embed": jnp.zeros((7, 960))}
    specs = param_pspecs(params, mesh)
    assert specs["wq"] is not None
    # on a 1-device mesh everything divides; use a synthetic big mesh via
    # dryrun tests instead — here just verify structure matches
    assert set(specs.keys()) == {"wq", "embed"}


def test_ep_moe_matches_tp_single_device(rng):
    """On a 1-device mesh the EP all_to_all is the identity, so EP and TP
    MoE must agree numerically (same routing, same capacity)."""
    from repro.launch.mesh import make_host_mesh
    from repro.models import moe as tp_moe
    from repro.models.layers import Rng
    from repro.sharding.ep_moe import ep_moe_apply
    cfg = dataclasses.replace(
        reduced_config(get_config("mixtral-8x22b")), num_shared_experts=0)
    mesh = make_host_mesh(1, 1)
    params = tp_moe.moe_init(Rng(jax.random.PRNGKey(0)), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 8, cfg.d_model)), jnp.float32)
    y_tp, _aux = tp_moe.moe_apply(params, cfg, x)
    y_ep = ep_moe_apply(params, cfg, x, mesh)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_tp),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_launch_path_lowers_on_host_mesh(kind, rng):
    """input_specs + step builders lower on the 1-device host mesh for a
    reduced config (guards the production launch path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (input_specs, make_decode_step,
                                    make_prefill_step, make_train_step)
    from repro.models import init_lm
    from repro.sharding.rules import param_pspecs, state_pspecs
    cfg = reduced_config(get_config("qwen2.5-3b"))
    mesh = make_host_mesh(1, 1)
    shape = dataclasses.replace(
        INPUT_SHAPES[{"train": "train_4k", "prefill": "prefill_32k",
                      "decode": "decode_32k"}[kind]],
        seq_len=32, global_batch=4,
        **({"clients_per_round": 2, "seqs_per_client": 2}
           if kind == "train" else {}))
    nm = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    with mesh:
        if kind == "train":
            step, init_state, _, _ = make_train_step(cfg)
            state = jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0)))
            spec = input_specs(cfg, shape, mesh)
            pspec = param_pspecs(state["phi"]["theta"], mesh)
            fn = jax.jit(step, in_shardings=(
                nm(state_pspecs(state, pspec, mesh)), nm(spec["pspec"])))
            lowered = fn.lower(state, spec["batch"])
        elif kind == "prefill":
            spec = input_specs(cfg, shape, mesh)
            params = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
            fn = jax.jit(make_prefill_step(cfg),
                         in_shardings=(nm(param_pspecs(params, mesh)),
                                       nm(spec["pspec"])))
            lowered = fn.lower(params, spec["batch"])
        else:
            spec = input_specs(cfg, shape, mesh)
            scfg = spec["serving_cfg"]
            params = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), scfg))
            fn = jax.jit(make_decode_step(scfg),
                         in_shardings=(nm(param_pspecs(params, mesh)),
                                       nm(spec["pspec"]["cache"]),
                                       nm(spec["pspec"]["tokens"])))
            lowered = fn.lower(params, spec["batch"]["cache"],
                               spec["batch"]["tokens"])
        assert lowered.compile() is not None
