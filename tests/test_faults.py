"""Failure-plane invariants (DESIGN.md §14).

Three contracts ship here: (1) robust aggregators on the (m, N) client
plane — dropout-masked renormalization preserves the effective weight
sum, norm screening clips outlier rows, and the trimmed-mean kernel
matches its NaN-sort oracle on adversarial rows; (2) the non-finite
guard is a bitwise no-op on clean runs for all four FedMeta algorithms
and skips poisoned rounds leaving φ and the optimizer untouched; (3)
disabled fault injection (zero fractions, aggregator="mean") leaves
every pipeline bit-identical to a config-free run. Plus a pin on the
committed robustness artifact: robust aggregators must hold accuracy at
the Byzantine fraction where plain mean collapses.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classification_loss, make_algorithm
from repro.federated.async_engine import StalenessConfig
from repro.federated.faults import FaultConfig, apply_faults
from repro.federated.server import FederatedTrainer
from repro.kernels.meta_update.aggregate import (
    masked_mean_flat, masked_mean_ref, row_liveness, screened_aggregate_flat,
    screened_aggregate_ref, screened_weights, trimmed_mean_flat,
    trimmed_mean_ref, weighted_aggregate_ref)
from repro.kernels.meta_update.ops import AGGREGATORS, robust_aggregate
from repro.optim import adam
from tests.test_async_engine import (ALGOS, EVAL, TRAIN, _TinyModel,
                                     _fedmeta_history)

N = 2048   # kernel plane width (multiple of 8*128)


def _block(m=8, seed=0, n=N):
    rng = np.random.RandomState(seed)
    gs = rng.normal(0, 1, (m, n)).astype(np.float32)
    w = rng.uniform(0.5, 2.0, (m,)).astype(np.float32)
    return jnp.asarray(gs), jnp.asarray(w)


# ---- FaultConfig: counts / pick ----------------------------------------

def test_counts_static_and_capped():
    assert FaultConfig(dropout=0.25).counts(8) == (2, 0, 0)
    assert FaultConfig(dropout=0.25, nonfinite=0.125,
                       byzantine=0.25).counts(8) == (2, 1, 2)
    # overflow shaves byzantine -> nonfinite -> dropout, keeps >= 1 honest
    assert FaultConfig(dropout=0.5, nonfinite=0.5,
                       byzantine=0.5).counts(8) == (4, 3, 0)
    assert FaultConfig().counts(8) == (0, 0, 0)


def test_pick_deterministic_and_disjoint():
    cfg = FaultConfig(dropout=0.25, nonfinite=0.125, byzantine=0.25, seed=7)
    a = cfg.pick(8, np.random.RandomState(7))
    b = cfg.pick(8, np.random.RandomState(7))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    keep, nan_m, byz_m, _ = a
    dropped = keep == 0.0
    # roles are disjoint slices of one permutation
    assert not np.any(dropped & nan_m) and not np.any(dropped & byz_m)
    assert not np.any(nan_m & byz_m)
    assert (int(dropped.sum()), int(nan_m.sum()),
            int(byz_m.sum())) == cfg.counts(8)


def test_pick_rng_draws_invariant_across_modes():
    """Every config consumes the same rng draws, so fraction sweeps share
    the underlying permutation (same clients fail as fractions grow)."""
    r1, r2 = np.random.RandomState(3), np.random.RandomState(3)
    FaultConfig(dropout=0.25).pick(8, r1)
    FaultConfig(byzantine=0.25, nonfinite=0.125).pick(8, r2)
    np.testing.assert_array_equal(r1.permutation(100), r2.permutation(100))


def test_apply_faults_zero_config_is_identity():
    cfg = FaultConfig()
    gs, w = _block()
    fault = cfg.pick(8, np.random.RandomState(0))
    g2, w_agg, w_rep = apply_faults(cfg, gs, w, fault)
    assert g2 is gs and w_agg is w and w_rep is w   # statically absent


def test_apply_faults_modes():
    gs, w = _block()
    cfg = FaultConfig(dropout=0.25, nonfinite=0.125, byzantine=0.25,
                      byzantine_scale=10.0)
    keep, nan_m, byz_m, seed = cfg.pick(8, np.random.RandomState(1))
    g2, w_agg, w_rep = apply_faults(
        cfg, gs, w, tuple(map(jnp.asarray, (keep, nan_m, byz_m, seed))))
    g2, w_agg, w_rep = map(np.asarray, (g2, w_agg, w_rep))
    np.testing.assert_array_equal(w_agg, np.asarray(w) * keep)
    assert np.isclose(w_rep.sum(), 1.0)              # renormalized
    for i in range(8):
        if nan_m[i]:
            assert np.all(np.isnan(g2[i]))
        elif byz_m[i]:
            np.testing.assert_allclose(g2[i], -10.0 * np.asarray(gs)[i],
                                       rtol=1e-6)
        else:
            np.testing.assert_array_equal(g2[i], np.asarray(gs)[i])


def test_fault_validation():
    with pytest.raises(ValueError):
        FaultConfig(dropout=1.0)
    with pytest.raises(ValueError):
        FaultConfig(byzantine_mode="zeroed")


# ---- masked mean: dropout renormalization ------------------------------

def test_masked_mean_renormalizes_dropped_weight():
    """Zeroing dropout rows' weights and renormalizing must equal the
    weighted mean over survivors only — the effective weight sum stays
    1 regardless of how many clients dropped (kernel == oracle)."""
    gs, w = _block()
    keep = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
    w_mask = w * keep
    ref = masked_mean_ref(gs, w_mask)
    ker = masked_mean_flat(gs, w_mask, interpret=True)
    surv = np.asarray(w_mask) > 0
    expect = (np.asarray(gs)[surv] * (np.asarray(w_mask)[surv] /
              np.asarray(w_mask)[surv].sum())[:, None]).sum(0)
    np.testing.assert_allclose(np.asarray(ref), expect, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)
    # effective weights sum to 1: aggregating all-ones rows returns ones
    ones = jnp.ones_like(gs)
    np.testing.assert_allclose(
        np.asarray(masked_mean_flat(ones, w_mask, interpret=True)),
        np.ones(N), rtol=1e-6)


# ---- norm screening -----------------------------------------------------

def test_screened_weights_clip_hand_check():
    """A row at 100x the median norm is clipped back to factor * median
    (weight scaled by thresh/norm); honest rows keep weight 1; non-finite
    rows are rejected from numerator and denominator."""
    m = 4
    gs = np.ones((m, N), np.float32)
    gs[1] *= 100.0                      # outlier
    gs[2] = np.nan                      # divergent
    w = np.ones((m,), np.float32)
    w_num, w_den = map(np.asarray, screened_weights(
        jnp.asarray(gs), jnp.asarray(w), factor=3.0))
    norms = np.linalg.norm(gs, axis=1)
    med = np.median([norms[0], norms[3]])   # live rows 0, 1, 3 -> lower med
    assert np.isclose(w_num[0], 1.0) and np.isclose(w_num[3], 1.0)
    assert np.isclose(w_num[1], 3.0 * med / norms[1], rtol=1e-5)
    assert w_num[2] == 0.0 and w_den[2] == 0.0
    assert np.isclose(w_den[1], 1.0)        # denominator is unclipped


def test_screened_aggregate_kernel_matches_oracle():
    gs, w = _block()
    gs = gs.at[3].multiply(1000.0)          # adversarial magnitude
    gs = gs.at[5].set(jnp.nan)              # divergent row
    ref = screened_aggregate_ref(gs, w)
    ker = screened_aggregate_flat(gs, w, interpret=True)
    assert np.all(np.isfinite(np.asarray(ref)))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=1e-5,
                               atol=1e-6)


# ---- trimmed mean: kernel vs oracle ------------------------------------

@pytest.mark.parametrize("trim", [1, 2])
def test_trimmed_kernel_matches_oracle_adversarial(trim):
    """Coordinate-wise trimmed mean under sign-flip x1000 adversarial rows
    and a NaN row: kernel == NaN-sort oracle, and the adversarial values
    never leak into the output (result stays within honest-row range)."""
    gs, w = _block(m=8, seed=2)
    gs = gs.at[1].multiply(-1000.0)
    gs = gs.at[6].multiply(1000.0)
    gs = gs.at[4].set(jnp.nan)
    live = row_liveness(gs, w)
    assert np.asarray(live).tolist() == [1, 1, 1, 1, 0, 1, 1, 1]
    ref = trimmed_mean_ref(gs, live, trim=trim)
    ker = trimmed_mean_flat(gs, live, trim=trim, interpret=True)
    # absolute tolerance: summing then subtracting the +-1000x rows
    # costs ~1e-4 abs in f32; near-zero coordinates make rtol meaningless
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=0,
                               atol=1e-3)
    if trim == 2:
        # both adversaries trimmed from either tail: the output stays
        # within the honest-row range coordinate-wise (at trim=1 a
        # coordinate where both adversarial values share a sign can
        # legitimately leak one of them)
        honest = np.asarray(gs)[[0, 2, 3, 5, 7]]
        assert np.all(np.asarray(ref) <= honest.max(0) + 1e-3)
        assert np.all(np.asarray(ref) >= honest.min(0) - 1e-3)


def test_trimmed_hand_check():
    """Columns [1, 3, 100, 5, 7], trim=1 -> drop 100 and 1, mean(3,5,7)=5;
    with row 2 dead the window is [1,3,5,7], trim -> mean(3,5)=4."""
    cols = np.tile(np.asarray([1, 3, 100, 5, 7], np.float32)[:, None],
                   (1, N))
    live = jnp.ones((5,), jnp.float32)
    for fn in (trimmed_mean_ref,
               lambda g, l, trim: trimmed_mean_flat(g, l, trim=trim,
                                                    interpret=True)):
        np.testing.assert_allclose(
            np.asarray(fn(jnp.asarray(cols), live, trim=1)), 5.0, rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(fn(jnp.asarray(cols), live.at[2].set(0.0), trim=1)),
            4.0, rtol=1e-6)


def test_trimmed_degenerate_round_is_nonfinite():
    """Too few live rows to trim (n_live <= 2*trim) must yield a
    non-finite aggregate — the guard's skip signal, never a silent
    garbage update."""
    gs, w = _block(m=4)
    live = jnp.asarray([1, 1, 0, 0], jnp.float32)
    out = trimmed_mean_flat(gs, live, trim=1, interpret=True)
    assert not np.all(np.isfinite(np.asarray(out)))


def test_robust_aggregate_dispatch():
    gs, w = _block()
    for agg in AGGREGATORS:
        xla = robust_aggregate(gs, w, aggregator=agg, impl="xla")
        pal = robust_aggregate(gs, w, aggregator=agg,
                               impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(xla), np.asarray(pal),
                                   rtol=1e-4, atol=1e-5)
    # on a clean block, every aggregator is close to the plain mean
    mean = np.asarray(weighted_aggregate_ref(gs, w / jnp.sum(w)))
    masked = np.asarray(robust_aggregate(gs, w, aggregator="masked_mean",
                                         impl="xla"))
    np.testing.assert_allclose(masked, mean, rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError):
        robust_aggregate(gs, w, aggregator="median")


# ---- guard: bitwise no-op on clean runs --------------------------------

@pytest.mark.parametrize("algo_name", ALGOS)
def test_guard_bitwise_noop_on_clean_run(algo_name):
    """guard=True on a fault-free run must not perturb a single bit of
    the trajectory — the only difference is the skipped=0.0 metric."""
    base = _fedmeta_history(algo_name, packed=True)
    guarded = _fedmeta_history(algo_name, packed=True, guard=True)
    assert all(r.pop("skipped") == 0.0 for r in guarded)
    assert guarded == base


def test_zero_fraction_faults_bitwise_noop():
    """FaultConfig with all fractions 0 (guard off) is bitwise identical
    to no config at all: same task stream, same jitted graph numerics."""
    base = _fedmeta_history("fomaml", packed=True)
    off = _fedmeta_history("fomaml", packed=True, faults=FaultConfig(),
                           guard=False)
    assert off == base


def test_guard_skips_poisoned_round_phi_untouched():
    """All-NaN uploads with mean aggregation: every round is skipped, φ
    and the Adam state never move, and history reports the skips."""
    algo = make_algorithm("fomaml", classification_loss(_TinyModel.apply)[0],
                          classification_loss(_TinyModel.apply)[1],
                          inner_lr=0.05)
    tr = FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                          support_size=8, query_size=8, seed=0, packed=True,
                          faults=FaultConfig(nonfinite=0.5))
    state = tr.init(jax.random.PRNGKey(0), _TinyModel.init)
    phi0 = np.asarray(state["phi"]).copy()
    state = tr.run(state, 4)
    # nonfinite=0.5 of m=4 -> 2 NaN rows every round; mean is poisoned
    assert [r["skipped"] for r in tr.history] == [1.0] * 4
    np.testing.assert_array_equal(np.asarray(state["phi"]), phi0)
    assert int(state["opt"]["step"]) == 0       # Adam step count untouched


@pytest.mark.parametrize("aggregator", ["screen", "trimmed"])
def test_robust_aggregators_absorb_faults(aggregator):
    """Under dropout + Byzantine injection the robust aggregators keep
    training: no skipped rounds, finite φ, full-length history."""
    hist = _fedmeta_history(
        "fomaml", packed=True, aggregator=aggregator, trim=1,
        faults=FaultConfig(dropout=0.25, byzantine=0.25, seed=3))
    assert len(hist) == 6
    assert sum(r["skipped"] for r in hist) == 0.0


def test_faults_compose_with_staleness_and_prefetch():
    """faults x staleness x prefetch_depth: the pipelined run is bitwise
    identical to the synchronous one under the same fault stream."""
    kw = dict(packed=True,
              staleness=StalenessConfig(delay=1, fraction=0.34,
                                        discount=0.5),
              faults=FaultConfig(dropout=0.25, seed=5))
    sync = _fedmeta_history("fomaml", **kw)
    piped = _fedmeta_history("fomaml", prefetch_depth=2, **kw)
    assert piped == sync


def test_fault_validation_in_trainer():
    algo = make_algorithm("fomaml", *classification_loss(_TinyModel.apply),
                          inner_lr=0.05)
    with pytest.raises(ValueError):    # faults need the packed plane
        FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                         support_size=8, query_size=8,
                         faults=FaultConfig(dropout=0.25))
    with pytest.raises(ValueError):    # unknown aggregator
        FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                         support_size=8, query_size=8, packed=True,
                         aggregator="median")
    with pytest.raises(ValueError):    # 2*trim must be < clients_per_round
        FederatedTrainer(algo, adam(1e-3), TRAIN, 4, support_frac=0.5,
                         support_size=8, query_size=8, packed=True,
                         aggregator="trimmed", trim=2)


# ---- committed artifact pin --------------------------------------------

def test_robustness_artifact_separation():
    """The committed sweep must show the §14 story: at byzantine 0.25
    plain mean collapses while screened/trimmed aggregation holds."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "experiments", "robustness_femnist.json")
    with open(path) as f:
        art = json.load(f)
    byz = art["headline"]["byzantine_0.25"]
    clean = art["headline"]["clean"]
    # the committed run: mean 0.040 vs trimmed 0.124 / screen 0.130 —
    # sign-flipped rows reverse the mean's aggregate (2 rows at -10x
    # outweigh 6 honest rows) while trimming/screening reject them
    assert byz["trimmed"] >= 2 * byz["mean"]
    assert byz["screen"] >= 2 * byz["mean"]
    # under attack the robust aggregators retain what clean mean
    # training reaches (trimmed 0.124 vs clean mean 0.129)
    assert byz["trimmed"] >= 0.75 * clean["mean"]
    # and cost little when the population is clean
    assert clean["trimmed"] >= clean["mean"] - 0.1
    assert clean["screen"] >= clean["mean"] - 0.1
