"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family (<=2 layers or one pattern period,
d_model<=256, <=4 experts) and run one forward + one FedMeta train step on
CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.launch.steps import make_train_step
from repro.models import (init_decode_cache, init_lm, lm_apply,
                          lm_decode_step)

ARCHS = list_archs()


def _inputs(cfg, rng, B=2, L=16):
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, L)), jnp.int32)
    embeds = None
    if cfg.modality is not None:
        embeds = jnp.asarray(
            rng.normal(0, 0.1, (B, cfg.num_modality_tokens, cfg.d_model)),
            jnp.float32)
    return tokens, embeds


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, rng):
    cfg = reduced_config(get_config(arch))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens, embeds = _inputs(cfg, rng)
    logits, aux = lm_apply(params, cfg, tokens, modality_embeds=embeds)
    n_mod = cfg.num_modality_tokens if cfg.modality == "vision" else 0
    assert logits.shape == (2, 16 + n_mod, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = reduced_config(get_config(arch))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    enc_out = (jnp.zeros((2, 8, cfg.d_model), jnp.float32)
               if cfg.is_encoder_decoder else None)
    cache = init_decode_cache(cfg, 2, 32, enc_out=enc_out, full=False)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 1)), jnp.int32)
    logits, cache2 = lm_decode_step(params, cfg, tok, cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["length"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_fedmeta_train_step(arch, rng):
    """One FedMeta (FOMAML) meta-train step on the reduced config: loss
    finite, params actually move, no NaNs anywhere in the state."""
    cfg = reduced_config(get_config(arch))
    train_step, init_state, _, _ = make_train_step(
        cfg, algo_name="fomaml", inner_lr=0.05, outer_lr=1e-3)
    state = init_state(jax.random.PRNGKey(0))
    G, C, S, L = 1, 2, 2, 16
    def part():
        leaf = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (G, C, S, L)), jnp.int32)}
        if cfg.modality is not None:
            leaf["embeds"] = jnp.asarray(
                rng.normal(0, 0.1, (G, C, S, cfg.num_modality_tokens,
                                    cfg.d_model)), jnp.float32)
        return leaf
    batch = {"support": part(), "query": part()}
    new_state, metrics = jax.jit(train_step)(state, batch)
    assert bool(jnp.isfinite(metrics["query_loss"]))
    # params moved
    before = np.asarray(jax.tree.leaves(state["phi"]["theta"])[0])
    after = np.asarray(jax.tree.leaves(new_state["phi"]["theta"])[0])
    assert not np.allclose(before, after)
    # nothing became NaN
    for leaf in jax.tree.leaves(new_state):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf)))


def test_prefill_decode_consistency(rng):
    """Prefill-then-decode equals full forward at the next position
    (granite reduced, full-precision)."""
    cfg = reduced_config(get_config("granite-3-2b"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, L = 1, 24
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, L + 1)), jnp.int32)
    # full forward logits at position L-1 predict token L
    full_logits, _ = lm_apply(params, cfg, tokens, remat=False)
    # prefill first L tokens (capacity > L so decode appends, not wraps),
    # then decode token L
    logits_pre, aux, cache = lm_apply(params, cfg, tokens[:, :L], remat=False,
                                      collect_cache=True, logits_mode="last",
                                      cache_capacity=L + 4)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(full_logits[:, L - 1]),
                               rtol=1e-4, atol=1e-4)
    dec_logits, _ = lm_decode_step(params, cfg, tokens[:, L:L + 1], cache)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, L]),
                               rtol=1e-4, atol=1e-4)


def test_prefill_decode_consistency_mamba(rng):
    """Same handoff check through the SSM state path (mamba2 reduced)."""
    cfg = reduced_config(get_config("mamba2-370m"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, L = 1, 32
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, L + 1)), jnp.int32)
    full_logits, _ = lm_apply(params, cfg, tokens, remat=False)
    _, _, cache = lm_apply(params, cfg, tokens[:, :L], remat=False,
                           collect_cache=True, logits_mode="last")
    dec_logits, _ = lm_decode_step(params, cfg, tokens[:, L:L + 1], cache)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, L]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_decode(rng):
    """SWA ring cache: decode after prefill matches a full forward whose
    attention is windowed (mixtral reduced, window < seq). capacity_factor
    is raised to E/K so MoE capacity dropping (which is batch-dependent by
    design) cannot differ between the two paths."""
    import dataclasses
    cfg = reduced_config(get_config("mixtral-8x22b"))
    cfg = dataclasses.replace(
        cfg, capacity_factor=cfg.num_experts / cfg.num_experts_per_tok)
    assert cfg.sliding_window == 64
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, L = 1, 96   # longer than the 64-token window
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, L + 1)), jnp.int32)
    full_logits, _ = lm_apply(params, cfg, tokens, remat=False)
    _, _, cache = lm_apply(params, cfg, tokens[:, :L], remat=False,
                           collect_cache=True, logits_mode="last")
    assert cache["stack"]["pos0"]["k"].shape[2] == 64   # ring capacity
    dec_logits, _ = lm_decode_step(params, cfg, tokens[:, L:L + 1], cache)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, L]),
                               rtol=2e-3, atol=2e-3)
