"""Runtime-sanitizer tests — the dynamic half of the invariant plane.

The lock-assert sanitizer wraps a live `ClientRegistry` and is
exercised two ways under a K=8 `WorkerPool`: the real registry (every
shared-state write under its leaf lock → zero violations) and a
planted unguarded write (→ detected, attributed to a worker thread,
and fatal under `assert_guarded`). Plus the tracer-leak guard the
experiment plane runs under REPRO_SANITIZE=1."""
import threading

import numpy as np
import pytest

from repro.analysis.sanitizers import (SanitizedLock, TracerLeakError,
                                       UnguardedAccessError,
                                       assert_guarded, assert_no_tracers,
                                       cross_thread_violations,
                                       guard_shared_state, no_tracer_leaks,
                                       sanitizers_enabled,
                                       unguarded_writes)
from repro.data.federated import ClientData
from repro.data.registry import ClientRegistry, IndependentClientSource
from repro.federated.async_engine import WorkerPool

K = 8          # worker threads — the ISSUE's K=8 contract
N_CLIENTS = 64


def tiny_client(rng) -> ClientData:
    x = rng.normal(size=(6, 3)).astype(np.float32)
    y = rng.randint(0, 3, size=6)
    return ClientData(x, y)


def make_registry(cache=16) -> ClientRegistry:
    src = IndependentClientSource(tiny_client, N_CLIENTS, seed=7)
    return ClientRegistry(src, num_classes=3, cache_clients=cache)


# ---- SanitizedLock -------------------------------------------------------

class TestSanitizedLock:
    def test_held_by_me_tracks_owner(self):
        lock = SanitizedLock()
        assert not lock.held_by_me()
        with lock:
            assert lock.held_by_me() and lock.locked()
        assert not lock.held_by_me() and not lock.locked()

    def test_other_thread_is_not_owner(self):
        lock = SanitizedLock()
        seen = {}
        with lock:
            t = threading.Thread(
                target=lambda: seen.update(held=lock.held_by_me()))
            t.start()
            t.join()
        assert seen["held"] is False


# ---- lock-assert sanitizer under K=8 workers ----------------------------

class TestGuardSharedState:
    def test_clean_registry_has_no_violations_under_k8(self):
        """The real registry, hammered by K=8 workers: every write to
        cache/counters goes through `with self._lock:` → the sanitizer
        records nothing. This is the invariant the thread-unguarded-
        write lint rule proves lexically, proven dynamically."""
        reg = guard_shared_state(make_registry(cache=8))
        pool = WorkerPool(lambda i: reg[i].n, workers=K)
        try:
            # 3 passes over the id space: misses, hits and evictions
            ids = list(range(N_CLIENTS)) * 3
            out = pool.map(ids, label="sanitizer-smoke")
        finally:
            pool.close()
        assert len(out) == len(ids)
        assert cross_thread_violations(reg) == []
        assert_guarded(reg)      # must not raise
        stats = reg.cache_stats()
        assert stats["hits"] + stats["misses"] >= len(ids)

    def test_planted_unguarded_write_detected_under_k8(self):
        """Plant the race the sanitizer exists for: workers bump a
        counter attribute *without* taking the registry lock."""
        reg = guard_shared_state(make_registry(cache=8))

        def racy(i):
            n = reg[i].n          # legal, lock-guarded path
            reg._hits = reg._hits  # unguarded shared-state write
            return n

        pool = WorkerPool(racy, workers=K)
        try:
            pool.map(list(range(N_CLIENTS)), label="planted-race")
        finally:
            pool.close()
        bad = cross_thread_violations(reg)
        assert bad, "planted unguarded write was not detected"
        assert all(v.attr == "_hits" and v.cross_thread for v in bad)
        assert any("worker" in v.thread_name for v in bad)
        with pytest.raises(UnguardedAccessError) as ei:
            assert_guarded(reg)
        assert "_hits" in str(ei.value)

    def test_owner_thread_unguarded_write_recorded_not_cross(self):
        reg = guard_shared_state(make_registry())
        reg._peak = 99            # unguarded, but on the owning thread
        assert unguarded_writes(reg) and not cross_thread_violations(reg)
        assert_guarded(reg)                       # cross-thread only
        with pytest.raises(UnguardedAccessError):
            assert_guarded(reg, cross_thread_only=False)

    def test_registry_still_correct_after_instrumentation(self):
        plain, wrapped = make_registry(), guard_shared_state(make_registry())
        for i in (0, 17, 63):
            np.testing.assert_array_equal(plain[i].x, wrapped[i].x)
        assert type(wrapped).__name__ == "SanitizedClientRegistry"

    def test_guard_refuses_held_lock(self):
        reg = make_registry()
        with reg._lock:
            with pytest.raises(RuntimeError):
                guard_shared_state(reg)


# ---- tracer-leak guard ---------------------------------------------------

class TestTracerGuard:
    def test_leaked_tracer_detected(self):
        # jax is a hard dep of repro itself — never skippable here
        import jax
        import jax.numpy as jnp
        leak = []

        @jax.jit
        def step(x):
            leak.append(x * 2)    # abstract value escapes the trace
            return x + 1

        step(jnp.ones(3))
        with pytest.raises(TracerLeakError) as ei:
            assert_no_tracers({"history": leak}, where="fixture record")
        assert "fixture record" in str(ei.value)

    def test_host_data_passes(self):
        record = {"round": 3, "acc": 0.91,
                  "phi": [np.zeros(4), np.ones(2)]}
        assert_no_tracers(record)      # must not raise

    def test_no_tracer_leaks_context_smoke(self):
        import jax
        import jax.numpy as jnp
        with no_tracer_leaks():
            assert float(jax.jit(lambda x: x * 2)(jnp.ones(()))) == 2.0

    def test_env_gate(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        assert not sanitizers_enabled()
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        assert sanitizers_enabled()
