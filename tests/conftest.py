import numpy as np
import pytest

# NOTE: deliberately no XLA_FLAGS device-count override here — smoke tests
# and benches must see the real single CPU device (the 512-device override
# lives exclusively inside repro/launch/dryrun.py, per the launch rules).


@pytest.fixture
def rng():
    return np.random.RandomState(0)
