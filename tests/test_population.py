"""Lazy client registry invariants (DESIGN.md §15, tentpole part 1).

The registry's load-bearing contract: a lazy `ClientRegistry` in
sequential mode is **bit-identical** to the eager `FederatedDataset`
for every scenario generator — same client arrays, same splits, same
task batches, same 10-round trainer histories across every pipeline
mode — while an independent-mode registry holds 10^5 clients behind a
bounded LRU cache whose peak residency never exceeds the cap. Plus the
partial-round batch assembler's hand-checked renormalization, shard
round-trips, and once-only synthesis under K concurrent readers.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classification_loss, make_algorithm
from repro.data.federated import (ClientData, FederatedDataset,
                                  assemble_task_batch, sample_task_batch)
from repro.data.lm_tasks import make_lm_clients
from repro.data.registry import (ClientRegistry, IndependentClientSource,
                                 RegistryView, load_shard_registry,
                                 registry_from_body, save_shards)
from repro.data.synth_femnist import make_femnist
from repro.data.synth_recommend import localize_clients, make_recommend
from repro.data.synth_sent140 import make_sent140
from repro.data.synth_shakespeare import make_shakespeare
from repro.federated.server import FederatedTrainer
from repro.federated.async_engine import StalenessConfig
from repro.federated.faults import FaultConfig
from repro.optim import adam

MAKERS = {
    "femnist": lambda **kw: make_femnist(
        num_clients=10, num_classes=5, image_size=8, mean_samples=12,
        seed=3, **kw),
    "sent140": lambda **kw: make_sent140(
        num_clients=10, seq_len=6, vocab=50, mean_samples=12, seed=3,
        **kw),
    "shakespeare": lambda **kw: make_shakespeare(
        num_clients=8, seq_len=8, mean_samples=20, seed=3, **kw),
    "recommend": lambda **kw: make_recommend(
        num_clients=10, num_services=60, ctx_dim=4, mean_records=20,
        seed=3, **kw),
    "lm": lambda **kw: make_lm_clients(
        num_clients=10, mean_seqs=8, seq_len=6, vocab=20, seed=3, **kw),
}


def _clients_of(ds):
    return ds.clients if isinstance(ds, FederatedDataset) else ds


# ---- bit-identity: lazy sequential == eager, all five scenarios ---------

@pytest.mark.parametrize("scenario", list(MAKERS))
def test_lazy_sequential_bit_identical(scenario):
    """Every client array, the seeded split, and a seeded task batch of
    the lazy registry must equal the eager dataset's exactly — the
    registry replays the SAME sequential rng stream."""
    eager = MAKERS[scenario]()
    lazy = MAKERS[scenario](lazy=True)
    ec, lc = _clients_of(eager), lazy
    assert len(lc) == len(ec) and lazy.num_classes == eager.num_classes
    for i in range(len(ec)):
        np.testing.assert_array_equal(lc[i].x, ec[i].x)
        np.testing.assert_array_equal(lc[i].y, ec[i].y)

    # seeded splits land on the same client indices / data
    et, ev, es = eager.split_clients(seed=7)
    lt, lv, ls = lazy.split_clients(seed=7)
    for e_split, l_split in ((et, lt), (ev, lv), (es, ls)):
        assert len(l_split) == len(e_split)
        for e, l in zip(e_split, (l_split[j] for j in range(len(l_split)))):
            np.testing.assert_array_equal(l.x, e.x)
            np.testing.assert_array_equal(l.y, e.y)

    # a seeded task batch drawn THROUGH the registry is byte-identical
    tb_e = sample_task_batch(ec, 4, 0.5, 4, 4, np.random.RandomState(11))
    tb_l = sample_task_batch(lazy, 4, 0.5, 4, 4, np.random.RandomState(11))
    for f in tb_e._fields:
        np.testing.assert_array_equal(getattr(tb_l, f), getattr(tb_e, f))


def test_lazy_sequential_bit_identical_with_tiny_cache():
    """A cache far smaller than the population forces every access to
    re-synthesize from the rng snapshot — the data must not change."""
    eager = MAKERS["femnist"]()
    lazy = MAKERS["femnist"](lazy=True, cache_clients=2)
    for i in (7, 0, 9, 3, 7, 0):     # revisits after guaranteed eviction
        np.testing.assert_array_equal(lazy[i].x, eager.clients[i].x)
        np.testing.assert_array_equal(lazy[i].y, eager.clients[i].y)
    st = lazy.cache_stats()
    assert st["peak_resident"] <= 2 and st["evictions"] > 0


def test_localize_view_parity_recommend():
    """The recommend local-head view composes lazily: a registry view's
    localized labels equal the eager localize output per client."""
    eager = MAKERS["recommend"]()
    lazy = MAKERS["recommend"](lazy=True)
    e_loc = localize_clients(eager.clients, head_size=40)
    l_loc = localize_clients(lazy, head_size=40)
    assert isinstance(l_loc, RegistryView)
    assert l_loc.num_classes == 40
    for i in range(len(e_loc)):
        np.testing.assert_array_equal(l_loc[i].x, e_loc[i].x)
        np.testing.assert_array_equal(l_loc[i].y, e_loc[i].y)


# ---- trainer histories: lazy == eager across every pipeline mode --------

class _FemnistModel:
    @staticmethod
    def init(key):
        k, _ = jax.random.split(key)
        return {"w": jax.random.normal(k, (64, 5)) * 0.1,
                "b": jnp.zeros((5,))}

    @staticmethod
    def apply(params, x):
        return x.reshape(x.shape[0], -1) @ params["w"] + params["b"]


PIPELINES = {
    "sync": {},
    "prefetch": dict(prefetch_depth=2, flush_every=3),
    "fused-k": dict(fuse_rounds=3, prefetch_depth=1, flush_every=0),
    "staleness": dict(staleness=StalenessConfig(delay=1, fraction=0.34,
                                                discount=0.5)),
    "faults": dict(faults=FaultConfig(dropout=0.25, byzantine=0.25,
                                      seed=5), aggregator="trimmed",
                   trim=1),
}


@pytest.mark.parametrize("pipeline", list(PIPELINES), ids=list(PIPELINES))
def test_lazy_history_bit_identical(pipeline):
    """10 femnist rounds, eager clients vs a lazy registry with an
    eviction-forcing cache, on every pipeline mode: histories must be
    equal record for record (the registry is invisible to the stream)."""
    def run(train):
        algo = make_algorithm("fomaml",
                              *classification_loss(_FemnistModel.apply),
                              inner_lr=0.05)
        tr = FederatedTrainer(algo, adam(1e-3), train, 4,
                              support_frac=0.5, support_size=4,
                              query_size=4, seed=0, packed=True,
                              **PIPELINES[pipeline])
        state = tr.init(jax.random.PRNGKey(0), _FemnistModel.init)
        tr.run(state, 10, eval_every=0)
        return tr.history

    eager = run(MAKERS["femnist"]().clients)
    lazy = run(MAKERS["femnist"](lazy=True, cache_clients=3))
    assert lazy == eager


# ---- bounded memory at population scale ---------------------------------

def test_lru_bound_under_1e5_sweep():
    """An independent-mode registry over 10^5 clients: O(1) per-client
    seeding (no construction pass), and a full index sweep keeps peak
    residency at the cache cap — the bounded-memory claim."""
    def body(rng):
        y = rng.randint(0, 2, size=4).astype(np.int64)
        return ClientData(rng.normal(0, 1, (4, 2)).astype(np.float32), y)

    n = 100_000
    reg = registry_from_body(body, n, 2, "pop", independent=True, seed=9,
                             cache_clients=64)
    assert len(reg) == n
    step = 997                         # sparse sweep across the range
    for i in range(0, n, step):
        assert reg[i].n == 4
    # then hammer a dense window larger than the cache
    for i in range(5_000, 5_000 + 512):
        reg[i]
    st = reg.cache_stats()
    assert st["peak_resident"] <= 64
    assert st["resident"] <= 64
    assert st["evictions"] > 0
    # determinism: client i is a pure function of (seed, i)
    a, b = reg[31_337], reg[31_337 - 1]
    again = IndependentClientSource(body, n, 9).get(31_337)
    np.testing.assert_array_equal(a.x, again.x)
    assert not np.array_equal(a.x, b.x)


def test_registry_validation_and_indexing():
    def body(rng):
        return ClientData(rng.normal(0, 1, (3, 2)).astype(np.float32),
                          np.array([0, 1, 0], np.int64))

    with pytest.raises(ValueError, match="cache_clients"):
        registry_from_body(body, 4, 2, "x", independent=True,
                           cache_clients=0)
    with pytest.raises(ValueError, match="rng"):
        registry_from_body(body, 4, 2, "x")      # sequential needs rng
    reg = registry_from_body(body, 4, 2, "x", independent=True)
    np.testing.assert_array_equal(reg[-1].x, reg[3].x)
    with pytest.raises(IndexError):
        reg[4]
    sl = reg[1:3]
    assert isinstance(sl, RegistryView) and len(sl) == 2
    np.testing.assert_array_equal(sl[0].x, reg[1].x)
    # view transform must preserve client sizes
    bad = reg.view(lambda c: ClientData(c.x[:1], c.y[:1]))
    with pytest.raises(ValueError, match="preserve client sizes"):
        bad[0]
    # chained views compose (and the chain re-checks n-preservation)
    v = reg.view(lambda c: ClientData(c.x, 1 - c.y))
    vv = v.view(lambda c: ClientData(c.x, 1 - c.y))
    np.testing.assert_array_equal(vv[2].y, reg[2].y)
    # stats over a sampled prefix
    st = reg.stats(max_clients=2)
    assert st["clients"] == 4 and st["sampled"] == 2


def test_shard_roundtrip(tmp_path):
    eager = MAKERS["lm"]()
    save_shards(eager.clients, str(tmp_path), eager.num_classes,
                name="lm-shards")
    reg = load_shard_registry(str(tmp_path), cache_clients=3)
    assert len(reg) == len(eager.clients)
    assert reg.num_classes == eager.num_classes and reg.name == "lm-shards"
    for i in range(len(reg)):
        np.testing.assert_array_equal(reg[i].x, eager.clients[i].x)
        np.testing.assert_array_equal(reg[i].y, eager.clients[i].y)
    assert reg.cache_stats()["peak_resident"] <= 3


def test_concurrent_access_synthesizes_once():
    """K threads racing for the same client must synthesize it exactly
    once (the in-flight event) and all read identical arrays."""
    calls = []
    lock = threading.Lock()

    def body(rng):
        with lock:
            calls.append(1)
        return ClientData(rng.normal(0, 1, (3, 2)).astype(np.float32),
                          np.array([0, 1, 0], np.int64))

    reg = registry_from_body(body, 8, 2, "x", independent=True,
                             cache_clients=8)
    results, errors = [], []

    def hit():
        try:
            for i in (5, 5, 5, 2):
                results.append((i, reg[i].x))
        except BaseException as e:   # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=hit) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(calls) == 2           # clients 5 and 2, once each
    for i, x in results:
        np.testing.assert_array_equal(x, reg[i].x)


def test_materialize_matches_eager():
    eager = MAKERS["sent140"]()
    snap = MAKERS["sent140"](lazy=True).materialize()
    assert isinstance(snap, FederatedDataset)
    for a, b in zip(snap.clients, eager.clients):
        np.testing.assert_array_equal(a.x, b.x)
        np.testing.assert_array_equal(a.y, b.y)


# ---- the partial-round batch assembler ----------------------------------

def test_assemble_task_batch_hand_check():
    """3 arrived of m=4: rows 0..2 are the arrivals in order with
    weights n_i/Σn, row 3 is a zero-weight copy of row 0."""
    rng0 = np.random.RandomState(2)
    shards = [ClientData(rng0.normal(0, 1, (n, 2)).astype(np.float32),
                         rng0.randint(0, 2, n).astype(np.int64))
              for n in (10, 20, 30)]
    tb = assemble_task_batch(shards, 4, 0.5, 4, 4,
                             np.random.RandomState(0))
    np.testing.assert_allclose(tb.weight, [10 / 60, 20 / 60, 30 / 60, 0.0],
                               rtol=1e-6)
    np.testing.assert_array_equal(tb.support_x[3], tb.support_x[0])
    np.testing.assert_array_equal(tb.query_y[3], tb.query_y[0])
    assert tb.query_count[3] == 0
    assert tb.support_x.shape == (4, 4, 2)

    # unweighted: uniform over arrivals
    tb_u = assemble_task_batch(shards, 4, 0.5, 4, 4,
                               np.random.RandomState(0), weighted=False)
    np.testing.assert_allclose(tb_u.weight, [1 / 3, 1 / 3, 1 / 3, 0.0],
                               rtol=1e-6)

    # all-failed round: probe supplies shapes, weights are all zero
    tb_0 = assemble_task_batch([], 4, 0.5, 4, 4, np.random.RandomState(0),
                               probe=shards[0])
    np.testing.assert_array_equal(tb_0.weight, np.zeros(4, np.float32))
    assert tb_0.support_x.shape == (4, 4, 2)

    with pytest.raises(ValueError, match="at most"):
        assemble_task_batch(shards, 2, 0.5, 4, 4, np.random.RandomState(0))
    with pytest.raises(ValueError, match="probe"):
        assemble_task_batch([], 4, 0.5, 4, 4, np.random.RandomState(0))
