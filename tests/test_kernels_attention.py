"""Pallas flash-attention kernel vs the pure-jnp oracle: shape/dtype
sweeps, causal + sliding-window masks, GQA group sizes, MLA-style
mismatched value dims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import mha_reference


def _mk(rng, B, Lq, Lk, H, Kv, hd, hd_v=None, dtype=jnp.float32):
    hd_v = hd_v or hd
    q = jnp.asarray(rng.normal(0, 1, (B, Lq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Lk, Kv, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Lk, Kv, hd_v)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,L,H,Kv,hd", [
    (1, 128, 2, 2, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 256, 8, 1, 128),     # MQA
    (2, 128, 3, 1, 32),      # odd head count
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref_shapes(rng, B, L, H, Kv, hd, causal):
    q, k, v = _mk(rng, B, L, L, H, Kv, hd)
    ref = flash_attention(q, k, v, causal=causal, impl="xla")
    out = flash_attention(q, k, v, causal=causal, impl="pallas_interpret",
                          block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_sliding_window(rng, window):
    q, k, v = _mk(rng, 2, 256, 256, 4, 2, 64)
    ref = flash_attention(q, k, v, causal=True, window=window, impl="xla")
    out = flash_attention(q, k, v, causal=True, window=window,
                          impl="pallas_interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16(rng):
    q, k, v = _mk(rng, 1, 128, 128, 2, 2, 64, dtype=jnp.bfloat16)
    ref = flash_attention(q, k, v, impl="xla")
    out = flash_attention(q, k, v, impl="pallas_interpret",
                          block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_mla_value_dim(rng):
    """MLA: qk dim 80 != value dim 64."""
    q, k, v = _mk(rng, 1, 128, 128, 4, 4, 80, hd_v=64)
    ref = flash_attention(q, k, v, impl="xla")
    out = flash_attention(q, k, v, impl="pallas_interpret",
                          block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ref_decode_ring_equivalence(rng):
    """Decode path: attention over a ring cache with kv_length masking
    equals full attention over the ordered history."""
    B, L, Kv, H, hd = 1, 65, 2, 4, 32
    q, k, v = _mk(rng, B, 1, L, H, Kv, hd)
    # full history, query at the last position
    full = mha_reference(q, k, v, causal=True, q_offset=L - 1)
    # ring: any permutation of kv slots gives the same softmax result
    perm = rng.permutation(L)
    ring = mha_reference(q, k[:, perm], v[:, perm], causal=False,
                         kv_length=L)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(full),
                               rtol=1e-5, atol=1e-5)
