"""Privacy mechanisms: secure-aggregation cancellation invariant and the
DP clip/noise behavior (beyond-paper; paper §5 future work)."""
import jax
import jax.numpy as jnp
import numpy as np

# `propsweep` re-exports hypothesis when installed, else a
# deterministic seeded sweep — no skip either way.
from propsweep import given, settings, st

from repro.federated.privacy import (clip_gradient, dp_aggregate,
                                     masked_uploads, secure_sum)
from repro.utils.pytree import tree_norm


def _grads(rng, m, dims=(5, 3)):
    return {"w": jnp.asarray(rng.normal(0, 1, (m,) + dims), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (m, dims[0])), jnp.float32)}


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(2, 6))
def test_secure_aggregation_masks_cancel(seed, m):
    rng = np.random.RandomState(seed)
    g = _grads(rng, m)
    ups = masked_uploads(g, jax.random.PRNGKey(seed))
    total = secure_sum(ups)
    expect = jax.tree.map(lambda x: jnp.sum(x, axis=0), g)
    for k in expect:
        np.testing.assert_allclose(np.asarray(total[k]),
                                   np.asarray(expect[k]), rtol=1e-4,
                                   atol=1e-4)
    # individual uploads differ substantially from raw gradients
    raw0 = jax.tree.map(lambda x: x[0], g)
    diff = tree_norm(jax.tree.map(lambda a, b: a - b, ups[0], raw0))
    assert float(diff) > 1.0


def test_clip_gradient_bounds_norm(rng):
    g = {"w": jnp.asarray(rng.normal(0, 10, (50,)), jnp.float32)}
    clipped, norm = clip_gradient(g, 1.0)
    assert float(tree_norm(clipped)) <= 1.0 + 1e-5
    small = {"w": jnp.asarray([0.1, 0.1], jnp.float32)}
    same, _ = clip_gradient(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["w"]),
                               np.asarray(small["w"]), rtol=1e-6)


def test_dp_aggregate_zero_noise_is_clipped_mean(rng):
    m = 4
    g = _grads(rng, m)
    w = jnp.ones((m,), jnp.float32)
    out = dp_aggregate(g, w, jax.random.PRNGKey(0), clip_norm=1e9,
                       noise_multiplier=0.0)
    expect = jax.tree.map(lambda x: jnp.mean(x, axis=0), g)
    for k in expect:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(expect[k]), rtol=1e-5,
                                   atol=1e-6)


def test_dp_noise_scale(rng):
    """Noise std matches σ = z·S/m (measured over many leaves)."""
    m, z, S = 4, 2.0, 1.0
    g = {"w": jnp.zeros((m, 20000), jnp.float32)}
    w = jnp.ones((m,), jnp.float32)
    out = dp_aggregate(g, w, jax.random.PRNGKey(1), clip_norm=S,
                       noise_multiplier=z)
    measured = float(jnp.std(out["w"]))
    assert abs(measured - z * S / m) / (z * S / m) < 0.05
