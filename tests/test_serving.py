"""Serving-plane tests (DESIGN.md §18): serve-path adaptation
bit-identity against the training kernel, adaptation-cache LRU
semantics, traffic-generator determinism, and decode equivalence
against a single-request oracle.

The bit-identity contract compares *jitted* paths on both sides —
training always runs under jit, and eager op-by-op dispatch fuses
differently (1-ulp drift), so jit-vs-eager is not part of the
contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_algorithm
from repro.federated.serving import (AdaptationCache, ServeRequest,
                                     ServingEngine, TrafficModel,
                                     support_digest)
from repro.utils.flat import plane_for


def _mlp_task(inner_steps=2):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (6, 8)) * 0.1,
                "w2": jax.random.normal(k2, (8, 3)) * 0.1}

    def loss_fn(p, batch):
        x, y = batch
        return jnp.mean((jnp.tanh(x @ p["w1"]) @ p["w2"] - y) ** 2)

    algo = make_algorithm("fomaml", loss_fn, lambda p, b: {}, 0.05,
                          inner_steps)
    phi = {"theta": init(jax.random.PRNGKey(0))}
    return algo, phi


def _mlp_support(rng, size):
    return (jnp.asarray(rng.randn(size, 6), jnp.float32),
            jnp.asarray(rng.randn(size, 3), jnp.float32))


def _requests(n, sizes, seed=0):
    """n requests with distinct clients and heterogeneous support sizes."""
    rng = np.random.RandomState(seed)
    return [ServeRequest(rid=i, client=i, arrival=float(i),
                         support=_mlp_support(rng, sizes[i % len(sizes)]))
            for i in range(n)]


# ---- bit-identity: serve path vs training kernel -------------------------

class TestServeAdaptBitIdentity:
    def test_engine_rows_match_solo_adapt_heterogeneous(self):
        """Engine rows == per-client jit(adapt) == jit(adapt_packed),
        with heterogeneous support sizes bucketed across batches."""
        algo, phi = _mlp_task()
        plane = plane_for(phi["theta"])
        reqs = _requests(10, sizes=(3, 5, 4))
        engine = ServingEngine(algo, phi, adapt_batch=3,
                               cache=AdaptationCache(None))
        report = engine.serve(reqs)

        jadapt = jax.jit(lambda p, s: plane.pack(algo.adapt(p, s)))
        jpacked = jax.jit(lambda p, s: plane.pack(
            algo.adapt_packed(p, s, plane=plane)))
        for rec, req in zip(report.records, reqs):
            assert rec["rid"] == req.rid
            np.testing.assert_array_equal(
                np.asarray(jadapt(phi, req.support)), np.asarray(rec["row"]))
            np.testing.assert_array_equal(
                np.asarray(jpacked(phi, req.support)), np.asarray(rec["row"]))

    def test_rows_independent_of_batch_schedule(self):
        """Same requests through adapt_batch = 1 / 2 / 5 (different
        executables, different padding) -> bit-identical rows."""
        algo, phi = _mlp_task()
        reqs = _requests(7, sizes=(4,))
        reports = [ServingEngine(algo, phi, adapt_batch=b,
                                 cache=AdaptationCache(None)).serve(reqs)
                   for b in (1, 2, 5)]
        for other in reports[1:]:
            for a, b in zip(reports[0].records, other.records):
                np.testing.assert_array_equal(np.asarray(a["row"]),
                                              np.asarray(b["row"]))

    def test_adapt_packed_batch_matches_training_path(self):
        """The engine's kernel entry (`adapt_packed_batch`) row c ==
        jit(adapt_packed) of client c — the training deployment path —
        including the meta-sgd learned-alpha variant."""
        for name in ("fomaml", "meta-sgd"):
            def loss_fn(p, batch):
                x, y = batch
                return jnp.mean((jnp.tanh(x @ p["w1"]) @ p["w2"] - y) ** 2)

            def init(key):
                k1, k2 = jax.random.split(key)
                return {"w1": jax.random.normal(k1, (6, 8)) * 0.1,
                        "w2": jax.random.normal(k2, (8, 3)) * 0.1}

            algo = make_algorithm(name, loss_fn, lambda p, b: {}, 0.05, 2)
            phi = algo.init_state(jax.random.PRNGKey(1), init)
            plane = plane_for(phi["theta"])
            rng = np.random.RandomState(3)
            sups = [_mlp_support(rng, 4) for _ in range(4)]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sups)
            fbatch = jax.jit(lambda p, s: algo.adapt_packed_batch(
                p, s, plane=plane))
            fsolo = jax.jit(lambda p, s: plane.pack(
                algo.adapt_packed(p, s, plane=plane)))
            rows = fbatch(phi, stacked)
            for c, sup in enumerate(sups):
                np.testing.assert_array_equal(
                    np.asarray(fsolo(phi, sup)), np.asarray(rows[c]),
                    err_msg=f"algo={name} row={c}")


# ---- adaptation cache ----------------------------------------------------

class TestAdaptationCache:
    def test_hit_miss_and_lru_bound(self):
        cache = AdaptationCache(capacity=2)
        assert cache.get("a") is None                   # miss
        cache.put("a", 1)
        cache.put("b", 2)
        assert cache.get("a") == 1                      # hit; a is now MRU
        cache.put("c", 3)                               # evicts b (LRU)
        assert cache.get("b") is None
        assert cache.get("a") == 1 and cache.get("c") == 3
        s = cache.stats()
        assert s["evictions"] == 1
        assert s["peak_resident"] == 2 and s["resident"] == 2
        assert s["hits"] == 3 and s["misses"] == 2

    def test_capacity_validation_and_clear(self):
        with pytest.raises(ValueError):
            AdaptationCache(0)
        cache = AdaptationCache(None)                   # unbounded
        for i in range(100):
            cache.put(i, i)
        assert len(cache) == 100
        cache.clear()
        assert len(cache) == 0 and cache.stats()["hits"] == 0

    def test_engine_cache_bound_and_replay(self):
        """6 distinct clients through a capacity-2 cache: peak stays at
        2, replay of the two resident clients hits, evicted re-adapts
        bit-identically."""
        algo, phi = _mlp_task()
        reqs = _requests(6, sizes=(4,))
        engine = ServingEngine(algo, phi, adapt_batch=2,
                               cache=AdaptationCache(2))
        first = engine.serve(reqs)
        assert engine.cache.stats()["peak_resident"] == 2
        assert engine.cache.stats()["evictions"] == 4
        # the resident tail (last two clients) hits; a full replay in
        # the original order would scan-thrash the LRU (0 hits)
        tail = engine.serve(reqs[4:])
        assert all(r["hit"] for r in tail.records)
        replay = engine.serve(reqs)       # evicted clients re-adapt
        for a, b in zip(first.records, replay.records):
            np.testing.assert_array_equal(np.asarray(a["row"]),
                                          np.asarray(b["row"]))

    def test_publish_phi_invalidates_by_version(self):
        algo, phi = _mlp_task()
        reqs = _requests(2, sizes=(4,))
        engine = ServingEngine(algo, phi, adapt_batch=2)
        engine.serve(reqs)
        assert all(r["hit"] for r in engine.serve(reqs).records)
        engine.publish_phi(phi)           # same φ, new version
        assert not any(r["hit"] for r in engine.serve(reqs).records)

    def test_support_digest_keys_content(self):
        rng = np.random.RandomState(0)
        a = _mlp_support(rng, 4)
        same = tuple(jnp.asarray(np.asarray(x)) for x in a)
        other = _mlp_support(rng, 4)
        assert support_digest(a) == support_digest(same)
        assert support_digest(a) != support_digest(other)
        assert support_digest(a) != support_digest(
            tuple(np.asarray(x, np.float64) for x in a))


# ---- traffic model -------------------------------------------------------

class TestTrafficModel:
    def test_same_seed_same_table(self):
        tm = dict(num_clients=8, rate=10.0, support_sizes=(2, 4),
                  think_time=0.05, hot_skew=1.2)
        t1 = TrafficModel(seed=5, **tm).arrival_table(40)
        t2 = TrafficModel(seed=5, **tm).arrival_table(40)
        assert t1 == t2
        assert TrafficModel(seed=6, **tm).arrival_table(40) != t1

    def test_content_stable_under_extension(self):
        """rid < 20 rows of a 40-request table equal the 20-request
        table's rows — per-field salted streams + causal flooring."""
        tm = TrafficModel(num_clients=8, think_time=0.03, seed=9)
        short = {row[0]: row for row in tm.arrival_table(20)}
        long = {row[0]: row for row in tm.arrival_table(40)}
        for rid, row in short.items():
            assert long[rid] == row

    def test_think_time_floor_per_client(self):
        tm = TrafficModel(num_clients=2, rate=100.0, think_time=0.5, seed=0)
        last = {}
        for _, client, t, _ in tm.arrival_table(30):
            if client in last:
                assert t - last[client] >= 0.5 - 1e-9
            last[client] = t

    def test_requests_independent_of_materialization(self):
        """Request payloads are stateless per (seed, client/rid): two
        materializations agree leaf-for-leaf, and a client's support
        set repeats across its requests (what makes caching work)."""
        tm = TrafficModel(num_clients=3, rate=50.0, seed=2)
        mk = lambda r, size: _mlp_support(r, size)
        mp = lambda r: jnp.asarray(r.randint(0, 100, (8,)), jnp.int32)
        r1 = tm.requests(12, mk, mp)
        r2 = tm.requests(12, mk, mp)
        by_client = {}
        for a, b in zip(r1, r2):
            assert (a.rid, a.client, a.arrival) == (b.rid, b.client, b.arrival)
            for x, y in zip(jax.tree.leaves((a.support, a.prompt)),
                            jax.tree.leaves((b.support, b.prompt))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            key = support_digest(a.support)
            assert by_client.setdefault(a.client, key) == key


# ---- decode equivalence --------------------------------------------------

class TestDecodeEquivalence:
    def test_batched_serve_matches_single_request_oracle(self):
        """Vmapped cross-request decode under per-request θ_u generates
        exactly the tokens of a one-request-at-a-time prefill+decode
        loop (reduced LM config)."""
        from repro.configs import get_config, reduced_config
        from repro.launch.serve import build_engine
        from repro.launch.steps import make_decode_step, make_prefill_step

        cfg = reduced_config(get_config("smollm-360m"))
        engine = build_engine(cfg, adapt_batch=2, seed=0)
        tm = TrafficModel(num_clients=3, rate=50.0, support_sizes=(2, 3),
                          seed=1)
        mk = lambda r, size: jnp.asarray(
            r.randint(0, cfg.vocab_size, (size, 32)), jnp.int32)
        mp = lambda r: jnp.asarray(
            r.randint(0, cfg.vocab_size, (12,)), jnp.int32)
        reqs = tm.requests(5, mk, mp)
        report = engine.serve(reqs, max_new_tokens=4)

        jprefill = jax.jit(make_prefill_step(cfg))
        jdecode = jax.jit(make_decode_step(cfg))
        plane = engine.plane
        jadapt = jax.jit(lambda p, s: plane.pack(engine.algo.adapt(p, s)))
        ordered = sorted(reqs, key=lambda q: (q.arrival, q.rid))
        for rec, req in zip(report.records, ordered):
            theta_u = plane.unpack(jadapt(engine._phi, req.support))
            logits, cache = jprefill(theta_u, req.prompt[None])
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            oracle = [int(tok[0])]
            for _ in range(3):
                logits, cache = jdecode(theta_u, cache, tok[:, None])
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                oracle.append(int(tok[0]))
            assert oracle == rec["tokens"].tolist()
            assert rec["decode_ms"] >= 0.0
        s = report.summary()
        assert s["requests"] == 5 and "decode_p50_ms" in s
