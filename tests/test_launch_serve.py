"""First-ever tests for the launch/serving stack: the
prefill -> adapt -> decode path on a reduced config, the engine
builders in `launch.serve`, the decode-attention `use_impl` scope, and
the example + launcher entry points as CI-runnable subprocesses."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.kernels.decode_attention import ops as dec_ops
from repro.launch.serve import build_engine, build_serving_fns

REPO = Path(__file__).resolve().parents[1]


def _env():
    env = dict(os.environ)
    src = str(REPO / "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    return env


class TestServingFns:
    def test_prefill_then_decode_shapes_and_cache(self):
        """The serve entry points compose: prefill yields last-position
        logits + a cache the decode step advances one token at a time."""
        cfg = reduced_config(get_config("smollm-360m"))
        from repro.models import init_lm
        params = init_lm(jax.random.PRNGKey(0), cfg)
        prefill, decode = build_serving_fns(cfg)
        rng = np.random.RandomState(0)
        B, L = 2, 16
        prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, L)),
                              jnp.int32)
        logits, cache = jax.jit(prefill)(params, prompts)
        assert logits.shape == (B, cfg.vocab_size)
        assert int(cache["length"]) == L
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits2, cache = jax.jit(decode)(params, cache, tok)
        assert logits2.shape == (B, cfg.vocab_size)
        assert int(cache["length"]) == L + 1

    def test_build_engine_serves_end_to_end(self):
        """build_engine wires algorithm + serve fns + cache into an
        engine that adapts and decodes (the example's path, inline)."""
        cfg = reduced_config(get_config("smollm-360m"))
        engine = build_engine(cfg, adapt_batch=2, cache_capacity=4, seed=0)
        from repro.federated.serving import TrafficModel
        tm = TrafficModel(num_clients=2, rate=50.0, support_sizes=(2,),
                          seed=0)
        reqs = tm.requests(
            3,
            lambda r, size: jnp.asarray(
                r.randint(0, cfg.vocab_size, (size, 16)), jnp.int32),
            lambda r: jnp.asarray(
                r.randint(0, cfg.vocab_size, (8,)), jnp.int32))
        report = engine.serve(reqs, max_new_tokens=2)
        s = report.summary()
        assert s["requests"] == 3
        assert s["hits"] + s["misses"] == 3
        for rec in report.records:
            assert rec["tokens"].shape == (2,)
            assert (0 <= rec["tokens"]).all()
            assert (rec["tokens"] < cfg.vocab_size).all()

    def test_use_impl_scopes_and_restores(self):
        prev = dec_ops._DEFAULT_IMPL
        with dec_ops.use_impl("pallas_interpret"):
            assert dec_ops._DEFAULT_IMPL == "pallas_interpret"
        assert dec_ops._DEFAULT_IMPL == prev
        try:
            with dec_ops.use_impl("xla"):
                raise RuntimeError("boom")
        except RuntimeError:
            pass
        assert dec_ops._DEFAULT_IMPL == prev    # restored on exception


class TestEntryPoints:
    def test_example_dry_run(self):
        """examples/serve_personalized.py --dry-run: the CI smoke for
        the full traffic -> adapt -> cache -> prefill -> decode path."""
        out = subprocess.run(
            [sys.executable, "examples/serve_personalized.py", "--dry-run",
             "--arch", "smollm-360m"],
            cwd=REPO, env=_env(), capture_output=True, text=True,
            timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "served 4 requests" in out.stdout
        assert "sample:" in out.stdout

    def test_launch_serve_reduced(self):
        """python -m repro.launch.serve --reduced: the decode launcher
        runs on the host mesh (covers the perf_counter step timing)."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "smollm-360m", "--shape", "decode_32k", "--steps", "2",
             "--reduced"],
            cwd=REPO, env=_env(), capture_output=True, text=True,
            timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "decode step 1" in out.stdout
