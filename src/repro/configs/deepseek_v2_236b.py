"""DeepSeek-V2 236B — MLA attention + fine-grained MoE.

Assigned spec: 60L d_model=5120 128H (kv=128) d_ff=1536 vocab=102400,
MoE 160 experts top-6, MLA kv_lora=512, 2 shared experts.
[arXiv:2405.04434] — first layer dense (d_ff 12288 in the release; we use
the assigned routed d_ff for all FFNs, shared experts = 2x routed width).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,                  # routed expert width
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_layer_period=1,
    first_k_dense=1,
    mlp_act="swiglu",
    source="arXiv:2405.04434",
)
