"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE.

Assigned spec: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16 experts top-2. [arXiv:2403.19887] — Jamba places one attention
layer per 8-layer block (1:7 attn:mamba ratio) and applies MoE every
other layer (16 experts, top-2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_layer_period=2,
    # one attention layer per 8 (position 4 of each block, as in the paper)
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state=128,
    ssm_heads=128,          # d_inner(8192) / ssm_head_dim(64)
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    conv_width=4,
    mlp_act="swiglu",
    source="arXiv:2403.19887",
)
