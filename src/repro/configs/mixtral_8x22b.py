"""Mixtral 8x22B — sparse MoE with sliding-window attention.

Assigned spec: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, SWA. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    moe_layer_period=1,      # every layer is MoE
    sliding_window=4096,     # Mixtral-style SWA
    rope_theta=1e6,
    mlp_act="swiglu",
    source="arXiv:2401.04088",
)
