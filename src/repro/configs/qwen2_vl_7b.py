"""Qwen2-VL 7B — VLM language backbone with M-RoPE.

Assigned spec: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064;
M-RoPE, dynamic resolution. [arXiv:2409.12191]
The ViT vision encoder + projector is a STUB per the assignment
carve-out: `input_specs()` supplies precomputed patch embeddings
(B, n_patches, d_model) with an (t, h, w) position grid for M-RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # t/h/w rotary sections (head_dim/2 = 64)
    rope_theta=1e6,
    modality="vision",
    num_modality_tokens=1024,      # image patches per example
    mlp_act="swiglu",
    source="arXiv:2409.12191",
)
