"""SmolLM 360M — llama-architecture small dense decoder.

Assigned spec: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M family card]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    mlp_act="swiglu",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
