"""Assigned input shapes and their mapping to entry points.

  train_4k     seq_len=4,096    global_batch=256   -> meta train_step
  prefill_32k  seq_len=32,768   global_batch=32    -> prefill_step
  decode_32k   seq_len=32,768   global_batch=128   -> decode_step (1 new
                                                      token, KV cache 32k)
  long_500k    seq_len=524,288  global_batch=1     -> decode_step, requires
                                                      sub-quadratic attention

For train_4k the global batch of 256 sequences is organized into the
FedMeta task structure: `clients_per_round` clients scanned sequentially,
each contributing `seqs_per_client` sequences (half support, half query),
with clients_per_round * seqs_per_client == global_batch.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"
    # FedMeta task structure (train shapes only)
    clients_per_round: int = 0
    seqs_per_client: int = 0     # support + query per client

    def __post_init__(self):
        if self.kind == "train":
            assert self.clients_per_round * self.seqs_per_client == self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train",
                           clients_per_round=8, seqs_per_client=32),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
