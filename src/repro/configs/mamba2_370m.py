"""Mamba2 370M — attention-free SSM with SSD (state-space duality).

Assigned spec: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                  # no MLP: mamba2 blocks only (as per spec)
    vocab_size=50280,
    layer_pattern=("mamba",),
    ssm_state=128,
    ssm_heads=32,            # d_inner(2048) / ssm_head_dim(64)
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    conv_width=4,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
