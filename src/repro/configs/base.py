"""Model configuration system.

Every assigned architecture is a `ModelConfig` (exact numbers from its
source paper / model card, cited in its config file). Configs are frozen
dataclasses; the registry maps arch ids (e.g. "jamba-v0.1-52b") to
factories. `reduced_config` produces the smoke-test variant (≤2 layers,
d_model ≤ 512, ≤ 4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # --- attention ---
    attention: str = "gqa"          # gqa | mla
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA window; None = full causal
    rope_theta: float = 1e4
    mrope: bool = False             # multimodal rotary (qwen2-vl)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_layer_period: int = 1       # layer i is MoE iff i % period == period-1
    first_k_dense: int = 0          # leading dense layers (deepseek-v2: 1)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (mamba2 / jamba) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4

    # --- layout ---
    layer_pattern: Tuple[str, ...] = ("attn",)  # repeating kinds per layer
    mlp_act: str = "swiglu"         # swiglu | relu2 | gelu
    tie_embeddings: bool = False

    # --- encoder-decoder (seamless-m4t) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- modality stub (vlm / audio) ---
    modality: Optional[str] = None  # "vision" | "audio"
    num_modality_tokens: int = 0    # patch/frame embeddings per example

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- distribution variants (perf levers; see EXPERIMENTS.md §Perf) ---
    moe_impl: str = "tp"            # "tp" (baseline) | "ep" (all-to-all)
    shard_seq: bool = False         # Megatron-style activation seq sharding

    # citation for the exact numbers
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert len(self.layer_pattern) >= 1
        assert self.num_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} must be a multiple of "
            f"the layer pattern period {len(self.layer_pattern)}")

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0 or i < self.first_k_dense:
            return False
        return i % self.moe_layer_period == self.moe_layer_period - 1


_ARCH_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "smollm-360m": "repro.configs.smollm_360m",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
}

ARCH_REGISTRY = dict(_ARCH_MODULES)  # id -> module path (resolved lazily)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIG


def list_archs() -> list:
    return sorted(_ARCH_MODULES)


def reduced_config(cfg: ModelConfig, *, seq_friendly: bool = True) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (2 layers, d_model<=512,
    <=4 experts, small vocab). Layer pattern is preserved by keeping one
    full pattern period when the family is hybrid."""
    period = len(cfg.layer_pattern)
    layers = period if period > 1 else 2
    d_model = min(cfg.d_model, 256)
    n_heads = max(2, min(cfg.num_heads, 4))
    head_dim = max(16, d_model // n_heads)
    n_kv = max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads else 0
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        first_k_dense=min(cfg.first_k_dense, 1 if layers > 1 else 0),
        kv_lora_rank=min(cfg.kv_lora_rank, 32),
        q_lora_rank=min(cfg.q_lora_rank, 32),
        rope_head_dim=min(cfg.rope_head_dim, 16),
        # keep ssm_heads * ssm_head_dim == ssm_expand * d_model
        ssm_heads=(cfg.ssm_expand * d_model // min(cfg.ssm_head_dim, 32)
                   if cfg.ssm_heads else 0),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=min(cfg.ssm_head_dim, 32) if cfg.ssm_heads else 0,
        ssm_chunk=16 if cfg.ssm_chunk else cfg.ssm_chunk,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        num_modality_tokens=min(cfg.num_modality_tokens, 8),
        sliding_window=(min(cfg.sliding_window, 64)
                        if cfg.sliding_window else cfg.sliding_window),
        mrope_sections=((head_dim // 4, head_dim // 8,
                         head_dim // 2 - head_dim // 4 - head_dim // 8)
                        if cfg.mrope else cfg.mrope_sections),
        dtype="float32",
        name=cfg.name + "-reduced",
    )
    return dataclasses.replace(cfg, **changes)
