"""SeamlessM4T-medium — encoder-decoder multimodal (audio) backbone.

Assigned spec: 12L d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096
vocab=256206; enc-dec, multimodal. [arXiv:2308.11596]
Interpreted as 12 encoder + 12 decoder layers (the M4T-medium text
backbone). The speech frontend (mel + conformer feature extractor) is a
STUB per the assignment carve-out: `input_specs()` supplies precomputed
frame embeddings of shape (B, T_frames, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,             # decoder layers
    num_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    modality="audio",
    num_modality_tokens=1024,  # audio frames consumed by the encoder
    mlp_act="gelu",
    source="arXiv:2308.11596",
)
