from repro.configs.base import ModelConfig, reduced_config, ARCH_REGISTRY, get_config, list_archs
from repro.configs.shapes import INPUT_SHAPES, InputShape
