"""Synthetic Sent140-like federated sentiment classification.

LEAF Sent140: each twitter user is a client; 2-class sentiment over
25-token tweets (paper Table 1: 3,790 clients, ~45 samples/client).

Generator: a global vocabulary where each word has a latent sentiment
score shared across all users (the learnable "language"); a tweet's label
is sign(sum of scores + user_bias). The per-client structure is chosen to
be *adaptation-learnable from a small support set* (not memorizable):
(a) a strong personal decision bias — one inner gradient step on the
support set shifts the output layer to capture it, and (b) a mild topical
skew over a broad word distribution so every client still exercises the
shared vocabulary. A single global model (FedAvg) cannot represent the
per-user bias; FedMeta's adapted models can — mirroring the paper's
motivation for personalization.
"""
from __future__ import annotations

import numpy as np

from repro.data.federated import ClientData, FederatedDataset


def _sent140_client(word_score, base, vocab, seq_len, mean_samples,
                    rng) -> ClientData:
    """One user's tweet shard — the per-client generator body."""
    # mild topical skew over a broad distribution (every client covers
    # the shared vocabulary; nothing is memorizable per client)
    topic = 0.5 * base + 0.5 * rng.dirichlet(np.ones(vocab) * 2.0)
    # strong, adaptation-learnable personal decision bias
    user_bias = rng.normal(0, 1.2)
    # small sarcasm subset (flipped polarity words)
    flip = np.ones(vocab, np.float32)
    n_flip = rng.randint(0, vocab // 20)
    flip[rng.choice(vocab, size=n_flip, replace=False)] = -1.0
    n = int(np.clip(rng.lognormal(np.log(mean_samples), 0.6), 10,
                    6 * mean_samples))
    xs = rng.choice(vocab, size=(n, seq_len), p=topic).astype(np.int32)
    score = ((word_score[xs] * flip[xs]).sum(axis=1) / np.sqrt(seq_len)
             + user_bias)
    ys = (score > 0).astype(np.int32)
    return ClientData(xs, ys)


def make_sent140(num_clients: int = 150, seq_len: int = 25,
                 vocab: int = 2000, mean_samples: int = 45,
                 seed: int = 0, *, lazy: bool = False,
                 independent: bool = False, cache_clients=None):
    """Eager dataset (default) or lazy `ClientRegistry` (see
    make_femnist for the lazy/independent semantics)."""
    rng = np.random.RandomState(seed)
    word_score = rng.normal(0, 1, size=vocab).astype(np.float32)
    base = np.ones(vocab) / vocab

    def body(r):
        return _sent140_client(word_score, base, vocab, seq_len,
                               mean_samples, r)

    if lazy:
        from repro.data.registry import registry_from_body
        return registry_from_body(body, num_clients, 2, "synth-sent140",
                                  rng=rng, seed=seed,
                                  independent=independent,
                                  cache_clients=cache_clients)
    clients = [body(rng) for _ in range(num_clients)]
    return FederatedDataset(clients, 2, name="synth-sent140")
