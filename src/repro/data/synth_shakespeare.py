"""Synthetic Shakespeare-like federated next-character prediction.

LEAF Shakespeare: each speaking role is a client; next-char prediction over
a ~70-symbol vocabulary with 80-char contexts (paper Table 1 / A.1:
528 clients, ~1183 samples/client with huge σ, 2–70 classes/client).

Generator: a global order-1 Markov chain over the vocabulary (shared
"language"), with a per-client *role voice*: a client-specific sparse
perturbation of the transition matrix plus a preferred-symbol subset.
Local adaptation captures the voice; a global model captures only the
average chain — giving FedMeta the same advantage the paper exploits.

Each example is (context[seq_len] int32, next_char int32).
"""
from __future__ import annotations

import numpy as np

from repro.data.federated import ClientData, FederatedDataset

VOCAB = 70


def _row_normalize(m):
    return m / m.sum(axis=1, keepdims=True)


def make_shakespeare(num_clients: int = 60, seq_len: int = 40,
                     mean_samples: int = 300, vocab: int = VOCAB,
                     seed: int = 0, *, lazy: bool = False,
                     independent: bool = False, cache_clients=None):
    rng = np.random.RandomState(seed)
    # global language: peaked Markov chain (natural text is highly
    # predictable per-char; a flat chain caps top-1 accuracy ~14% which is
    # unrepresentative). Each char has 2-4 likely successors with ~85% of
    # the mass -> order-1 Bayes ceiling ~45%, comparable to real
    # Shakespeare next-char accuracy.
    base = rng.gamma(0.05, 1.0, size=(vocab, vocab)) + 1e-4
    for r in range(vocab):
        k = rng.randint(2, 5)
        peaks = rng.choice(vocab, size=k, replace=False)
        base[r, peaks] += rng.dirichlet(np.ones(k)) * 6.0
    base = _row_normalize(base)

    def body(r):
        return _shakespeare_client(base, vocab, seq_len, mean_samples, r)

    if lazy:
        from repro.data.registry import registry_from_body
        return registry_from_body(body, num_clients, vocab,
                                  "synth-shakespeare", rng=rng, seed=seed,
                                  independent=independent,
                                  cache_clients=cache_clients)
    clients = [body(rng) for _ in range(num_clients)]
    return FederatedDataset(clients, vocab, name="synth-shakespeare")


def _shakespeare_client(base, vocab, seq_len, mean_samples,
                        rng) -> ClientData:
    """One role's line shard — the per-client generator body."""
    # role voice: boost a random subset of transitions
    voice = base.copy()
    k = rng.randint(5, 20)
    rows = rng.randint(0, vocab, size=k)
    cols = rng.randint(0, vocab, size=k)
    voice[rows, cols] += rng.uniform(2.0, 6.0, size=k)
    voice = _row_normalize(voice)
    n = int(np.clip(rng.lognormal(np.log(mean_samples), 0.8), 20, 8 * mean_samples))
    # sample one long stream then slice contexts
    stream = np.zeros(n + seq_len + 1, np.int32)
    stream[0] = rng.randint(vocab)
    cdf = np.cumsum(voice, axis=1)
    u = rng.random_sample(n + seq_len)
    for t in range(1, n + seq_len + 1):
        stream[t] = np.searchsorted(cdf[stream[t - 1]], u[t - 1])
    xs = np.stack([stream[i:i + seq_len] for i in range(n)])
    ys = stream[seq_len:seq_len + n]
    return ClientData(xs.astype(np.int32), ys.astype(np.int32))
