"""Federated dataset abstraction.

A federated dataset is a list of clients, each holding local (x, y)
arrays. Every client doubles as a meta-learning *task*: its data is split
into a disjoint support set (inner/local training) and query set
(evaluation / meta-gradient), following the paper's evaluation scheme
(§4.1): 80/10/10 client split into train/val/test clients, and a support
fraction p per client.

All sampling is deterministic given seeds, and batches are padded to fixed
shapes so the training step jits once.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np


@dataclasses.dataclass
class ClientData:
    x: np.ndarray  # (n, ...) features
    y: np.ndarray  # (n,) int labels

    @property
    def n(self) -> int:
        return len(self.y)


class TaskBatch(NamedTuple):
    """A fixed-shape batch of m client tasks (jit-friendly)."""
    support_x: np.ndarray  # (m, S, ...)
    support_y: np.ndarray  # (m, S)
    query_x: np.ndarray    # (m, Q, ...)
    query_y: np.ndarray    # (m, Q)
    # weights for weighted server aggregation (∝ #local examples, paper A.2)
    weight: np.ndarray     # (m,)
    # true per-client query-set sizes *before* the fixed-shape resample —
    # the §4.1 "accuracy w.r.t. all data points" evaluation weights each
    # client by how many query examples it actually holds
    query_count: np.ndarray = None  # (m,) int
    # the picked client indices behind the m rows — the error-feedback
    # residual plane is addressed by these (DESIGN.md §17). Recorded
    # from a draw the sampler already makes, so adding it changes no
    # sampling stream. None when the batch wasn't drawn by picks
    # (population-plane assembly).
    client_idx: np.ndarray = None  # (m,) int


@dataclasses.dataclass
class FederatedDataset:
    clients: list[ClientData]
    num_classes: int
    name: str = "federated"

    def __post_init__(self):
        assert len(self.clients) > 0

    def split_clients(self, seed: int = 0,
                      fractions: Sequence[float] = (0.8, 0.1, 0.1)):
        """80/10/10 train/val/test split over *clients* (paper §4.1)."""
        rng = np.random.RandomState(seed)
        idx = rng.permutation(len(self.clients))
        n = len(idx)
        n_train = int(fractions[0] * n)
        n_val = int(fractions[1] * n)
        train = [self.clients[i] for i in idx[:n_train]]
        val = [self.clients[i] for i in idx[n_train:n_train + n_val]]
        test = [self.clients[i] for i in idx[n_train + n_val:]]
        return train, val, test

    def view(self, transform, num_classes: int | None = None,
             name: str | None = None) -> "FederatedDataset":
        """A per-client re-labelled/re-featured view of the same dataset.

        ``transform(client) -> ClientData`` runs independently on every
        client and MUST preserve client order and per-client example
        counts ``n``. Under that contract a view consumes *identical*
        seeded sampling streams to the original (`sample_task_batch`
        draws depend only on client count and per-client ``n``), which is
        what lets the scenario plane (DESIGN.md §13) run FedMeta on a
        local-label view and FedAvg on the global view of one dataset
        while keeping the shared-stream discipline of DESIGN.md §11.
        """
        clients = []
        for c in self.clients:
            t = transform(c)
            if t.n != c.n:
                raise ValueError("view transform must preserve client "
                                 f"sizes (got {t.n}, want {c.n})")
            clients.append(t)
        return FederatedDataset(clients, num_classes or self.num_classes,
                                name=name or self.name)

    def stats(self) -> dict:
        ns = np.array([c.n for c in self.clients])
        classes = np.array([len(np.unique(c.y)) for c in self.clients])
        return {
            "clients": len(self.clients),
            "samples": int(ns.sum()),
            "classes": self.num_classes,
            "samples_per_client_mean": float(ns.mean()),
            "samples_per_client_std": float(ns.std()),
            "classes_per_client_min": int(classes.min()),
            "classes_per_client_max": int(classes.max()),
        }


def support_query_split(client: ClientData, support_frac: float,
                        rng: np.random.RandomState):
    """Disjoint support/query split of one client's local data."""
    n = client.n
    perm = rng.permutation(n)
    n_sup = max(1, min(n - 1, int(round(support_frac * n))))
    sup = perm[:n_sup]
    qry = perm[n_sup:]
    return (client.x[sup], client.y[sup]), (client.x[qry], client.y[qry])


def _resample_to(x: np.ndarray, y: np.ndarray, size: int,
                 rng: np.random.RandomState):
    """Fixed-size batch from a variable-size set (sample w/ replacement
    when short, subsample when long) — keeps jit shapes static."""
    n = len(y)
    if n >= size:
        idx = rng.choice(n, size=size, replace=False)
    else:
        idx = rng.choice(n, size=size, replace=True)
    return x[idx], y[idx]


@dataclasses.dataclass
class TaskStream:
    """The task-sampling stream one trainer consumes: exactly one
    `sample_task_batch` per `next()`, drawn from the trainer's seeded
    `RandomState` with the call pattern every driver shares (one batch
    per round). This is the object the async engine's prefetcher owns:
    it is advanced *sequentially* — on a single background thread when
    prefetching — so the sequence of batches is identical to the
    synchronous loop's, which is what makes pipelined runs bit-identical
    to synchronous ones under a fixed seed."""
    clients: list
    m: int
    support_frac: float
    support_size: int
    query_size: int
    rng: np.random.RandomState

    def next(self) -> TaskBatch:
        return sample_task_batch(self.clients, self.m, self.support_frac,
                                 self.support_size, self.query_size, self.rng)

    def take(self, k: int) -> list[TaskBatch]:
        return [self.next() for _ in range(k)]


def stack_task_batches(tbs: Sequence[TaskBatch]) -> TaskBatch:
    """k TaskBatches -> one TaskBatch with a leading (k,) round axis on
    every field — the stacked buffer the fused-K round mode scans over.
    Optional fields that any batch leaves as None stay None."""
    def stk(f):
        vals = [getattr(tb, f) for tb in tbs]
        return None if any(v is None for v in vals) else np.stack(vals)

    return TaskBatch(*(stk(f) for f in TaskBatch._fields))


def sample_task_batch(clients: list[ClientData], m: int, support_frac: float,
                      support_size: int, query_size: int,
                      rng: np.random.RandomState) -> TaskBatch:
    """Sample m clients uniformly and build a fixed-shape TaskBatch."""
    picks = rng.choice(len(clients), size=m, replace=len(clients) < m)
    sx, sy, qx, qy, w, qc = [], [], [], [], [], []
    for ci in picks:
        c = clients[ci]
        (a, b), (p, q) = support_query_split(c, support_frac, rng)
        qc.append(len(q))
        a, b = _resample_to(a, b, support_size, rng)
        p, q = _resample_to(p, q, query_size, rng)
        sx.append(a); sy.append(b); qx.append(p); qy.append(q)
        w.append(c.n)
    w = np.asarray(w, np.float32)
    return TaskBatch(np.stack(sx), np.stack(sy), np.stack(qx), np.stack(qy),
                     w / w.sum(), np.asarray(qc, np.int64),
                     np.asarray(picks, np.int64))


def assemble_task_batch(shards, m: int, support_frac: float,
                        support_size: int, query_size: int,
                        rng: np.random.RandomState,
                        weighted: bool = True, probe=None) -> TaskBatch:
    """Fixed-shape TaskBatch from pre-picked *arrived* client shards,
    zero-weight padded to ``m`` rows (the population plane's partial
    round, DESIGN.md §15).

    The first ``len(shards)`` rows are the arrived clients in arrival
    order, weighted by data count (or uniformly with ``weighted=False``)
    and renormalized over the arrived set; the remaining rows are copies
    of row 0 with weight 0 — `masked_mean` aggregation (Σ w·g / Σ w over
    w > 0 rows) makes them exact no-ops in-graph, and the weighted
    metrics reduction ignores them for the same reason. An empty arrived
    set (``probe`` supplies the row shapes — any client of the same
    dataset) yields an all-zero weight vector: the step's weight
    normalization then goes non-finite and the guard skips the round —
    the designed all-candidates-failed behavior.
    """
    a = len(shards)
    if a > m:
        raise ValueError(f"need at most {m} arrived shards, got {a}")
    if a == 0 and probe is None:
        raise ValueError("empty arrived set needs a shape probe client")
    sx, sy, qx, qy, w, qc = [], [], [], [], [], []
    for c in (shards if a else [probe]):
        (s_x, s_y), (q_x, q_y) = support_query_split(c, support_frac, rng)
        qc.append(len(q_y))
        s_x, s_y = _resample_to(s_x, s_y, support_size, rng)
        q_x, q_y = _resample_to(q_x, q_y, query_size, rng)
        sx.append(s_x); sy.append(s_y); qx.append(q_x); qy.append(q_y)
        w.append(c.n if weighted else 1.0)
    if a == 0:                   # probe row is itself a zero-weight pad
        w[0] = 0.0; qc[0] = 0
    for _ in range(m - max(a, 1)):  # zero-weight pads (copies of row 0)
        sx.append(sx[0]); sy.append(sy[0])
        qx.append(qx[0]); qy.append(qy[0])
        w.append(0.0); qc.append(0)
    w = np.asarray(w, np.float32)
    s = w.sum()
    return TaskBatch(np.stack(sx), np.stack(sy), np.stack(qx), np.stack(qy),
                     w / s if s > 0 else w, np.asarray(qc, np.int64))
