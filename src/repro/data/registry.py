"""Lazy client registry: bounded-memory federated populations.

`FederatedDataset` materializes every client up front — fine at the
repo's experiment scales (~10^2 clients), hopeless at the paper's
deployment scale ("distributed networks of mobile devices", §1) where
populations are 10^5–10^6 devices. `ClientRegistry` is the same
Sequence-of-clients contract (`len()`, integer indexing — everything
`sample_task_batch` and the evaluators consume) with clients
synthesized *on demand* from a per-client source and held in a bounded
LRU host cache, so resident memory is O(cache) instead of O(population).

Three sources:

  * `SequentialClientSource` — replays the eager generator's single
    sequential `RandomState`: construction runs the generator once to
    snapshot the rng state *before* each client (discarding the
    arrays), and `get(i)` re-runs client i's body from its snapshot.
    Every draw is the one the eager loop made, so a lazy dataset in
    this mode is **bit-identical** to `FederatedDataset` at any scale
    you could have materialized eagerly. Cost: one full generation
    pass at construction plus ~2.5 KB of rng state per client — the
    bit-identity mode for current scales, not the 10^6 mode.
  * `IndependentClientSource` — seeds client i's rng O(1) from
    `SeedSequence((seed, i))`: no construction pass, no per-client
    state, arbitrary population sizes. The draws differ from the
    sequential stream (there is no eager baseline at these scales to
    be identical to); statistics match because the body is the same.
  * `ShardIndexSource` — loads `client_%08d.npz` shards from an
    on-disk index directory written by `save_shards` (the
    pre-partitioned-corpus deployment shape).

`split_clients` / `view` mirror the eager dataset: splits are
`RegistryView`s (index views — nothing materializes), and views apply
an order/size-preserving per-client transform lazily with the same
n-preservation check `FederatedDataset.view` enforces.

Thread safety: `__getitem__` is safe under concurrent access (the
worker pool materializes shards from K threads); an in-flight map
ensures a client is synthesized once even when K workers race for it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from collections import OrderedDict
from typing import Callable, Optional, Sequence

import numpy as np

from repro.data.federated import ClientData, FederatedDataset


def _seeded_rng(*entropy) -> np.random.RandomState:
    """O(1) per-client RandomState from a SeedSequence entropy tuple."""
    return np.random.RandomState(
        np.random.MT19937(np.random.SeedSequence(entropy)))


class SequentialClientSource:
    """Bit-identical lazy source: per-client rng-state snapshots of the
    eager generator's sequential stream (see module docstring)."""

    def __init__(self, body: Callable, num_clients: int,
                 rng: np.random.RandomState, warm: Callable = None):
        self._body = body
        self.num_clients = num_clients
        self._snaps = []
        for i in range(num_clients):
            self._snaps.append(rng.get_state())
            c = body(rng)          # advance the stream exactly as eager
            if warm is not None:
                warm(i, c)         # don't waste the construction pass

    def get(self, i: int) -> ClientData:
        # the constructor's OS-entropy state is dead on arrival:
        # set_state installs client i's snapshot before any draw
        # repro-lint: disable=rng-unseeded (state replaced by set_state)
        rng = np.random.RandomState()
        rng.set_state(self._snaps[i])
        return self._body(rng)


class IndependentClientSource:
    """O(1) lazy source: client i's rng is seeded from
    `SeedSequence((seed, i))` — no construction pass, 10^5–10^6 scale.
    Not bit-identical to the eager sequential stream (documented)."""

    def __init__(self, body: Callable, num_clients: int, seed: int):
        self._body = body
        self.num_clients = num_clients
        self.seed = seed

    def get(self, i: int) -> ClientData:
        return self._body(_seeded_rng(self.seed, i))


class ShardIndexSource:
    """On-disk shard index: `client_%08d.npz` files + `index.json`
    written by `save_shards`."""

    def __init__(self, shard_dir: str):
        self.shard_dir = shard_dir
        with open(os.path.join(shard_dir, "index.json")) as f:
            self.index = json.load(f)
        self.num_clients = int(self.index["num_clients"])

    def get(self, i: int) -> ClientData:
        path = os.path.join(self.shard_dir, f"client_{i:08d}.npz")
        with np.load(path) as z:
            return ClientData(z["x"], z["y"])


class ClientRegistry:
    """Lazy client population behind a bounded, thread-safe LRU cache.

    Sequence protocol: ``len(reg)`` and ``reg[i]`` (negative indices
    and slices work; a slice is a `RegistryView`, nothing materializes).
    ``cache_clients=None`` means unbounded (every touched client stays
    resident — the eager-equivalent memory mode); an integer bounds the
    resident set and `cache_stats()["peak_resident"]` proves it.

    Lock-order contract (audited with `async_engine.WorkerPool`, whose
    docstring states the full pool↔registry ordering): ``self._lock``
    is a **leaf** lock guarding only the cache dict, the in-flight map
    and the counters. It is never held across a blocking call — the
    in-flight ``Event.wait`` in ``__getitem__`` and the
    ``source.get(i)`` synthesis both run with the lock released, so a
    worker synthesizing client i can always reach ``_insert`` (which
    re-acquires the lock to publish and ``set()`` the Event). Holding
    the lock around either would strand every waiter of that Event —
    the inversion the ``thread-lock-order`` lint rule exists to catch.
    """

    def __init__(self, source, num_classes: int, name: str = "registry",
                 cache_clients: Optional[int] = None):
        if cache_clients is not None and cache_clients < 1:
            raise ValueError("cache_clients must be >= 1 (or None)")
        self._source = source
        self.num_classes = num_classes
        self.name = name
        self.cache_clients = cache_clients
        self._cache: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self._inflight: dict = {}
        self._hits = self._misses = self._evictions = 0
        self._peak = 0

    def __len__(self) -> int:
        return self._source.num_clients

    def __getitem__(self, i):
        if isinstance(i, slice):
            return RegistryView(self, range(*i.indices(len(self))))
        i = int(i)
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(i)
        while True:
            with self._lock:
                if i in self._cache:
                    self._hits += 1
                    self._cache.move_to_end(i)
                    return self._cache[i]
                ev = self._inflight.get(i)
                if ev is None:
                    self._inflight[i] = threading.Event()
                    self._misses += 1
                    break
            ev.wait()          # another thread is synthesizing client i
        try:
            c = self._source.get(i)
        except BaseException:
            with self._lock:
                self._inflight.pop(i).set()
            raise
        self._insert(i, c)
        return c

    def _insert(self, i: int, c: ClientData):
        with self._lock:
            self._cache[i] = c
            self._cache.move_to_end(i)
            cap = self.cache_clients
            while cap is not None and len(self._cache) > cap:
                self._cache.popitem(last=False)
                self._evictions += 1
            self._peak = max(self._peak, len(self._cache))
            ev = self._inflight.pop(i, None)
            if ev is not None:
                ev.set()

    def cache_stats(self) -> dict:
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "resident": len(self._cache),
                    "peak_resident": self._peak,
                    "cache_clients": self.cache_clients}

    def split_clients(self, seed: int = 0,
                      fractions: Sequence[float] = (0.8, 0.1, 0.1)):
        """Same 80/10/10 permutation math as `FederatedDataset` — the
        SAME seed yields the same client-index split, as index views."""
        rng = np.random.RandomState(seed)
        idx = rng.permutation(len(self))
        n = len(idx)
        n_train = int(fractions[0] * n)
        n_val = int(fractions[1] * n)
        return (RegistryView(self, idx[:n_train].tolist()),
                RegistryView(self, idx[n_train:n_train + n_val].tolist()),
                RegistryView(self, idx[n_train + n_val:].tolist()))

    def view(self, transform, num_classes: Optional[int] = None,
             name: Optional[str] = None) -> "RegistryView":
        """Lazy analogue of `FederatedDataset.view`: the transform runs
        per access, under the same n-preservation contract."""
        return RegistryView(self, range(len(self)), transform=transform,
                            num_classes=num_classes or self.num_classes,
                            name=name or self.name)

    def materialize(self) -> FederatedDataset:
        """Eager snapshot (small populations / tests only)."""
        return FederatedDataset([self[i] for i in range(len(self))],
                                self.num_classes, name=self.name)

    def stats(self, max_clients: Optional[int] = None) -> dict:
        """`FederatedDataset.stats` over the first ``max_clients``
        clients (None = all — materializes the population once)."""
        k = len(self) if max_clients is None else min(max_clients,
                                                     len(self))
        ns = np.array([self[i].n for i in range(k)])
        classes = np.array([len(np.unique(self[i].y)) for i in range(k)])
        return {
            "clients": len(self), "sampled": k,
            "samples": int(ns.sum()), "classes": self.num_classes,
            "samples_per_client_mean": float(ns.mean()),
            "samples_per_client_std": float(ns.std()),
            "classes_per_client_min": int(classes.min()),
            "classes_per_client_max": int(classes.max()),
        }


class RegistryView:
    """Index (+ optional transform) view of a `ClientRegistry` — the
    lazy analogue of the eager split/view lists. Sequence protocol;
    composes (`view` of a view chains transforms)."""

    def __init__(self, base, indices, transform=None,
                 num_classes: Optional[int] = None,
                 name: Optional[str] = None):
        self._base = base
        self._indices = list(indices)
        self._transform = transform
        self.num_classes = num_classes or base.num_classes
        self.name = name or getattr(base, "name", "registry-view")

    def __len__(self) -> int:
        return len(self._indices)

    def __getitem__(self, j):
        if isinstance(j, slice):
            return RegistryView(self._base, self._indices[j],
                                transform=self._transform,
                                num_classes=self.num_classes,
                                name=self.name)
        c = self._base[self._indices[j]]
        if self._transform is not None:
            t = self._transform(c)
            if t.n != c.n:
                raise ValueError("view transform must preserve client "
                                 f"sizes (got {t.n}, want {c.n})")
            return t
        return c

    def view(self, transform, num_classes: Optional[int] = None,
             name: Optional[str] = None) -> "RegistryView":
        prev = self._transform

        def chained(c):
            if prev is not None:
                t = prev(c)
                if t.n != c.n:
                    raise ValueError("view transform must preserve "
                                     f"client sizes (got {t.n}, want "
                                     f"{c.n})")
                c = t
            return transform(c)

        return RegistryView(self._base, self._indices, transform=chained,
                            num_classes=num_classes or self.num_classes,
                            name=name or self.name)


def registry_from_body(body: Callable, num_clients: int, num_classes: int,
                       name: str, *, rng: np.random.RandomState = None,
                       seed: int = 0, independent: bool = False,
                       cache_clients: Optional[int] = None
                       ) -> ClientRegistry:
    """A `ClientRegistry` over a per-client generator body
    ``body(rng) -> ClientData``.

    ``independent=False`` (default) consumes ``rng`` sequentially for
    bit-identity with the eager generator (the construction pass also
    warms the cache, so small populations pay generation once, not
    twice); ``independent=True`` seeds each client O(1) from ``seed``.
    """
    reg_ref: list = [None]

    if independent:
        src = IndependentClientSource(body, num_clients, seed)
        reg = ClientRegistry(src, num_classes, name=name,
                             cache_clients=cache_clients)
    else:
        if rng is None:
            raise ValueError("sequential registry needs the generator's "
                             "rng (independent=False)")

        def warm(i, c):
            if reg_ref[0] is not None:
                reg_ref[0]._insert(i, c)

        reg = ClientRegistry.__new__(ClientRegistry)
        # init the cache machinery first so the construction pass can
        # warm it through the same bounded insert path
        ClientRegistry.__init__(
            reg, None, num_classes, name=name, cache_clients=cache_clients)
        reg_ref[0] = reg
        reg._source = SequentialClientSource(body, num_clients, rng,
                                             warm=warm)
    return reg


def save_shards(clients, out_dir: str, num_classes: int,
                name: str = "shards") -> str:
    """Write a Sequence of clients as an on-disk shard index
    (`client_%08d.npz` + `index.json`); returns the index path."""
    os.makedirs(out_dir, exist_ok=True)
    n = len(clients)
    for i in range(n):
        c = clients[i]
        np.savez(os.path.join(out_dir, f"client_{i:08d}.npz"),
                 x=c.x, y=c.y)
    path = os.path.join(out_dir, "index.json")
    with open(path, "w") as f:
        json.dump({"num_clients": n, "num_classes": num_classes,
                   "name": name}, f)
    return path


def load_shard_registry(shard_dir: str,
                        cache_clients: Optional[int] = None
                        ) -> ClientRegistry:
    """Open an on-disk shard index as a lazy `ClientRegistry`."""
    src = ShardIndexSource(shard_dir)
    return ClientRegistry(src, int(src.index["num_classes"]),
                          name=str(src.index.get("name", "shards")),
                          cache_clients=cache_clients)
