from repro.data.federated import (ClientData, FederatedDataset, TaskBatch,
                                  TaskStream, assemble_task_batch,
                                  sample_task_batch, stack_task_batches)
from repro.data.registry import (ClientRegistry, IndependentClientSource,
                                 RegistryView, SequentialClientSource,
                                 ShardIndexSource, load_shard_registry,
                                 registry_from_body, save_shards)
from repro.data.synth_femnist import make_femnist
from repro.data.synth_shakespeare import make_shakespeare
from repro.data.synth_sent140 import make_sent140
from repro.data.synth_recommend import (localize_clients, localize_recommend,
                                        make_recommend)
from repro.data.lm_tasks import make_lm_clients, make_lm_task_batch
