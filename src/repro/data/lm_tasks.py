"""Per-client language-model personalization tasks.

Bridges FedMeta to the assigned LM architectures: each client is a task
whose private corpus is a "dialect" of a shared synthetic language — a
client-specific permutation applied to a slice of the vocabulary plus a
client-specific topic mixture. The meta-learner trains an initialization
that adapts to a new client's dialect in a few inner steps.

Two entry points:
  * `make_lm_task_batch` — a fixed-shape `LMTaskBatch` for the direct
    LM examples / smoke tests (the dry-run uses ShapeDtypeStructs from
    configs.shapes instead — no allocation);
  * `make_lm_clients` — the same dialect generator behind the
    `FederatedDataset` / `TaskStream` interface, so LM personalization
    runs through the scenario plane's `run_comparison` like any other
    workload (DESIGN.md §13): each client's corpus is its local data,
    support/query splits and seeded sampling come from
    `data/federated.py`, and `core/losses.lm_pair_loss` adapts the
    next-token objective to the (x, y) task convention.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class LMTaskBatch(NamedTuple):
    support_tokens: np.ndarray  # (m, S, L) int32
    query_tokens: np.ndarray    # (m, Q, L) int32


def _sample_stream(rng, length, vocab, trans_sparsity=0.2):
    # cheap order-1 chain via per-token candidate jumps
    stream = np.zeros(length, np.int32)
    stream[0] = rng.randint(vocab)
    jumps = rng.randint(0, vocab, size=length)
    stay = rng.random_sample(length) < trans_sparsity
    for t in range(1, length):
        stream[t] = (stream[t - 1] + 1) % vocab if stay[t] else jumps[t]
    return stream


def make_lm_task_batch(num_clients: int, support_seqs: int, query_seqs: int,
                       seq_len: int, vocab: int, seed: int = 0) -> LMTaskBatch:
    """Fixed-shape batch of per-client token tasks."""
    rng = np.random.RandomState(seed)
    sup = np.zeros((num_clients, support_seqs, seq_len), np.int32)
    qry = np.zeros((num_clients, query_seqs, seq_len), np.int32)
    for c in range(num_clients):
        # client dialect: permutation of a vocab slice
        perm = np.arange(vocab)
        sl = rng.choice(vocab, size=max(2, vocab // 8), replace=False)
        perm[sl] = rng.permutation(sl)
        for i in range(support_seqs):
            sup[c, i] = perm[_sample_stream(rng, seq_len, vocab)]
        for i in range(query_seqs):
            qry[c, i] = perm[_sample_stream(rng, seq_len, vocab)]
    return LMTaskBatch(sup, qry)


def _dialect_perm(rng, vocab):
    """A client dialect: permutation of a random slice of the vocab."""
    perm = np.arange(vocab)
    sl = rng.choice(vocab, size=max(2, vocab // 8), replace=False)
    perm[sl] = rng.permutation(sl)
    return perm


def _lm_client(mean_seqs, seq_len, vocab, rng):
    """One client's dialect corpus — the per-client generator body."""
    from repro.data.federated import ClientData
    perm = _dialect_perm(rng, vocab)
    n = mean_seqs + rng.randint(mean_seqs)
    seqs = np.stack([perm[_sample_stream(rng, seq_len, vocab)]
                     for _ in range(n)]).astype(np.int32)
    return ClientData(seqs, seqs[:, -1].copy())


def make_lm_clients(num_clients: int = 32, mean_seqs: int = 24,
                    seq_len: int = 16, vocab: int = 64, seed: int = 0,
                    *, lazy: bool = False, independent: bool = False,
                    cache_clients=None):
    """Per-client dialect corpora as a `FederatedDataset`.

    Each client holds ``n`` token sequences of its own dialect as local
    data: ``x`` is the (n, seq_len) int32 token matrix, ``y`` is the
    final token of each sequence (a stand-in label — `lm_pair_loss`
    trains on the shifted sequence itself and never reads y, but the
    federated batch plumbing carries (x, y) pairs). ``n`` varies per
    client in [mean_seqs, 2*mean_seqs) so data-count weighting and true
    query counts are exercised like every other dataset.

    ``lazy=True`` returns a `ClientRegistry` over the same body (see
    data/registry.py for the sequential/independent semantics).
    """
    from repro.data.federated import FederatedDataset
    rng = np.random.RandomState(seed)

    def body(r):
        return _lm_client(mean_seqs, seq_len, vocab, r)

    if lazy:
        from repro.data.registry import registry_from_body
        return registry_from_body(body, num_clients, vocab,
                                  "synth-lm-dialects", rng=rng, seed=seed,
                                  independent=independent,
                                  cache_clients=cache_clients)
    clients = [body(rng) for _ in range(num_clients)]
    return FederatedDataset(clients, vocab, name="synth-lm-dialects")
