"""Per-client language-model personalization tasks.

Bridges FedMeta to the assigned LM architectures: each client is a task
whose private corpus is a "dialect" of a shared synthetic language — a
client-specific permutation applied to a slice of the vocabulary plus a
client-specific topic mixture. The meta-learner trains an initialization
that adapts to a new client's dialect in a few inner steps.

Used by the end-to-end LM examples and smoke tests; the dry-run uses
ShapeDtypeStructs from configs.shapes instead (no allocation).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class LMTaskBatch(NamedTuple):
    support_tokens: np.ndarray  # (m, S, L) int32
    query_tokens: np.ndarray    # (m, Q, L) int32


def _sample_stream(rng, length, vocab, trans_sparsity=0.2):
    # cheap order-1 chain via per-token candidate jumps
    stream = np.zeros(length, np.int32)
    stream[0] = rng.randint(vocab)
    jumps = rng.randint(0, vocab, size=length)
    stay = rng.random_sample(length) < trans_sparsity
    for t in range(1, length):
        stream[t] = (stream[t - 1] + 1) % vocab if stay[t] else jumps[t]
    return stream


def make_lm_task_batch(num_clients: int, support_seqs: int, query_seqs: int,
                       seq_len: int, vocab: int, seed: int = 0) -> LMTaskBatch:
    """Fixed-shape batch of per-client token tasks."""
    rng = np.random.RandomState(seed)
    sup = np.zeros((num_clients, support_seqs, seq_len), np.int32)
    qry = np.zeros((num_clients, query_seqs, seq_len), np.int32)
    for c in range(num_clients):
        # client dialect: permutation of a vocab slice
        perm = np.arange(vocab)
        sl = rng.choice(vocab, size=max(2, vocab // 8), replace=False)
        perm[sl] = rng.permutation(sl)
        for i in range(support_seqs):
            sup[c, i] = perm[_sample_stream(rng, seq_len, vocab)]
        for i in range(query_seqs):
            qry[c, i] = perm[_sample_stream(rng, seq_len, vocab)]
    return LMTaskBatch(sup, qry)
