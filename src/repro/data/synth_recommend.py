"""Synthetic production-like federated recommendation dataset.

Paper §4.3 / Table 1: 9,369 clients, 6.4M usage records, 2,400 services,
each client uses 2–36 services with 100–5,000 records; features are a
103-dim encoding of (service, user, context). Task: predict the next
service (top-k recommendation, cast as classification over the client's
services; the paper uses a 40-way local classifier instead of a 2420-way
global one — the key FedMeta size argument).

Generator (scaled): `num_services` global services; each client uses a
small subset with a personal context->service preference: the label
depends on context features through a client-specific linear map over a
shared low-rank structure — so meta-learned initializations adapt fast.

Feature layout (dim = ctx_dim + num_services):
  [context features | one-hot of last-used service]
Label: global service id (models may project to a local head).
"""
from __future__ import annotations

import numpy as np

from repro.data.federated import ClientData, FederatedDataset


def _recommend_client(U, V, num_services, ctx_dim, mean_records, rank,
                      rng) -> ClientData:
    """One user's usage-record shard — the per-client generator body."""
    feat_dim = ctx_dim + num_services
    k = rng.randint(2, 37)  # 2..36 services per client (paper)
    services = rng.choice(num_services, size=k, replace=False)
    # personal taste: client-specific mixing in the shared rank space
    taste = rng.normal(0, 1, size=(rank,)).astype(np.float32)
    n = int(np.clip(rng.lognormal(np.log(mean_records), 0.5), 30,
                    10 * mean_records))
    ctx = rng.normal(0, 1, size=(n, ctx_dim)).astype(np.float32)
    # affinity over this client's services only
    logits = (ctx @ U * taste) @ V[:, services]  # (n, k)
    # markov-ish: also condition on last service via a recency boost
    ys_local = np.zeros(n, np.int64)
    last = rng.randint(k)
    for i in range(n):
        l = logits[i].copy()
        l[last] += 1.0  # recency
        p = np.exp(l - l.max()); p /= p.sum()
        ys_local[i] = rng.choice(k, p=p)
        last = ys_local[i]
    ys = services[ys_local]
    x = np.zeros((n, feat_dim), np.float32)
    x[:, :ctx_dim] = ctx
    lasts = np.concatenate([[services[rng.randint(k)]], ys[:-1]])
    x[np.arange(n), ctx_dim + lasts] = 1.0
    return ClientData(x, ys.astype(np.int32))


def make_recommend(num_clients: int = 200, num_services: int = 120,
                   ctx_dim: int = 24, mean_records: int = 160,
                   rank: int = 8, seed: int = 0, *, lazy: bool = False,
                   independent: bool = False, cache_clients=None):
    rng = np.random.RandomState(seed)
    # shared low-rank structure: context -> service affinity
    U = rng.normal(0, 1, size=(ctx_dim, rank)).astype(np.float32)
    V = rng.normal(0, 1, size=(rank, num_services)).astype(np.float32)

    def body(r):
        return _recommend_client(U, V, num_services, ctx_dim,
                                 mean_records, rank, r)

    if lazy:
        from repro.data.registry import registry_from_body
        return registry_from_body(body, num_clients, num_services,
                                  "synth-recommend", rng=rng, seed=seed,
                                  independent=independent,
                                  cache_clients=cache_clients)
    clients = [body(rng) for _ in range(num_clients)]
    return FederatedDataset(clients, num_services, name="synth-recommend")


def _localize_one(c: ClientData, head_size: int) -> ClientData:
    """One client's labels remapped to local ids 0..k-1 (rank of the
    service in the client's sorted service set); features untouched."""
    services = np.unique(c.y)
    if len(services) > head_size:
        raise ValueError(f"client uses {len(services)} services > "
                         f"head_size {head_size}")
    lut = np.full(int(c.y.max()) + 1, -1, c.y.dtype)
    lut[services] = np.arange(len(services), dtype=c.y.dtype)
    return ClientData(c.x, lut[c.y])


def localize_clients(clients, head_size: int = 40):
    """Global service labels -> per-client local-head labels (paper §4.3).

    The paper's FedMeta recommender trains a ~40-way classifier over THE
    CLIENT'S OWN services instead of FedAvg's 2420-way classifier over the
    global catalogue — the model-size asymmetry behind its Table-3 bytes
    advantage. This produces that local view: each client's labels are
    remapped to local ids ``0..k-1`` (a mapping the client can build
    offline from its own history), so a ``head_size``-way model covers
    every client.

    Client order, features, and example counts are preserved, keeping
    seeded sampling streams identical to the global view (the §11 shared-
    stream discipline). Raises if any client uses more than ``head_size``
    services.

    Polymorphic over eager and lazy populations: a list materializes
    the localized view; a `ClientRegistry`/`RegistryView` gets a lazy
    transform view (the remap runs per access, nothing materializes).
    """
    from repro.data.registry import ClientRegistry, RegistryView
    if isinstance(clients, (ClientRegistry, RegistryView)):
        return clients.view(lambda c: _localize_one(c, head_size),
                            num_classes=head_size)
    return [_localize_one(c, head_size) for c in clients]


def localize_recommend(ds: FederatedDataset,
                       head_size: int = 40) -> FederatedDataset:
    """`localize_clients` as a whole-dataset view (num_classes becomes
    the head size), through `FederatedDataset.view`'s order/size
    contract check."""
    return ds.view(lambda c: _localize_one(c, head_size),
                   num_classes=head_size, name=ds.name + "-local")
