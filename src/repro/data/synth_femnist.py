"""Synthetic FEMNIST-like federated image dataset.

LEAF's FEMNIST partitions 62-class handwritten characters by *writer*;
statistics (paper Table 1): 1,068 clients, ~220 samples/client (σ≈90),
9–62 classes per client. This generator reproduces the structure without
the raw data (offline container):

- each class has a global prototype image (smooth random blob pattern),
- each *writer* (client) applies a personal style: a fixed affine warp +
  stroke-thickness bias + per-writer contrast, shared across all of that
  writer's samples — so per-client adaptation genuinely helps,
- per-client class subsets are skewed (Dirichlet over classes, truncated),
- samples-per-client is lognormal, matching a heavy-ish tail.

Images are (H, W) float32 in [0, 1]; default 28x28 like FEMNIST.
"""
from __future__ import annotations

import numpy as np

from repro.data.federated import ClientData, FederatedDataset


def _class_prototypes(num_classes: int, size: int, rng: np.random.RandomState):
    """Smooth random patterns: low-freq Fourier blobs per class."""
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    protos = np.zeros((num_classes, size, size), np.float32)
    for c in range(num_classes):
        img = np.zeros((size, size), np.float32)
        for _ in range(4):
            fx, fy = rng.uniform(1, 4, size=2)
            px, py = rng.uniform(0, 2 * np.pi, size=2)
            amp = rng.uniform(0.5, 1.0)
            img += amp * np.sin(2 * np.pi * fx * xx + px) * np.sin(2 * np.pi * fy * yy + py)
        img = (img - img.min()) / (np.ptp(img) + 1e-6)
        protos[c] = img
    return protos


def _affine_warp(img: np.ndarray, theta: float, shear: float, scale: float):
    """Nearest-neighbour affine warp about the image centre (pure numpy)."""
    size = img.shape[0]
    c = (size - 1) / 2.0
    ct, st = np.cos(theta), np.sin(theta)
    # inverse transform sampling
    a = np.array([[ct, -st + shear], [st, ct]], np.float32) / scale
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    ys = a[0, 0] * (yy - c) + a[0, 1] * (xx - c) + c
    xs = a[1, 0] * (yy - c) + a[1, 1] * (xx - c) + c
    ys = np.clip(np.round(ys).astype(int), 0, size - 1)
    xs = np.clip(np.round(xs).astype(int), 0, size - 1)
    return img[ys, xs]


def _femnist_client(protos, num_classes, image_size, mean_samples,
                    rng) -> ClientData:
    """One writer's local shard — the per-client generator body (same
    draw sequence the eager loop always made)."""
    # writer style (fixed per client)
    theta = rng.uniform(-0.5, 0.5)
    shear = rng.uniform(-0.3, 0.3)
    scale = rng.uniform(0.8, 1.2)
    contrast = rng.uniform(0.7, 1.3)
    bias = rng.uniform(-0.1, 0.1)
    # skewed class subset: between ~15% and 100% of classes
    k = rng.randint(max(2, num_classes // 7), num_classes + 1)
    classes = rng.choice(num_classes, size=k, replace=False)
    pvals = rng.dirichlet(np.ones(k) * 0.5)
    n = int(np.clip(rng.lognormal(np.log(mean_samples), 0.4), 8, 4 * mean_samples))
    ys = classes[rng.choice(k, size=n, p=pvals)]
    xs = np.zeros((n, image_size, image_size), np.float32)
    for i, y in enumerate(ys):
        img = _affine_warp(protos[y], theta, shear, scale)
        img = np.clip(contrast * img + bias + rng.normal(0, 0.15, img.shape), 0, 1)
        xs[i] = img
    return ClientData(xs.astype(np.float32), ys.astype(np.int32))


def make_femnist(num_clients: int = 120, num_classes: int = 62,
                 image_size: int = 28, mean_samples: int = 80,
                 seed: int = 0, *, lazy: bool = False,
                 independent: bool = False, cache_clients=None):
    """Eager `FederatedDataset` (default) or, with ``lazy=True``, a
    `ClientRegistry` over the same generator body: sequential mode
    (``independent=False``) is bit-identical to eager; independent mode
    seeds clients O(1) for 10^5+ populations (data/registry.py)."""
    rng = np.random.RandomState(seed)
    protos = _class_prototypes(num_classes, image_size, rng)

    def body(r):
        return _femnist_client(protos, num_classes, image_size,
                               mean_samples, r)

    if lazy:
        from repro.data.registry import registry_from_body
        return registry_from_body(body, num_clients, num_classes,
                                  "synth-femnist", rng=rng, seed=seed,
                                  independent=independent,
                                  cache_clients=cache_clients)
    clients = [body(rng) for _ in range(num_clients)]
    return FederatedDataset(clients, num_classes, name="synth-femnist")
