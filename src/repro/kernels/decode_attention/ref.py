"""Pure-jnp oracle for one-token decode attention over a KV cache."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, kv_length):
    """q: (B, H, hd); k_cache/v_cache: (B, C, Kv, hd); kv_length: () or
    (B,) valid cache slots. Returns (B, H, hd); softmax in f32."""
    B, H, hd = q.shape
    _, C, Kv, _ = k_cache.shape
    G = H // Kv
    qf = q.astype(jnp.float32).reshape(B, Kv, G, hd)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bjkd->bkgj", qf, kf) / np.sqrt(hd)
    kvl = jnp.asarray(kv_length)
    mask = jnp.arange(C)[None, :] < (kvl[:, None] if kvl.ndim else kvl)
    if mask.ndim == 1:
        mask = mask[None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgj,bjkd->bkgd", p, vf)
    return o.reshape(B, H, hd).astype(q.dtype)
