"""Dispatcher for one-token decode attention.

impl: "xla" (oracle; default), "pallas", "pallas_interpret".
"""
from __future__ import annotations

import contextlib
import os

import jax.numpy as jnp

from repro.kernels.decode_attention import ref
from repro.kernels.decode_attention.flash_decode import flash_decode

_DEFAULT_IMPL = os.environ.get("REPRO_DECODE_ATTN_IMPL", "xla")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


@contextlib.contextmanager
def use_impl(impl: str):
    """Scoped default-impl override (restores on exit). The impl is
    baked in at *trace* time: wrap the first call of a jitted serve
    fn, not later replays of an already-compiled executable."""
    global _DEFAULT_IMPL
    prev = _DEFAULT_IMPL
    set_default_impl(impl)
    try:
        yield
    finally:
        _DEFAULT_IMPL = prev


def decode_attention(q, k_cache, v_cache, kv_length, *, impl=None,
                     block_k: int = 512):
    """q: (B, H, hd); caches: (B, C, Kv, hd); kv_length: () or (B,)."""
    impl = impl or _DEFAULT_IMPL
    kvl = jnp.broadcast_to(jnp.asarray(kv_length), (q.shape[0],))
    if impl == "xla":
        return ref.decode_attention_ref(q, k_cache, v_cache, kvl)
    return flash_decode(q, k_cache, v_cache, kvl, block_k=block_k,
                        interpret=(impl == "pallas_interpret"))
