"""Pallas TPU flash-decode kernel: one-token attention over a KV cache.

Decode is latency-bound on cache reads (§Perf H1); this kernel is the
VMEM-streamed counterpart of the serve path:
  - grid (B, Kv, nc): cache length is the innermost (sequential) axis,
    (m, l, acc) online-softmax carries live in VMEM scratch — the cache
    streams HBM->VMEM exactly once, in bf16, with the f32 upcast done
    per-tile in registers (the XLA path materializes an f32 cache copy),
  - GQA packing: all G = H/Kv query heads of one kv head are processed
    together as a (G, hd) tile — one cache read serves G heads
    (MXU matmul (G, hd) x (hd, bk)),
  - kv_length masks invalid slots (ring caches are position-free; see
    models/attention.py gqa_decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(kvl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, bk: int, nc: int,
                         scale: float):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    kvl = kvl_ref[0]                                 # () valid length

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ci * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kvl, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])
    p = jnp.exp(s - m_new[:, :1])
    l_scr[...] = jnp.broadcast_to(
        alpha * l_scr[...][:, :1] + jnp.sum(p, axis=-1, keepdims=True),
        l_scr.shape)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ci == nc - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...][:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, kv_length, *, block_k: int = 512,
                 interpret: bool = False):
    """q: (B, H, hd); caches: (B, C, Kv, hd); kv_length: (B,) int32.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, C, Kv, _ = k_cache.shape
    assert H % Kv == 0
    G = H // Kv
    bk = min(block_k, C)
    assert C % bk == 0, (C, bk)
    nc = C // bk
    # layouts: q -> (B, Kv, G, hd); caches -> (B, Kv, C, hd)
    qt = q.reshape(B, Kv, G, hd)
    kt = jnp.swapaxes(k_cache, 1, 2)
    vt = jnp.swapaxes(v_cache, 1, 2)

    kernel = functools.partial(_flash_decode_kernel, bk=bk, nc=nc,
                               scale=1.0 / (hd ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(B, Kv, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_length.astype(jnp.int32), qt, kt, vt)
    return out.reshape(B, H, hd)
