"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) scan.

Two references:
  - `ssd_sequential`: the exact O(L) recurrence (ground truth),
  - `ssd_chunked_ref`: the chunked SSD algorithm in plain jnp (the
    algorithm the Pallas kernel implements; equal to sequential up to
    float error).

Shapes (ngroups = 1):
  x:  (B, L, nh, hp)   per-head inputs
  dt: (B, L, nh)       softplus-activated step sizes
  A:  (nh,)            negative decay rates
  Bm: (B, L, N)        input projection (shared across heads)
  Cm: (B, L, N)        output projection
Returns y: (B, L, nh, hp)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential(x, dt, A, Bm, Cm):
    B, L, nh, hp = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # (B,nh,hp),(B,nh),(B,N),(B,N)
        decay = jnp.exp(dtt * Af[None])           # (B, nh)
        state = (state * decay[..., None, None]
                 + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt))
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((B, nh, hp, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def ssd_chunked_ref(x, dt, A, Bm, Cm, chunk: int, return_final_state: bool = False):
    B, L, nh, hp = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xf = x.astype(jnp.float32).reshape(B, nc, chunk, nh, hp)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, nh)
    Bf = Bm.astype(jnp.float32).reshape(B, nc, chunk, N)
    Cf = Cm.astype(jnp.float32).reshape(B, nc, chunk, N)
    Af = A.astype(jnp.float32)

    a = dtf * Af[None, None, None]                 # (B,nc,Q,nh) log-decay
    cum = jnp.cumsum(a, axis=2)                    # inclusive cumsum
    xdt = xf * dtf[..., None]

    # ---- intra-chunk (quadratic within chunk)
    # decay(i,j) = exp(cum_i - cum_j) for j <= i  (uses inclusive cumsums:
    # product of decays in (j, i])
    di = cum[:, :, :, None, :]                     # (B,nc,Q,1,nh)
    dj = cum[:, :, None, :, :]                     # (B,nc,1,Q,nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    dmat = jnp.exp(di - dj) * tri[None, None, :, :, None]
    cb = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)     # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, dmat, xdt)

    # ---- chunk states: S_c = sum_j exp(cum_last - cum_j) B_j (x dt)_j
    last = cum[:, :, -1:, :]                       # (B,nc,1,nh)
    sdecay = jnp.exp(last - cum)                   # (B,nc,Q,nh)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bf, sdecay, xdt)

    # ---- inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(last[:, :, 0, :])        # (B,nc,nh)

    def combine(prev, cur):
        S_prev, _ = prev
        S_c, dec = cur
        return S_c + S_prev * dec[..., None, None], dec

    def scan_step(S_prev, inp):
        S_c, dec = inp
        S_in = S_prev                              # state entering the chunk
        S_out = S_c + S_prev * dec[..., None, None]
        return S_out, S_in

    S0 = jnp.zeros((B, nh, hp, N), jnp.float32)
    (S_final, S_in) = jax.lax.scan(scan_step, S0,
                                   (jnp.moveaxis(S, 1, 0),
                                    jnp.moveaxis(chunk_decay, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                # (B,nc,nh,hp,N)

    # ---- inter-chunk output: y_inter[i] = exp(cum_i) C_i . S_in
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cf, jnp.exp(cum), S_in)

    y = (y_intra + y_inter).reshape(B, L, nh, hp).astype(x.dtype)
    if return_final_state:
        return y, S_final                          # (B, nh, hp, N)
    return y
