"""Pallas TPU kernel for the Mamba2 SSD intra-chunk compute.

Per grid step (b, h, c) the kernel holds one (Q, hp) chunk of inputs and
one (Q, N) chunk of B/C projections in VMEM and produces:
  - y_intra: the within-chunk quadratic term ((C B^T) ⊙ decay) @ (x·dt),
  - S_c:     the chunk's contribution to the running state (N, hp).
Both are MXU matmuls of shape (Q,Q)x(Q,hp) and (N,Q)x(Q,hp); Q and N are
chosen 128-aligned. The sequential inter-chunk recurrence (a tiny
(nh,hp,N) state per step) stays in XLA — it is O(nc) with trivial FLOPs,
while >99% of SSD FLOPs live in this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *, Q: int):
    xdt = xdt_ref[0, 0].astype(jnp.float32)        # (Q, hp)
    a = a_ref[0, 0].astype(jnp.float32)            # (Q, 1) log-decay steps
    Bm = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (Q, N)

    cum = jnp.cumsum(a[:, 0])                      # inclusive (Q,)
    # intra-chunk decay matrix exp(cum_i - cum_j), lower-triangular
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    dmat = jnp.where(jj <= ii, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(cb * dmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, hp)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    sdecay = jnp.exp(cum[-1] - cum)                # (Q,)
    S = jax.lax.dot_general(Bm * sdecay[:, None], xdt,
                            (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (N, hp)
    s_ref[0, 0, 0] = S.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk_pallas(xdt, a, Bm, Cm, *, chunk: int,
                           interpret: bool = False):
    """xdt: (B, nh, L, hp); a: (B, nh, L, 1); Bm/Cm: (B, L, N).

    Returns y_intra (B, nh, L, hp) float32 and S (B, nh, nc, N, hp) f32.
    """
    B, nh, L, hp = xdt.shape
    N = Bm.shape[-1]
    assert L % chunk == 0
    nc = L // chunk
    Bm_c = Bm.reshape(B, nc, chunk, N)
    Cm_c = Cm.reshape(B, nc, chunk, N)

    kernel = functools.partial(_ssd_chunk_kernel, Q=chunk)
    y, S = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, N, hp), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, L, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, nc, N, hp), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, a, Bm_c, Cm_c)
    return y, S
