"""Jitted wrapper for the SSD scan: Pallas intra-chunk kernel + XLA
inter-chunk recurrence, with the pure-jnp chunked oracle as fallback.

impl: "xla" (default; used on CPU and in the dry-run), "pallas",
"pallas_interpret". Default from REPRO_SSD_IMPL env var.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ref
from repro.kernels.ssd.ssd_scan import ssd_intra_chunk_pallas

_DEFAULT_IMPL = os.environ.get("REPRO_SSD_IMPL", "xla")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, impl: str | None = None,
                return_final_state: bool = False):
    """SSD over (B, L, nh, hp) inputs; see kernels/ssd/ref.py for shapes.

    With return_final_state, also returns the (B, nh, hp, N) state after
    the last token (for prefill -> decode handoff)."""
    impl = impl or _DEFAULT_IMPL
    if impl == "xla":
        return ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk,
                                   return_final_state=return_final_state)

    B, L, nh, hp = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    dtf = dt.astype(jnp.float32)
    a = (dtf * A.astype(jnp.float32)[None, None, :])          # (B,L,nh)
    xdt = (x.astype(jnp.float32) * dtf[..., None])            # (B,L,nh,hp)

    # layout for the kernel: (B, nh, L, ·)
    xdt_t = jnp.moveaxis(xdt, 2, 1)                           # (B,nh,L,hp)
    a_t = jnp.moveaxis(a, 2, 1)[..., None]                    # (B,nh,L,1)
    y_intra, S = ssd_intra_chunk_pallas(
        xdt_t, a_t, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
        chunk=chunk, interpret=(impl == "pallas_interpret"))
    # S: (B, nh, nc, N, hp)

    # ---- inter-chunk recurrence (XLA; tiny state, O(nc) steps)
    cum = jnp.cumsum(a_t[..., 0].reshape(B, nh, nc, chunk), axis=-1)
    chunk_decay = jnp.exp(cum[..., -1])                       # (B,nh,nc)

    def scan_step(S_prev, inp):
        S_c, dec = inp                                        # (B,nh,N,hp),(B,nh)
        S_in = S_prev
        S_out = S_c + S_prev * dec[..., None, None]
        return S_out, S_in

    S0 = jnp.zeros((B, nh, N, hp), jnp.float32)
    S_final, S_in = jax.lax.scan(scan_step, S0,
                                 (jnp.moveaxis(S, 2, 0),
                                  jnp.moveaxis(chunk_decay, 2, 0)))
    S_in = jnp.moveaxis(S_in, 0, 2)                           # (B,nh,nc,N,hp)

    # ---- inter-chunk output: y_inter[i] = exp(cum_i) C_i . S_in
    Cm_c = Cm.astype(jnp.float32).reshape(B, nc, chunk, N)
    y_inter = jnp.einsum("bcin,bhcnp,bhci->bhcip",
                         Cm_c, S_in, jnp.exp(cum))
    y = y_intra.reshape(B, nh, nc, chunk, hp) + y_inter
    y = jnp.moveaxis(y.reshape(B, nh, L, hp), 1, 2).astype(x.dtype)
    if return_final_state:
        return y, jnp.swapaxes(S_final, -1, -2)               # (B,nh,hp,N)
    return y


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """Single-token recurrent update.

    state: (B, nh, hp, N); x: (B, nh, hp); dt: (B, nh); Bm/Cm: (B, N).
    Returns (y (B, nh, hp), new_state).
    """
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None])
    state = (state * decay[..., None, None]
             + jnp.einsum("bh,bn,bhp->bhpn", dtf, Bm.astype(jnp.float32),
                          x.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    return y.astype(x.dtype), state
