"""Pure-jnp oracle for the fused inner update θ' = θ − α ∘ g.

α is a per-coordinate learning-rate pytree (Meta-SGD) or a python scalar
(MAML). This is the paper's Algorithm 1 line "θ_u ← θ − α ∘ ∇L(θ)".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def inner_update_plane_ref(theta, alpha, grads):
    """Flat oracle for the client-plane inner update: θ − α∘g over
    (C, N) (or (N,)) buffers with α a scalar, (N,), or (C, N)."""
    return (theta.astype(jnp.float32)
            - jnp.asarray(alpha, jnp.float32) * grads.astype(jnp.float32)
            ).astype(theta.dtype)


def meta_update_ref(theta, alpha, grads):
    if isinstance(alpha, (int, float)):
        return jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - alpha * g.astype(jnp.float32)).astype(p.dtype),
            theta, grads)
    return jax.tree.map(
        lambda p, a, g: (p.astype(jnp.float32)
                         - a.astype(jnp.float32) * g.astype(jnp.float32)
                         ).astype(p.dtype),
        theta, alpha, grads)
