"""Compression codecs for the packed (m, N) client-gradient block
(DESIGN.md §17): the bytes-on-the-wire plane.

The paper's headline is communication cost; beyond the bf16 block
(`block_dtype`), two classic codecs shrink the *upload* leg further,
both operating on the same (m, N) block the fused aggregation kernel
already consumes, so encode → aggregate stays single fused passes over
flat memory:

  * **int8 per-row-scaled quantization** — each client row is scaled by
    ``s_u = max|g_u| / 127`` and rounded to int8. The wire format is
    ``N`` int8 payload + one f32 scale per client. Dequantization
    *fuses into the existing weighted-aggregate kernel*: the kernel
    casts each row to f32 and multiplies by its scalar weight, so
    feeding it the int8 block with combined weights ``w_u · s_u``
    computes Σ w_u·s_u·q_u = Σ w_u·ĝ_u in the same single sweep — no
    dequantized (m, N) f32 block ever materializes.
  * **top-k sparsification** — each row transmits its k
    largest-magnitude coordinates as (index, value) pairs; values may
    additionally be cast to the block dtype. The selection runs as one
    XLA ``lax.top_k`` over |G| (a per-row sort network is out of scope
    for the pallas plane — documented, not hidden); the
    dequantize-and-aggregate half scatters the pairs back to a dense
    block and reuses the fused weighted-aggregate kernel.

Both codecs are lossy, so the round's quantization error must not be
*lost*: with **error feedback** (Seide et al.; Karimireddy et al.) each
train client carries a residual e_u in server-side client state, the
encoder compresses g_u + e_u, and the new residual
e_u' = (g_u + e_u) − decode(encode(g_u + e_u)) is carried to that
client's next participating round. Residuals telescope: the sum of
dequantized uploads plus the final residual equals the sum of true
(corrected) gradients — pinned in tests/test_compression.py. The int8
encode kernel therefore emits the quantized block AND the residual
block in one pass.

Every kernel has a pure-jnp oracle beside it (`*_ref`), following the
aggregate.py idiom; the package-level `pallas-missing-ref` contract is
carried by meta_update/ref.py + ops.py as before.

TPU note: int8 native tiling is (32, 128) sublanes × lanes; the plane's
ALIGN guarantees 8-row multiples only, so on real TPUs the int8 block
may be relayed out — the interpret path (CPU CI) and the byte
accounting are unaffected, and the padded N is itself 1024-aligned.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update.aggregate import (_SLAB_BUDGET_ELEMS,
                                                 weighted_aggregate_flat)
from repro.kernels.meta_update.fused import LANE, SUBLANE, choose_block_rows

CODECS = ("int8", "topk")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Upload-compression spec for the packed pipeline.

    codec           "int8" (per-row-scaled 8-bit) or "topk" (magnitude
                    sparsification).
    topk_frac       fraction of the REAL (unpadded) parameter count each
                    client transmits under "topk" (k = max(1, round(
                    topk_frac · n_real))).
    error_feedback  carry per-client residuals in train state (on by
                    default — both codecs are biased without it).

    Frozen and asdict-serializable, so a plan's artifact records its
    exact codec (the FaultConfig pattern).
    """
    codec: str = "int8"
    topk_frac: float = 0.05
    error_feedback: bool = True

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; expected one "
                             f"of {CODECS}")
        if not (0.0 < self.topk_frac <= 1.0):
            raise ValueError(f"topk_frac must be in (0, 1], got "
                             f"{self.topk_frac}")

    def label(self) -> str:
        """Short codec tag for comm summaries/artifacts ("int8+ef",
        "topk0.05", ...)."""
        base = ("int8" if self.codec == "int8"
                else f"topk{self.topk_frac:g}")
        return base + ("+ef" if self.error_feedback else "")

    def k_for(self, n_real: int) -> int:
        """Per-client transmitted coordinate count under "topk"."""
        return max(1, int(round(self.topk_frac * n_real)))

    def upload_bytes(self, n_real: int, val_itemsize: int = 4) -> int:
        """True transmitted bytes for ONE client's upload (§17 rules):
        payload + side information, over the REAL parameter count (the
        plane's alignment padding is a server-side artifact — zeros are
        never put on the wire).

          int8: n_real × 1 B payload + 4 B (one f32 row scale)
          topk: k × (4 B int32 index + val_itemsize B value)

        ``val_itemsize`` is the top-k value dtype's width — the block
        dtype when the pipeline runs a reduced-precision block.
        """
        if self.codec == "int8":
            return n_real + 4
        return self.k_for(n_real) * (4 + int(val_itemsize))


# ---- int8 per-row-scaled quantization -----------------------------------

def int8_scales(G) -> jnp.ndarray:
    """(m, N) block -> (m,) f32 per-row scales max|g_u| / 127 (one XLA
    row reduce; an all-zero row gets scale 0 and quantizes to zeros)."""
    return jnp.max(jnp.abs(G.astype(jnp.float32)), axis=1) / 127.0


def _int8_encode_kernel(s_ref, g_ref, q_ref, r_ref):
    """One (m, rows, 128) slab: quantize every client row against its
    SMEM scalar scale and emit the residual g − s·q in the same pass —
    the error-feedback state never needs a separate decode sweep."""
    m = g_ref.shape[0]

    def body(u, _):
        g = g_ref[u, :, :].astype(jnp.float32)
        s = s_ref[u]
        inv = jnp.where(s > 0, 1.0 / jnp.maximum(s, 1e-30), 0.0)
        q = jnp.clip(jnp.round(g * inv), -127.0, 127.0)
        q_ref[u, :, :] = q.astype(jnp.int8)
        r_ref[u, :, :] = g - s * q
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_encode_flat(G, *, interpret: bool = False):
    """(m, N) f32 block -> (q int8, scales f32 (m,), resid f32) in one
    fused pass per slab (quantize + residual)."""
    m, N = G.shape
    assert N % (SUBLANE * LANE) == 0, N
    scales = int8_scales(G)
    total_rows = N // LANE
    # slab + two same-shape outputs resident in VMEM -> third the budget
    max_rows = max(SUBLANE, _SLAB_BUDGET_ELEMS // (LANE * max(3 * m, 1)))
    rows = choose_block_rows(total_rows, max_rows=max_rows)
    n_tiles = total_rows // rows

    q, resid = pl.pallas_call(
        _int8_encode_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, rows, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m, rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((m, rows, LANE), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, total_rows, LANE), jnp.int8),
            jax.ShapeDtypeStruct((m, total_rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(scales, G.astype(jnp.float32).reshape(m, total_rows, LANE))
    return q.reshape(m, N), scales, resid.reshape(m, N)


def int8_encode_ref(G):
    """Pure-jnp oracle of ``int8_encode_flat`` (identical arithmetic:
    scale, reciprocal, round-half-even, clip, residual)."""
    g = G.astype(jnp.float32)
    scales = int8_scales(g)
    inv = jnp.where(scales > 0, 1.0 / jnp.maximum(scales, 1e-30), 0.0)
    q = jnp.clip(jnp.round(g * inv[:, None]), -127.0, 127.0)
    resid = g - scales[:, None] * q
    return q.astype(jnp.int8), scales, resid


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_aggregate_flat(q, scales, w, *, interpret: bool = False):
    """Dequantize-and-aggregate, fused: Σ_u w_u·s_u·q_u through the
    existing weighted-aggregate kernel. The kernel casts each int8 row
    to f32 and multiplies by its SMEM scalar weight, so folding the
    dequantization scale into the weight makes dequant + reduce ONE
    sweep over the int8 block — 4× less memory traffic than aggregating
    a dequantized f32 block."""
    return weighted_aggregate_flat(
        q, w.astype(jnp.float32) * scales, interpret=interpret)


def int8_aggregate_ref(q, scales, w):
    """Oracle: (w ∘ s) @ q in f32. (`weighted_aggregate_ref` casts the
    weights to the BLOCK dtype — int8 here — so the codec needs its own
    oracle with the combined weights kept in f32.)"""
    return jax.lax.dot_general(
        w.astype(jnp.float32) * scales, q.astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def int8_row_norms(q, scales) -> jnp.ndarray:
    """L2 norm of each DECODED row: s_u·‖q_u‖ — a per-row reduce over
    the int8 block (no dequantized block), exact for the decoded values
    the server actually aggregates. Feeds the DP clip (§17: clip is
    computed in the codec domain, after decode)."""
    sq = jnp.sum(jnp.square(q.astype(jnp.float32)), axis=1)
    return scales * jnp.sqrt(sq)


# ---- top-k sparsification -----------------------------------------------

def topk_encode(G, k: int, val_dtype=jnp.float32):
    """(m, N) block -> ((m, k) values, (m, k) int32 indices, (m, N) f32
    residual). Selection is per-row magnitude top-k via ``lax.top_k``
    (ties broken toward the lower index, deterministically). Values are
    cast to ``val_dtype`` BEFORE the residual is computed, so the
    residual absorbs the cast error too — error feedback sees exactly
    what the wire carries."""
    g = G.astype(jnp.float32)
    m = g.shape[0]
    _, idx = jax.lax.top_k(jnp.abs(g), k)
    idx = idx.astype(jnp.int32)
    vals = jnp.take_along_axis(g, idx, axis=1).astype(val_dtype)
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    resid = g.at[rows, idx].add(-vals.astype(jnp.float32))
    return vals, idx, resid


def topk_densify(vals, idx, n: int):
    """Scatter (m, k) pairs back to the dense (m, n) f32 block the
    fused aggregation kernel consumes (decode half of the codec)."""
    m = vals.shape[0]
    rows = jnp.arange(m, dtype=jnp.int32)[:, None]
    dense = jnp.zeros((m, n), jnp.float32)
    return dense.at[rows, idx].add(vals.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def topk_aggregate_flat(vals, idx, w, n: int, *, interpret: bool = False):
    """Decode-and-aggregate: scatter to dense (one XLA scatter), then
    the fused weighted-aggregate kernel reduces (m, n) -> (n,)."""
    return weighted_aggregate_flat(topk_densify(vals, idx, n), w,
                                   interpret=interpret)


def topk_aggregate_ref(vals, idx, w, n: int):
    """Oracle: direct weighted scatter-add into the (n,) output —
    never materializes the dense block, so kernel-vs-oracle parity
    also cross-checks the densify step."""
    m, k = vals.shape
    wv = (w.astype(jnp.float32)[:, None] * vals.astype(jnp.float32))
    return jnp.zeros((n,), jnp.float32).at[idx.reshape(-1)].add(
        wv.reshape(-1))


def topk_row_norms(vals) -> jnp.ndarray:
    """L2 norm of each decoded row = ‖transmitted values‖ (all other
    coordinates decode to zero) — the DP clip's per-row reduction in
    the codec domain."""
    return jnp.sqrt(jnp.sum(jnp.square(vals.astype(jnp.float32)), axis=1))
