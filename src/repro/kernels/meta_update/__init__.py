from repro.kernels.meta_update.ops import meta_update
