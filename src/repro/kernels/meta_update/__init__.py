from repro.kernels.meta_update.ops import (get_default_impl, inner_update,
                                           meta_update, set_default_impl,
                                           weighted_aggregate)
