"""Pallas TPU kernels for fused client-gradient aggregation.

The server step reduces the (m, N) block of packed per-client
meta-gradients to the (N,) meta-gradient g = Σ_u w_u · g_u (paper A.2
weights by local data count). Per-leaf XLA emits one broadcast-multiply
plus reduce per tensor; this kernel makes one sweep over the block —
each grid step streams an (m, block_rows, 128) slab through VMEM,
accumulates the weighted sum across the client axis, and writes one
(block_rows, 128) output tile. Weights live in SMEM and are read as
scalars inside the client loop.

On top of the plain weighted mean, the failure plane (DESIGN.md §14)
adds three robust reductions over the same (m, N) block, each with a
pure-jnp reference oracle:

  * ``masked_mean_flat`` — dropout-masked renormalizing weighted mean:
    Σ w g / Σ w, so zero-weight (dropped) rows renormalize over the
    rows that actually arrived. An all-dropped round divides 0/0 and
    surfaces as NaN for the engine's non-finite guard to skip.
  * ``screened_aggregate_flat`` — per-row L2-norm screening: non-finite
    and dropped rows are rejected outright, rows whose norm exceeds
    ``factor ×`` the live-row median are clipped down to the threshold
    (clipping a row by c is identical to scaling its aggregation weight
    by c, so the reduce reuses the plain weighted kernel), and the
    result renormalizes over the *unclipped* live weights.
  * ``trimmed_mean_flat`` — coordinate-wise trimmed mean: per coordinate,
    drop the ``trim`` largest and ``trim`` smallest live values and
    average the rest — the classic Byzantine-robust estimator.
    Dedicated single-sweep kernel below.

Inputs come from the packed parameter plane (``utils/flat.py``): N must
be a multiple of ALIGN = 8 * 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update.fused import LANE, SUBLANE, choose_block_rows

# VMEM budget for the (m, block_rows, 128) slab: ~2 MiB f32
_SLAB_BUDGET_ELEMS = 1 << 19

# finite sentinel for the trimmed-mean selection sweeps: larger than any
# real gradient coordinate, but finite so dead-row sentinels can never
# poison an accumulation the way ±inf would (python float: pallas
# kernels cannot capture traced constants)
_BIG = 3.0e38


def _agg_kernel(w_ref, g_ref, out_ref):
    m = g_ref.shape[0]

    def body(u, acc):
        return acc + w_ref[u] * g_ref[u, :, :].astype(jnp.float32)

    acc = jax.lax.fori_loop(
        0, m, body, jnp.zeros(out_ref.shape, jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate_flat(gs, w, *, interpret: bool = False):
    """gs: (m, N) packed client gradients, w: (m,) weights -> (N,) f32.

    Computes Σ_u w_u · gs[u] in a single pass; the caller is responsible
    for weight normalization (fedmeta normalizes once per round).
    """
    m, N = gs.shape
    assert N % (SUBLANE * LANE) == 0, N
    total_rows = N // LANE
    max_rows = max(SUBLANE, _SLAB_BUDGET_ELEMS // (LANE * max(m, 1)))
    rows = choose_block_rows(total_rows, max_rows=max_rows)
    n_tiles = total_rows // rows

    out = pl.pallas_call(
        _agg_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, rows, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total_rows, LANE), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32), gs.reshape(m, total_rows, LANE))
    return out.reshape(N)


def weighted_aggregate_ref(gs, w):
    """Pure-jnp oracle: w @ gs, accumulating in f32.

    The dot runs in the block's dtype with a f32 accumulator so a
    reduced-precision (bf16) gradient block is consumed directly —
    upcasting gs first would materialize a full f32 copy of the block."""
    return jax.lax.dot_general(
        w.astype(gs.dtype), gs, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---- robust aggregation (DESIGN.md §14) ---------------------------------

def row_liveness(gs, w):
    """(m,) f32 mask of aggregatable rows: weight > 0 AND all-finite.

    The finiteness check is one fused reduce over |g| per row (a NaN or
    ±inf anywhere makes the row sum non-finite) rather than a
    materialized (m, N) isfinite mask."""
    row_mag = jnp.sum(jnp.abs(gs.astype(jnp.float32)), axis=1)
    live = jnp.isfinite(row_mag) & (w.astype(jnp.float32) > 0)
    return live.astype(jnp.float32)


def masked_mean_ref(gs, w):
    """Dropout-masked renormalizing weighted mean oracle: Σ w g / Σ w."""
    return weighted_aggregate_ref(gs, w) / jnp.sum(w.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_mean_flat(gs, w, *, interpret: bool = False):
    """Kernel path of the masked mean: the fused weighted reduce plus a
    scalar renormalization (one elementwise op; XLA fuses it into the
    kernel's epilogue). Dropped rows carry w = 0 so the surviving rows'
    relative weights are preserved while their sum returns to 1."""
    return (weighted_aggregate_flat(gs, w, interpret=interpret)
            / jnp.sum(w.astype(jnp.float32)))


def screened_weights(gs, w, *, factor: float = 3.0):
    """Norm-screening as effective aggregation weights.

    Computes per-row L2 norms, rejects dead rows (zero weight or any
    non-finite coordinate), and derives a robust threshold
    τ = factor × median(live norms). Rows with ‖g‖ > τ are *clipped*:
    scaling row u by τ/‖g_u‖ is exactly scaling its weight by the same
    factor, so the screen composes with the plain weighted kernel.
    Returns ``(w_num, w_den)`` with
    ``aggregate = Σ w_num·g / Σ w_den`` — the denominator keeps the
    *unclipped* live weights so clipping shrinks an outlier's
    contribution instead of silently re-inflating the others. An
    all-dead round yields Σ w_den = 0 → NaN for the guard."""
    g32 = gs.astype(jnp.float32)
    sq = jnp.sum(g32 * g32, axis=1)
    live = jnp.isfinite(sq) & (w.astype(jnp.float32) > 0)
    norms = jnp.sqrt(sq)
    # masked lower median: dead rows sort to the top as +BIG sentinels
    ranked = jnp.sort(jnp.where(live, norms, _BIG))
    n_live = jnp.sum(live)
    med = ranked[jnp.maximum(n_live - 1, 0) // 2]
    thresh = jnp.float32(factor) * med
    clip = jnp.where(norms > thresh, thresh / norms, jnp.float32(1.0))
    w32 = w.astype(jnp.float32)
    w_num = jnp.where(live, w32 * clip, 0.0)
    w_den = jnp.where(live, w32, 0.0)
    return w_num, w_den


def _screen_rows(gs, w_num):
    # rejected rows may be NaN: 0-weight × NaN would still poison the
    # reduce, so zero the rejected rows before it
    return jnp.where((w_num > 0)[:, None], gs, jnp.zeros((), gs.dtype))


def screened_aggregate_ref(gs, w, *, factor: float = 3.0):
    """Norm-screened aggregation oracle (see ``screened_weights``)."""
    w_num, w_den = screened_weights(gs, w, factor=factor)
    return (weighted_aggregate_ref(_screen_rows(gs, w_num), w_num)
            / jnp.sum(w_den))


@functools.partial(jax.jit, static_argnames=("factor", "interpret"))
def screened_aggregate_flat(gs, w, *, factor: float = 3.0,
                            interpret: bool = False):
    """Kernel path of norm screening: the screen itself is (m,)-sized
    scalar work; the (m, N) reduce reuses the fused weighted kernel with
    the clipped effective weights."""
    w_num, w_den = screened_weights(gs, w, factor=factor)
    return (weighted_aggregate_flat(_screen_rows(gs, w_num), w_num,
                                    interpret=interpret)
            / jnp.sum(w_den))


def _trimmed_kernel(live_ref, g_ref, out_ref, x_ref, *, trim):
    """Coordinate-wise trimmed mean over the live rows of one slab.

    x_ref is a VMEM scratch copy of the slab with dead rows replaced by
    a ∓BIG sentinel. Each of the ``trim`` extraction sweeps finds the
    per-coordinate extreme across the m rows (tracking the first row
    index achieving it), adds it to the running extreme-sum, and knocks
    that row's coordinate out with the sentinel so the next sweep finds
    the next-most-extreme value. 2·trim sweeps of m rows each — still
    sequential streaming over the slab, same access pattern as the
    weighted kernel, no per-coordinate sort."""
    m = g_ref.shape[0]

    def fill(sign):
        # dead rows -> -sign*BIG: never selected as a sign-extreme
        def body(u, _):
            x_ref[u, :, :] = jnp.where(
                live_ref[u] > 0.0, g_ref[u, :, :].astype(jnp.float32),
                -sign * _BIG)
            return 0
        jax.lax.fori_loop(0, m, body, 0)

    def extract(sign):
        """Per-coordinate sum of the ``trim`` most sign-extreme live
        values; destructive on x_ref."""
        ext = jnp.zeros(out_ref.shape, jnp.float32)
        for _ in range(trim):
            def best_body(u, carry):
                bv, bu = carry
                xu = x_ref[u, :, :]
                better = (sign * xu) > (sign * bv)   # strict: first wins
                return (jnp.where(better, xu, bv),
                        jnp.where(better, u, bu))
            best, best_u = jax.lax.fori_loop(
                0, m, best_body,
                (jnp.full(out_ref.shape, -sign * _BIG, jnp.float32),
                 jnp.zeros(out_ref.shape, jnp.int32)))
            ext = ext + best

            def knock_out(u, _):
                xu = x_ref[u, :, :]
                x_ref[u, :, :] = jnp.where(best_u == u, -sign * _BIG, xu)
                return 0
            jax.lax.fori_loop(0, m, knock_out, 0)
        return ext

    def live_sum(u, acc):
        alive = live_ref[u] > 0.0
        return acc + jnp.where(alive, g_ref[u, :, :].astype(jnp.float32),
                               0.0)

    total = jax.lax.fori_loop(
        0, m, live_sum, jnp.zeros(out_ref.shape, jnp.float32))
    fill(1.0)
    top = extract(1.0)
    bot = jnp.zeros(out_ref.shape, jnp.float32)
    if trim:
        fill(-1.0)
        bot = extract(-1.0)
    n_live = jax.lax.fori_loop(
        0, m, lambda u, a: a + jnp.where(live_ref[u] > 0.0, 1.0, 0.0),
        jnp.float32(0.0))
    out_ref[...] = ((total - top - bot)
                    / (n_live - 2.0 * trim)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("trim", "interpret"))
def trimmed_mean_flat(gs, live, *, trim: int = 1, interpret: bool = False):
    """gs: (m, N) block, live: (m,) f32 liveness mask -> (N,) coordinate-
    wise trimmed mean over live rows (``row_liveness`` supplies the mask;
    pre-screening non-finite rows there keeps NaNs out of the kernel).

    Requires n_live > 2·trim at runtime — fewer live rows divide by a
    non-positive count and the non-finite guard skips the round; the
    static bound 2·trim < m is asserted here."""
    m, N = gs.shape
    assert N % (SUBLANE * LANE) == 0, N
    assert 0 <= 2 * trim < m, (trim, m)
    total_rows = N // LANE
    # slab + same-shape scratch both live in VMEM -> halve the budget
    max_rows = max(SUBLANE, _SLAB_BUDGET_ELEMS // (LANE * max(2 * m, 1)))
    rows = choose_block_rows(total_rows, max_rows=max_rows)
    n_tiles = total_rows // rows

    out = pl.pallas_call(
        functools.partial(_trimmed_kernel, trim=trim),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, rows, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total_rows, LANE), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, rows, LANE), jnp.float32)],
        interpret=interpret,
    )(live.astype(jnp.float32), gs.reshape(m, total_rows, LANE))
    return out.reshape(N)


def trimmed_mean_ref(gs, live, *, trim: int = 1):
    """Sort-based trimmed-mean oracle.

    Dead rows become NaN, which ``jnp.sort`` places last per coordinate,
    so live values occupy ranks [0, n_live) and the kept window is
    ranks [trim, n_live − trim)."""
    x = jnp.where(live[:, None] > 0, gs.astype(jnp.float32), jnp.nan)
    ranked = jnp.sort(x, axis=0)
    n_live = jnp.sum(live > 0)
    rank = jnp.arange(gs.shape[0])[:, None]
    keep = (rank >= trim) & (rank < n_live - trim)
    kept_sum = jnp.sum(jnp.where(keep, ranked, 0.0), axis=0)
    return kept_sum / (n_live - 2 * trim)
