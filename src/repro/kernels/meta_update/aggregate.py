"""Pallas TPU kernel for fused weighted client-gradient aggregation.

The server step reduces the (m, N) block of packed per-client
meta-gradients to the (N,) meta-gradient g = Σ_u w_u · g_u (paper A.2
weights by local data count). Per-leaf XLA emits one broadcast-multiply
plus reduce per tensor; this kernel makes one sweep over the block —
each grid step streams an (m, block_rows, 128) slab through VMEM,
accumulates the weighted sum across the client axis, and writes one
(block_rows, 128) output tile. Weights live in SMEM and are read as
scalars inside the client loop.

Inputs come from the packed parameter plane (``utils/flat.py``): N must
be a multiple of ALIGN = 8 * 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update.fused import LANE, SUBLANE, choose_block_rows

# VMEM budget for the (m, block_rows, 128) slab: ~2 MiB f32
_SLAB_BUDGET_ELEMS = 1 << 19


def _agg_kernel(w_ref, g_ref, out_ref):
    m = g_ref.shape[0]

    def body(u, acc):
        return acc + w_ref[u] * g_ref[u, :, :].astype(jnp.float32)

    acc = jax.lax.fori_loop(
        0, m, body, jnp.zeros(out_ref.shape, jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate_flat(gs, w, *, interpret: bool = False):
    """gs: (m, N) packed client gradients, w: (m,) weights -> (N,) f32.

    Computes Σ_u w_u · gs[u] in a single pass; the caller is responsible
    for weight normalization (fedmeta normalizes once per round).
    """
    m, N = gs.shape
    assert N % (SUBLANE * LANE) == 0, N
    total_rows = N // LANE
    max_rows = max(SUBLANE, _SLAB_BUDGET_ELEMS // (LANE * max(m, 1)))
    rows = choose_block_rows(total_rows, max_rows=max_rows)
    n_tiles = total_rows // rows

    out = pl.pallas_call(
        _agg_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((m, rows, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total_rows, LANE), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32), gs.reshape(m, total_rows, LANE))
    return out.reshape(N)


def weighted_aggregate_ref(gs, w):
    """Pure-jnp oracle: w @ gs, accumulating in f32.

    The dot runs in the block's dtype with a f32 accumulator so a
    reduced-precision (bf16) gradient block is consumed directly —
    upcasting gs first would materialize a full f32 copy of the block."""
    return jax.lax.dot_general(
        w.astype(gs.dtype), gs, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
