"""Pallas TPU kernel for the fused Meta-SGD inner update.

The inner update θ' = θ − α ∘ g is executed once per client per round
over the full parameter vector — pure memory traffic (3 reads, 1 write,
1 FMA per element). Unfused, XLA emits it per-leaf as mul+sub pairs; the
kernel streams 128-lane-aligned tiles through VMEM in a single pass,
which is the roofline-optimal schedule for this op on TPU.

Layout: callers hand in the packed parameter plane (`utils/flat.py`) — a
padded (N,) vector with N a multiple of ALIGN = 8 * 128 — and the kernel
runs a 1-D grid over (block_rows, 128) tiles, block_rows chosen as the
largest sublane-aligned divisor of N // 128 up to MAX_BLOCK_ROWS.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
MAX_BLOCK_ROWS = 8 * 64      # 64k elements per grid step (~256 KiB f32)
TILE = MAX_BLOCK_ROWS * LANE  # kept for back-compat with older callers


def choose_block_rows(total_rows: int, max_rows: int = MAX_BLOCK_ROWS) -> int:
    """Largest divisor of ``total_rows`` that is ≤ max_rows and a multiple
    of SUBLANE (total_rows is guaranteed sublane-aligned by flat.ALIGN)."""
    assert total_rows % SUBLANE == 0, total_rows
    k = total_rows // SUBLANE
    cap = max(1, max_rows // SUBLANE)
    d = max(x for x in range(1, min(cap, k) + 1) if k % x == 0)
    return SUBLANE * d


def _meta_update_kernel(theta_ref, alpha_ref, g_ref, out_ref):
    out_ref[...] = (theta_ref[...].astype(jnp.float32)
                    - alpha_ref[...].astype(jnp.float32)
                    * g_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def meta_update_flat(theta, alpha, g, *, interpret: bool = False):
    """theta, alpha, g: flat (N,) with N % (8*128) == 0. Returns θ − α∘g."""
    (N,) = theta.shape
    assert N % (SUBLANE * LANE) == 0, N
    total_rows = N // LANE
    rows = choose_block_rows(total_rows)
    n_tiles = total_rows // rows

    def reshape(x):
        return x.reshape(total_rows, LANE)

    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        _meta_update_kernel,
        grid=(n_tiles,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((total_rows, LANE), theta.dtype),
        interpret=interpret,
    )(reshape(theta), reshape(alpha), reshape(g))
    return out.reshape(N)
