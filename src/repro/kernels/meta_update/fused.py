"""Pallas TPU kernel for the fused Meta-SGD inner update.

The inner update θ' = θ − α ∘ g is executed once per client per round
over the full parameter vector — pure memory traffic (3 reads, 1 write,
1 FMA per element). Unfused, XLA emits it per-leaf as mul+sub pairs; the
kernel streams 128-lane-aligned tiles through VMEM in a single pass,
which is the roofline-optimal schedule for this op on TPU.

Layout: callers flatten the pytree into one padded (n_tiles * TILE,)
vector (see ops.py); the kernel is a 1-D grid over (TILE,) blocks
reshaped to (TILE // 128, 128) for (sublane, lane) alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 8 * 128 * 64          # 64k elements per grid step (~256 KiB f32)


def _meta_update_kernel(theta_ref, alpha_ref, g_ref, out_ref):
    out_ref[...] = (theta_ref[...].astype(jnp.float32)
                    - alpha_ref[...].astype(jnp.float32)
                    * g_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def meta_update_flat(theta, alpha, g, *, interpret: bool = False):
    """theta, alpha, g: flat (N,) with N % TILE == 0. Returns θ − α∘g."""
    (N,) = theta.shape
    assert N % TILE == 0, N
    rows = TILE // 128
    n_tiles = N // TILE

    def reshape(x):
        return x.reshape(n_tiles * rows, 128)

    spec = pl.BlockSpec((rows, 128), lambda i: (i, 0))
    out = pl.pallas_call(
        _meta_update_kernel,
        grid=(n_tiles,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles * rows, 128), theta.dtype),
        interpret=interpret,
    )(reshape(theta), reshape(alpha), reshape(g))
    return out.reshape(N)
