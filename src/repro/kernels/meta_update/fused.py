"""Pallas TPU kernels for the fused inner update (single θ and client
plane).

The inner update θ' = θ − α ∘ g is executed once per client per inner
step per round over the full parameter vector — pure memory traffic
(3 reads, 1 write, 1 FMA per element). Unfused, XLA emits it per-leaf as
mul+sub pairs; the kernels stream 128-lane-aligned tiles through VMEM in
a single pass, which is the roofline-optimal schedule for this op on
TPU.

Two layouts, both over the packed parameter plane (`utils/flat.py`,
N a multiple of ALIGN = 8 * 128):

- ``meta_update_flat``: one client, flat (N,) buffers, 1-D grid over
  (block_rows, 128) tiles — the deployment/adapt path.
- ``inner_update_plane``: a chunk of C clients adapting in lockstep on a
  (C, N) client plane, 2-D (client, tile) grid, with θ aliased to the
  output so the plane updates in place across inner steps. α is a
  compile-time scalar (MAML/FOMAML/Reptile), a shared (N,) vector, or a
  per-client (C, N) block (Meta-SGD, where α rides the plane as a
  learnable input). ``inner_update_plane`` carries a custom VJP
  (θ' = θ − α∘g ⇒ dθ = ḡ, dα = −g∘ḡ reduced to α's shape, dg = −α∘ḡ) so
  MAML/Meta-SGD can reverse-differentiate through the fused kernel; the
  backward is plain jnp — elementwise, fused by XLA, and only live on
  second-order paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
MAX_BLOCK_ROWS = 8 * 64      # 64k elements per grid step (~256 KiB f32)
TILE = MAX_BLOCK_ROWS * LANE  # kept for back-compat with older callers


def choose_block_rows(total_rows: int, max_rows: int = MAX_BLOCK_ROWS) -> int:
    """Largest divisor of ``total_rows`` that is ≤ max_rows and a multiple
    of SUBLANE (total_rows is guaranteed sublane-aligned by flat.ALIGN)."""
    assert total_rows % SUBLANE == 0, total_rows
    k = total_rows // SUBLANE
    cap = max(1, max_rows // SUBLANE)
    d = max(x for x in range(1, min(cap, k) + 1) if k % x == 0)
    return SUBLANE * d


def _meta_update_kernel(theta_ref, alpha_ref, g_ref, out_ref):
    out_ref[...] = (theta_ref[...].astype(jnp.float32)
                    - alpha_ref[...].astype(jnp.float32)
                    * g_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def meta_update_flat(theta, alpha, g, *, interpret: bool = False):
    """theta, alpha, g: flat (N,) with N % (8*128) == 0. Returns θ − α∘g."""
    (N,) = theta.shape
    assert N % (SUBLANE * LANE) == 0, N
    total_rows = N // LANE
    rows = choose_block_rows(total_rows)
    n_tiles = total_rows // rows

    def reshape(x):
        return x.reshape(total_rows, LANE)

    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        _meta_update_kernel,
        grid=(n_tiles,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((total_rows, LANE), theta.dtype),
        interpret=interpret,
    )(reshape(theta), reshape(alpha), reshape(g))
    return out.reshape(N)


# ---- client-plane inner update ------------------------------------------

def _plane_kernel_scalar(theta_ref, g_ref, out_ref, *, alpha):
    out_ref[...] = (theta_ref[...].astype(jnp.float32)
                    - alpha * g_ref[...].astype(jnp.float32)
                    ).astype(out_ref.dtype)


def _plane_kernel_vec(theta_ref, alpha_ref, g_ref, out_ref):
    out_ref[...] = (theta_ref[...].astype(jnp.float32)
                    - alpha_ref[...].astype(jnp.float32)
                    * g_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


def _plane_grid(C: int, N: int):
    assert N % (SUBLANE * LANE) == 0, N
    total_rows = N // LANE
    rows = choose_block_rows(total_rows)
    spec3 = pl.BlockSpec((1, rows, LANE), lambda c, i: (c, i, 0))
    return total_rows, rows, (C, total_rows // rows), spec3


# NOTE: deliberately NOT wrapped in jax.jit. Production callers jit the
# whole meta step, so compiled-mode dispatch cost is irrelevant; and an
# eager interpret-mode call must round mul-then-sub exactly like the
# eager per-leaf tree reference (XLA:CPU contracts θ − α∘g into an FMA
# whenever the expression compiles as one program — optimization_barrier
# does not stop LLVM's fp contraction — which would put the "bit-exact
# oracle" 1 ulp off the tree path).
def _inner_plane_scalar_call(theta, g, *, alpha: float,
                             interpret: bool = False):
    C, N = theta.shape
    total_rows, rows, grid, spec = _plane_grid(C, N)
    shape3 = (C, total_rows, LANE)
    out = pl.pallas_call(
        functools.partial(_plane_kernel_scalar, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape3, theta.dtype),
        input_output_aliases={0: 0},      # θ plane updates in place
        interpret=interpret,
    )(theta.reshape(shape3), g.reshape(shape3))
    return out.reshape(C, N)


def _inner_plane_vec_call(theta, alpha, g, *, interpret: bool = False):
    # un-jitted on purpose — see _inner_plane_scalar_call
    C, N = theta.shape
    total_rows, rows, grid, spec = _plane_grid(C, N)
    shape3 = (C, total_rows, LANE)
    if alpha.ndim == 1:        # shared (N,) α, broadcast over the chunk
        a_spec = pl.BlockSpec((rows, LANE), lambda c, i: (i, 0))
        a = alpha.reshape(total_rows, LANE)
    else:                      # per-client (C, N) α block
        a_spec = spec
        a = alpha.reshape(shape3)
    out = pl.pallas_call(
        _plane_kernel_vec,
        grid=grid,
        in_specs=[spec, a_spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape3, theta.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(theta.reshape(shape3), a, g.reshape(shape3))
    return out.reshape(C, N)


def _reduce_to_shape(x, shape):
    """Sum-reduce ``x`` down to ``shape`` (inverse of broadcasting)."""
    if x.shape == tuple(shape):
        return x
    extra = x.ndim - len(shape)
    if extra:
        x = jnp.sum(x, axis=tuple(range(extra)))
    axes = tuple(i for i, d in enumerate(shape) if d == 1 and x.shape[i] != 1)
    if axes:
        x = jnp.sum(x, axis=axes, keepdims=True)
    return x


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _inner_plane_scalar(alpha, interpret, theta, g):
    return _inner_plane_scalar_call(theta, g, alpha=alpha,
                                    interpret=interpret)


def _inner_plane_scalar_fwd(alpha, interpret, theta, g):
    return _inner_plane_scalar(alpha, interpret, theta, g), None


def _inner_plane_scalar_bwd(alpha, interpret, _res, ct):
    return ct, -alpha * ct


_inner_plane_scalar.defvjp(_inner_plane_scalar_fwd, _inner_plane_scalar_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _inner_plane_vec(interpret, theta, alpha, g):
    return _inner_plane_vec_call(theta, alpha, g, interpret=interpret)


def _inner_plane_vec_fwd(interpret, theta, alpha, g):
    return _inner_plane_vec(interpret, theta, alpha, g), (alpha, g)


def _inner_plane_vec_bwd(interpret, res, ct):
    alpha, g = res
    d_alpha = _reduce_to_shape(-g * ct, alpha.shape)
    return ct, d_alpha, -alpha * ct


_inner_plane_vec.defvjp(_inner_plane_vec_fwd, _inner_plane_vec_bwd)


def inner_update_plane(theta, alpha, g, *, interpret: bool = False):
    """Fused θ ← θ − α∘g over a (C, N) client plane, differentiable.

    theta, g: (C, N) with N % (8*128) == 0. alpha: python scalar
    (compile-time constant baked into the kernel), (N,) shared
    per-coordinate rates, or (C, N) per-client rates. Input/output
    aliasing updates θ in place; a custom VJP makes the op safe under
    reverse-mode autodiff (second-order MAML / Meta-SGD)."""
    if isinstance(alpha, (int, float)):
        return _inner_plane_scalar(float(alpha), interpret, theta, g)
    return _inner_plane_vec(interpret, theta, alpha, g)
