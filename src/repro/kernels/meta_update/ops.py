"""Dispatcher for the fused inner update.

impl: "xla" (tree_map; default), "pallas", "pallas_interpret".
The pallas path flattens the pytree into one padded vector, runs the
single-pass kernel, and unflattens — one kernel launch for the whole
parameter set instead of one op pair per leaf.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.meta_update import ref
from repro.kernels.meta_update.fused import TILE, meta_update_flat

_DEFAULT_IMPL = os.environ.get("REPRO_META_UPDATE_IMPL", "xla")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


def _flatten_pad(tree, dtype):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([x.reshape(-1).astype(dtype) for x in leaves])
    pad = (-flat.shape[0]) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def _unflatten(tree, flat):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for x in leaves:
        n = int(np.prod(x.shape))
        out.append(flat[off:off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def meta_update(theta, alpha, grads, *, impl: str | None = None):
    """θ' = θ − α ∘ g; α is a scalar or a pytree matching θ."""
    impl = impl or _DEFAULT_IMPL
    if impl == "xla":
        return ref.meta_update_ref(theta, alpha, grads)
    dtype = jnp.float32
    t = _flatten_pad(theta, dtype)
    if isinstance(alpha, (int, float)):
        a = jnp.full_like(t, alpha)
    else:
        a = _flatten_pad(alpha, dtype)
    g = _flatten_pad(grads, dtype)
    out = meta_update_flat(t, a, g, interpret=(impl == "pallas_interpret"))
    return _unflatten(theta, out)
