"""Dispatchers for the fused meta-step ops.

impl: "xla" (tree_map / jnp; default), "pallas", "pallas_interpret",
selected per-call, via :func:`set_default_impl`, or the
``REPRO_META_UPDATE_IMPL`` environment variable (see DESIGN.md §5).
One switch governs all three fused ops — inner update, weighted
aggregation, outer Adam — so a config flips the whole pipeline.

The pallas paths run on the packed parameter plane (``utils/flat.py``):
the flattening spec (treedef, offsets, padding) is computed once per
tree structure and memoized, so repeated calls — e.g. the inner update
inside every client of every round — never recompute the layout.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.meta_update import ref
from repro.kernels.meta_update.compress import (CODECS,  # noqa: F401
                                                CompressionConfig,
                                                int8_aggregate_flat,
                                                int8_aggregate_ref,
                                                int8_encode_flat,
                                                int8_encode_ref,
                                                topk_aggregate_flat,
                                                topk_aggregate_ref)
from repro.kernels.meta_update.aggregate import (masked_mean_flat,
                                                 masked_mean_ref,
                                                 row_liveness,
                                                 screened_aggregate_flat,
                                                 screened_aggregate_ref,
                                                 trimmed_mean_flat,
                                                 trimmed_mean_ref,
                                                 weighted_aggregate_flat,
                                                 weighted_aggregate_ref)
from repro.kernels.meta_update.fused import (TILE,  # noqa: F401 (re-export)
                                             inner_update_plane,
                                             meta_update_flat)
from repro.utils.flat import plane_for

_DEFAULT_IMPL = os.environ.get("REPRO_META_UPDATE_IMPL", "xla")
_IMPLS = ("xla", "pallas", "pallas_interpret")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in _IMPLS
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def resolve_impl(impl: str | None) -> str:
    impl = impl or _DEFAULT_IMPL
    assert impl in _IMPLS, impl
    return impl


def meta_update(theta, alpha, grads, *, impl: str | None = None):
    """θ' = θ − α ∘ g; α is a scalar or a pytree matching θ.

    The pallas paths route through the plane kernel's custom VJP
    (``inner_update``), so the tree inner loop stays reverse-
    differentiable under a pallas impl (second-order MAML/Meta-SGD used
    to hit the missing pallas_call VJP here)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.meta_update_ref(theta, alpha, grads)
    plane = plane_for(theta)
    t = plane.pack(theta)
    a = alpha if isinstance(alpha, (int, float)) else plane.pack(alpha)
    out = inner_update(t, a, plane.pack(grads), impl=impl)
    return plane.unpack_ad(out)


def inner_update(theta, alpha, g, *, impl: str | None = None):
    """Fused inner update on flat client-plane buffers, differentiable.

    theta, g: (C, N) — or (N,), treated as a one-client plane — with N a
    multiple of flat.ALIGN. alpha: python scalar, (N,) shared rates, or
    a (C, N) per-client block. "xla" is the fused-elementwise oracle;
    the pallas paths run the single-pass plane kernel
    (``fused.inner_update_plane``) with its custom VJP, so second-order
    algorithms can differentiate straight through it."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.inner_update_plane_ref(theta, alpha, g)
    if not isinstance(alpha, (int, float)) and alpha.ndim == 0:
        # a 0-d array (e.g. a traced learning rate) can't be baked into
        # the kernel as a compile-time scalar; run it as shared rates
        alpha = jnp.broadcast_to(alpha, theta.shape[-1:])
    squeeze = theta.ndim == 1
    if squeeze:
        theta, g = theta[None], g[None]
        if not isinstance(alpha, (int, float)) and alpha.ndim == 2:
            raise ValueError("2-D alpha with 1-D theta")
    out = inner_update_plane(theta, alpha, g,
                             interpret=(impl == "pallas_interpret"))
    return out[0] if squeeze else out


def weighted_aggregate(gs, w, *, impl: str | None = None):
    """(m, N) packed client grads × (m,) weights -> (N,) Σ_u w_u·g_u."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return weighted_aggregate_ref(gs, w)
    return weighted_aggregate_flat(gs, w,
                                   interpret=(impl == "pallas_interpret"))


def int8_encode(G, *, impl: str | None = None):
    """(m, N) block -> (q int8, (m,) f32 scales, (m, N) f32 residual).

    Per-row-scaled int8 quantization with the error-feedback residual
    emitted in the same pass (compress.py). "xla" runs the pure-jnp
    oracle; the pallas paths run the fused encode kernel."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return int8_encode_ref(G)
    return int8_encode_flat(G, interpret=(impl == "pallas_interpret"))


def int8_aggregate(q, scales, w, *, impl: str | None = None):
    """Dequantize-and-aggregate Σ_u w_u·s_u·q_u -> (N,) f32, fused into
    the weighted-aggregate kernel (the scale folds into the weight)."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return int8_aggregate_ref(q, scales, w)
    return int8_aggregate_flat(q, scales, w,
                               interpret=(impl == "pallas_interpret"))


def topk_aggregate(vals, idx, w, n: int, *, impl: str | None = None):
    """Decode-and-aggregate (m, k) top-k uploads -> (n,) f32 weighted
    sum. (Encoding is ``compress.topk_encode`` on every impl: per-row
    selection is one XLA ``lax.top_k`` — a pallas sort network is out
    of scope, documented in compress.py.)"""
    impl = resolve_impl(impl)
    if impl == "xla":
        return topk_aggregate_ref(vals, idx, w, n)
    return topk_aggregate_flat(vals, idx, w, n,
                               interpret=(impl == "pallas_interpret"))


AGGREGATORS = ("mean", "masked_mean", "screen", "trimmed")


def robust_aggregate(gs, w, *, aggregator: str = "mean",
                     impl: str | None = None, screen_factor: float = 3.0,
                     trim: int = 1):
    """Failure-plane reduction over the (m, N) client block (§14).

      mean         Σ w·g — the plain weighted kernel, caller-normalized
                   weights; byte-for-byte today's path.
      masked_mean  Σ w·g / Σ w — renormalizes over arrived (w > 0) rows,
                   so dropouts shrink the round, not the gradient.
      screen       reject non-finite rows, clip rows with
                   ‖g‖ > screen_factor × median(live ‖g‖), renormalize.
      trimmed      coordinate-wise trimmed mean over live (arrived,
                   finite) rows, dropping the ``trim`` largest and
                   smallest values per coordinate — unweighted, the
                   classic Byzantine-robust estimator.

    All four share the impl switch; non-mean aggregators may return a
    non-finite result on degenerate rounds (every row dead, or fewer
    than 2·trim + 1 live rows) — that is deliberate: the engine's
    non-finite guard turns it into a skipped round."""
    impl = resolve_impl(impl)
    interp = impl == "pallas_interpret"
    if aggregator == "mean":
        return weighted_aggregate(gs, w, impl=impl)
    if aggregator == "masked_mean":
        if impl == "xla":
            return masked_mean_ref(gs, w)
        return masked_mean_flat(gs, w, interpret=interp)
    if aggregator == "screen":
        if impl == "xla":
            return screened_aggregate_ref(gs, w, factor=screen_factor)
        return screened_aggregate_flat(gs, w, factor=screen_factor,
                                       interpret=interp)
    if aggregator == "trimmed":
        live = row_liveness(gs, w)
        if impl == "xla":
            return trimmed_mean_ref(gs, live, trim=trim)
        return trimmed_mean_flat(gs, live, trim=trim, interpret=interp)
    raise ValueError(f"unknown aggregator {aggregator!r}; "
                     f"expected one of {AGGREGATORS}")
