"""Dispatchers for the fused meta-step ops.

impl: "xla" (tree_map / jnp; default), "pallas", "pallas_interpret",
selected per-call, via :func:`set_default_impl`, or the
``REPRO_META_UPDATE_IMPL`` environment variable (see DESIGN.md §5).
One switch governs all three fused ops — inner update, weighted
aggregation, outer Adam — so a config flips the whole pipeline.

The pallas paths run on the packed parameter plane (``utils/flat.py``):
the flattening spec (treedef, offsets, padding) is computed once per
tree structure and memoized, so repeated calls — e.g. the inner update
inside every client of every round — never recompute the layout.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.meta_update import ref
from repro.kernels.meta_update.aggregate import (weighted_aggregate_flat,
                                                 weighted_aggregate_ref)
from repro.kernels.meta_update.fused import TILE, meta_update_flat  # noqa: F401 (TILE re-exported)
from repro.utils.flat import plane_for

_DEFAULT_IMPL = os.environ.get("REPRO_META_UPDATE_IMPL", "xla")
_IMPLS = ("xla", "pallas", "pallas_interpret")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in _IMPLS
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def resolve_impl(impl: str | None) -> str:
    impl = impl or _DEFAULT_IMPL
    assert impl in _IMPLS, impl
    return impl


def meta_update(theta, alpha, grads, *, impl: str | None = None):
    """θ' = θ − α ∘ g; α is a scalar or a pytree matching θ."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.meta_update_ref(theta, alpha, grads)
    plane = plane_for(theta)
    t = plane.pack(theta)
    if isinstance(alpha, (int, float)):
        a = jnp.full_like(t, alpha)
    else:
        a = plane.pack(alpha)
    g = plane.pack(grads)
    out = meta_update_flat(t, a, g, interpret=(impl == "pallas_interpret"))
    return plane.unpack(out)


def weighted_aggregate(gs, w, *, impl: str | None = None):
    """(m, N) packed client grads × (m,) weights -> (N,) Σ_u w_u·g_u."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return weighted_aggregate_ref(gs, w)
    return weighted_aggregate_flat(gs, w,
                                   interpret=(impl == "pallas_interpret"))
