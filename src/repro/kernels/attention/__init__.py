from repro.kernels.attention.ops import flash_attention, set_default_impl
