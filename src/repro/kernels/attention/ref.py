"""Pure-jnp oracle for multi-head attention (GQA, causal, sliding window).

This is the reference implementation the Pallas kernel is validated
against, and also the XLA fallback used on CPU and inside the dry-run.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mha_reference(q, k, v, *, causal: bool = True, window: int | None = None,
                  q_offset: int = 0, kv_length=None, scale: float | None = None):
    """Multi-head attention with GQA, causal and sliding-window masking.

    q: (B, Lq, H, hd); k, v: (B, Lk, Kv, hd) with H % Kv == 0.
    q_offset: absolute position of q[0] relative to k[0] (decode: Lk-1).
    kv_length: optional (B,) or scalar count of valid kv slots (from 0).
    window: sliding window size; query i attends keys j with
            i - window < j <= i (standard SWA convention).
    Returns (B, Lq, H, hd) in q.dtype; softmax in float32.
    """
    B, Lq, H, hd = q.shape
    _, Lk, Kv, _ = k.shape
    hd_v = v.shape[-1]          # value dim may differ from qk dim (MLA)
    assert H % Kv == 0
    G = H // Kv
    if scale is None:
        scale = 1.0 / np.sqrt(hd)

    qf = q.astype(jnp.float32).reshape(B, Lq, Kv, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores: (B, Kv, G, Lq, Lk)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qf, kf) * scale

    qpos = jnp.arange(Lq) + q_offset            # absolute query positions
    jpos = jnp.arange(Lk)
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= jpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= jpos[None, :] > qpos[:, None] - window
    if kv_length is not None:
        kvl = jnp.asarray(kv_length)
        if kvl.ndim == 0:
            mask &= (jpos < kvl)[None, :]
        else:
            mask = mask[None] & (jpos[None, None, :] < kvl[:, None, None])
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    # guard fully-masked rows (can happen with kv_length=0)
    smax = jnp.max(s, axis=-1, keepdims=True)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    p = jnp.exp(s - smax)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", p, vf)
    return o.reshape(B, Lq, H, hd_v).astype(q.dtype)
