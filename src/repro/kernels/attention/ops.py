"""Jitted wrapper / dispatcher for attention.

Layout contract with the models: (B, L, H, hd) activations. The Pallas
kernel wants (B, H, L, hd); this wrapper transposes around the call.

impl:
  "xla"              — pure-jnp reference (CPU tests, dry-run lowering)
  "pallas_interpret" — Pallas kernel, interpret mode (CPU correctness)
  "pallas"           — Pallas kernel compiled for TPU (production)
Default comes from REPRO_ATTN_IMPL env var, else "xla".
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.attention import ref
from repro.kernels.attention.flash_attention import flash_attention_bhld

_DEFAULT_IMPL = os.environ.get("REPRO_ATTN_IMPL", "xla")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _DEFAULT_IMPL = impl


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_offset: int = 0, kv_length=None, impl: str | None = None,
                    block_q: int = 128, block_k: int = 128):
    """q: (B, Lq, H, hd); k, v: (B, Lk, Kv, hd) -> (B, Lq, H, hd)."""
    impl = impl or _DEFAULT_IMPL
    if impl == "xla" or kv_length is not None:
        # variable kv_length (ragged decode) stays on the XLA path
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_length=kv_length)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhld(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"))
    return jnp.swapaxes(out, 1, 2)
