"""Pallas TPU flash attention (tiled online-softmax).

TPU-native design (targets v5e; validated with interpret=True on CPU):
  - inputs pre-transposed to (B, H, L, hd) so the last two dims tile
    cleanly onto (sublane, lane) = (block, 128-multiple head_dim),
  - grid (B, H, nq, nk): the kv dimension is innermost, so each core
    iterates kv blocks sequentially while the (m, l, acc) online-softmax
    carry lives in VMEM scratch — one HBM read per tile, one HBM write
    per output block,
  - GQA folded into the k/v BlockSpec index_map (h -> h // group_size),
    no materialized kv repeat,
  - causal + sliding-window masks applied per tile from absolute
    positions (q_offset supports decode/chunked prefill).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, q_offset: int,
                  bq: int, bk: int, nk: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 128) replicated
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)     # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (bq, 1)
    p = jnp.exp(s - m_new[:, :1])                  # (bq, bk)
    l_new = alpha * l_prev[:, :1] + jnp.sum(p, axis=-1, keepdims=True)

    acc = acc_scr[...]
    acc = acc * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...][:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhld(q, k, v, *, causal: bool = True, window=None,
                         q_offset: int = 0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q: (B, H, Lq, hd); k: (B, Kv, Lk, hd); v: (B, Kv, Lk, hd_v).
    Returns (B, H, Lq, hd_v) — hd_v may differ from hd (MLA)."""
    B, H, Lq, hd = q.shape
    _, Kv, Lk, _ = k.shape
    hd_v = v.shape[-1]
    assert H % Kv == 0
    G = H // Kv
    bq = min(block_q, Lq)
    bk = min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, (Lq, bq, Lk, bk)
    nq, nk = Lq // bq, Lk // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd_v), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd_v), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Lq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (replicated)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd_v), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
