"""The paper's contribution: gradient-based meta-learning algorithms in
the federated setting (Algorithm 1 of the paper).

Every algorithm maintains server-side *algorithm parameters* φ and
implements the client-side procedure ModelTraining(φ; D_S, D_Q) -> g_u:

  MAML      φ = {theta};          inner: θ_u = θ − α∇L_S(θ);
            g = ∇_θ L_Q(θ_u)      (second-order, differentiates through
                                   the inner update)
  FOMAML    same, but g = ∇_{θ_u} L_Q(θ_u)  (first-order approximation)
  Meta-SGD  φ = {theta, alpha};   inner: θ_u = θ − α ∘ ∇L_S(θ) with
            per-coordinate learnable α; g = ∇_{(θ,α)} L_Q(θ_u)
  Reptile   φ = {theta};          client runs k SGD steps on local data;
            g = θ − θ_k           (beyond-paper extra; Nichol et al. '18)

`adapt` is the deployment path (paper §3.2 last ¶): update θ on a new
client's support set and predict with θ_u.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.meta_update import ops as mu_ops
from repro.models.layers import Rng


def _inner_adapt(loss_fn, theta, alpha, support, steps: int,
                 second_order: bool):
    """k gradient steps on the support set (unrolled so reverse-mode
    differentiation through the update is possible for MAML/Meta-SGD)."""
    for _ in range(steps):
        g = jax.grad(loss_fn)(theta, support)
        if not second_order:
            g = jax.lax.stop_gradient(g)
        theta = mu_ops.meta_update(theta, alpha, g)
    return theta


@dataclasses.dataclass
class MetaAlgorithm:
    """Common interface; see factory classes below."""
    name: str
    loss_fn: Callable                     # (params, batch) -> scalar
    eval_fn: Callable                     # (params, batch) -> (loss, metrics)
    inner_lr: float
    inner_steps: int = 1

    # ---- subclass hooks -------------------------------------------------
    def init_state(self, key, model_init: Callable):
        raise NotImplementedError

    def client_grad(self, phi, support, query):
        """ModelTraining on one client: returns (g_u matching φ, metrics)."""
        raise NotImplementedError

    def adapt(self, phi, support, steps: int | None = None):
        """Deployment: adapt θ to a new client's support set."""
        alpha = phi.get("alpha", self.inner_lr)
        return _inner_adapt(self.loss_fn, phi["theta"], alpha, support,
                            steps or self.inner_steps, second_order=False)

    def query_metrics(self, phi, support, query):
        theta_u = self.adapt(phi, support)
        loss, m = self.eval_fn(theta_u, query)
        return {"query_loss": loss, **m}


class MAML(MetaAlgorithm):
    def __init__(self, loss_fn, eval_fn, inner_lr, inner_steps=1, order=2,
                 name=None):
        super().__init__(name or ("maml" if order == 2 else "fomaml"),
                         loss_fn, eval_fn, inner_lr, inner_steps)
        assert order in (1, 2)
        self.order = order

    def init_state(self, key, model_init):
        return {"theta": model_init(key)}

    def client_grad(self, phi, support, query):
        def meta_loss(theta):
            theta_u = _inner_adapt(self.loss_fn, theta, self.inner_lr,
                                   support, self.inner_steps,
                                   second_order=(self.order == 2))
            return self.eval_fn(theta_u, query)

        if self.order == 2:
            (loss, metrics), g = jax.value_and_grad(meta_loss,
                                                    has_aux=True)(phi["theta"])
        else:
            # FOMAML: gradient at the adapted parameters
            theta_u = _inner_adapt(self.loss_fn, phi["theta"], self.inner_lr,
                                   support, self.inner_steps,
                                   second_order=False)
            (loss, metrics), g = jax.value_and_grad(
                self.eval_fn, has_aux=True)(theta_u, query)
        return {"theta": g}, {"query_loss": loss, **metrics}


def FOMAML(loss_fn, eval_fn, inner_lr, inner_steps=1):
    return MAML(loss_fn, eval_fn, inner_lr, inner_steps, order=1)


class MetaSGD(MetaAlgorithm):
    def __init__(self, loss_fn, eval_fn, inner_lr, inner_steps=1, order=2):
        super().__init__("meta-sgd" if order == 2 else "meta-sgd-fo",
                         loss_fn, eval_fn, inner_lr, inner_steps)
        self.order = order

    def init_state(self, key, model_init):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0) if isinstance(key, int)
                                  else key)
        theta = model_init(k1)
        # α initialized around inner_lr with small random spread (paper [12])
        rng = Rng(k2)
        alpha = jax.tree.map(
            lambda p: self.inner_lr * (0.5 + jax.random.uniform(
                rng.next(), p.shape, jnp.float32)),
            theta)
        return {"theta": theta, "alpha": alpha}

    def client_grad(self, phi, support, query):
        def meta_loss(phi_):
            theta_u = _inner_adapt(self.loss_fn, phi_["theta"], phi_["alpha"],
                                   support, self.inner_steps,
                                   second_order=(self.order == 2))
            return self.eval_fn(theta_u, query)

        (loss, metrics), g = jax.value_and_grad(meta_loss,
                                                has_aux=True)(phi)
        return g, {"query_loss": loss, **metrics}


class Reptile(MetaAlgorithm):
    """Beyond-paper extra: first-order, no support/query split needed."""

    def __init__(self, loss_fn, eval_fn, inner_lr, inner_steps=3):
        super().__init__("reptile", loss_fn, eval_fn, inner_lr, inner_steps)

    def init_state(self, key, model_init):
        return {"theta": model_init(key)}

    def client_grad(self, phi, support, query):
        theta_k = _inner_adapt(self.loss_fn, phi["theta"], self.inner_lr,
                               support, self.inner_steps, second_order=False)
        # one extra pass over the query set (uses all local data, like the
        # original Reptile which has no support/query distinction)
        theta_k = _inner_adapt(self.loss_fn, theta_k, self.inner_lr, query,
                               1, second_order=False)
        g = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                         phi["theta"], theta_k)
        loss, metrics = self.eval_fn(theta_k, query)
        return {"theta": g}, {"query_loss": loss, **metrics}


def make_algorithm(name: str, loss_fn, eval_fn, inner_lr: float,
                   inner_steps: int = 1) -> MetaAlgorithm:
    name = name.lower()
    if name == "maml":
        return MAML(loss_fn, eval_fn, inner_lr, inner_steps, order=2)
    if name == "fomaml":
        return MAML(loss_fn, eval_fn, inner_lr, inner_steps, order=1)
    if name in ("meta-sgd", "metasgd"):
        return MetaSGD(loss_fn, eval_fn, inner_lr, inner_steps, order=2)
    if name in ("meta-sgd-fo", "metasgd-fo"):
        return MetaSGD(loss_fn, eval_fn, inner_lr, inner_steps, order=1)
    if name == "reptile":
        return Reptile(loss_fn, eval_fn, inner_lr, inner_steps)
    raise ValueError(f"unknown algorithm {name!r}")
