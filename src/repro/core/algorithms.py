"""The paper's contribution: gradient-based meta-learning algorithms in
the federated setting (Algorithm 1 of the paper).

Every algorithm maintains server-side *algorithm parameters* φ and
implements the client-side procedure ModelTraining(φ; D_S, D_Q) -> g_u:

  MAML      φ = {theta};          inner: θ_u = θ − α∇L_S(θ);
            g = ∇_θ L_Q(θ_u)      (second-order, differentiates through
                                   the inner update)
  FOMAML    same, but g = ∇_{θ_u} L_Q(θ_u)  (first-order approximation)
  Meta-SGD  φ = {theta, alpha};   inner: θ_u = θ − α ∘ ∇L_S(θ) with
            per-coordinate learnable α; g = ∇_{(θ,α)} L_Q(θ_u)
  Reptile   φ = {theta};          client runs k SGD steps on local data;
            g = θ − θ_k           (beyond-paper extra; Nichol et al. '18)

`adapt` is the deployment path (paper §3.2 last ¶): update θ on a new
client's support set and predict with θ_u.

Two executions of the inner loop:

- tree (``_inner_adapt`` / ``client_grad``): θ stays a pytree; the
  update runs per-leaf. Default, works everywhere.
- client plane (``_inner_adapt_plane`` / ``client_grad_chunk_packed``):
  a chunk of C clients adapts in lockstep on a flat (C, N) plane
  (``utils/flat.py``); each inner step is one vmapped model gradient
  plus ONE fused update over the whole chunk
  (``kernels/meta_update/ops.inner_update``), instead of per-client
  per-leaf op soup. Per-client meta-gradients come out flat — grad of
  the summed chunk meta-loss w.r.t. the per-client (C, N) plane is
  exactly the stack of per-client gradients, because row c only enters
  client c's loss — so the (m, N) aggregation block never goes through
  a pytree. See DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.meta_update import ops as mu_ops
from repro.models.layers import Rng
from repro.utils.flat import plane_for


def _inner_adapt(loss_fn, theta, alpha, support, steps: int,
                 second_order: bool):
    """k gradient steps on the support set (unrolled so reverse-mode
    differentiation through the update is possible for MAML/Meta-SGD)."""
    for _ in range(steps):
        g = jax.grad(loss_fn)(theta, support)
        if not second_order:
            g = jax.lax.stop_gradient(g)
        theta = mu_ops.meta_update(theta, alpha, g)
    return theta


# ---- client-plane (packed) inner loop -----------------------------------

def _flat_fn(fn, plane):
    """Lift ``fn(params_tree, batch)`` to flat θ (static slices, no
    FLOPs; autodiff through the unpack yields flat gradients —
    ``unpack_ad`` so each backward pass emits one concat, not L
    zero-padded planes)."""
    def flat(theta_flat, batch):
        return fn(plane.unpack_ad(theta_flat), batch)
    return flat


def _inner_adapt_plane(loss_fn, tplane, Theta, alpha, support, steps: int,
                       second_order: bool, impl):
    """k fused gradient steps for a chunk of clients in lockstep.

    Theta: (C, N) client plane; support leaves carry a leading C axis.
    alpha: python scalar, shared (N,), or per-client (C, N) flat rates.
    Unrolled like ``_inner_adapt``; the fused update's custom VJP keeps
    the whole loop reverse-differentiable for second-order algorithms.
    """
    flat_loss = _flat_fn(loss_fn, tplane)
    for _ in range(steps):
        G = jax.vmap(jax.grad(flat_loss))(Theta, support)
        if not second_order:
            G = jax.lax.stop_gradient(G)
        Theta = mu_ops.inner_update(Theta, alpha, G, impl=impl)
    return Theta


def _broadcast_plane(flat, C):
    return jnp.broadcast_to(flat, (C, flat.shape[-1]))


def _chunk_len(tree):
    return jax.tree.leaves(tree)[0].shape[0]


def _assemble_phi_rows(pplane, tplane, parts: dict):
    """Per-part flat (C, tplane.n_padded) grads -> (C, pplane.n_padded)
    rows in φ-plane layout.

    φ is a flat dict whose values are each structurally identical to θ
    (e.g. Meta-SGD's {"alpha", "theta"}), so the φ plane is the sorted-
    key concatenation of each part's real region, plus alignment pad —
    pure slice/concat on flat buffers, no pytree round-trip."""
    assert pplane.n_real == len(parts) * tplane.n_real, \
        (pplane.n_real, tplane.n_real, sorted(parts))
    body = jnp.concatenate(
        [parts[k][..., :tplane.n_real] for k in sorted(parts)], axis=-1)
    pad = pplane.n_padded - body.shape[-1]
    if pad:
        body = jnp.pad(body, ((0, 0), (0, pad)))
    return body


@dataclasses.dataclass
class MetaAlgorithm:
    """Common interface; see factory classes below."""
    name: str
    loss_fn: Callable                     # (params, batch) -> scalar
    eval_fn: Callable                     # (params, batch) -> (loss, metrics)
    inner_lr: float
    inner_steps: int = 1

    # ---- subclass hooks -------------------------------------------------
    def init_state(self, key, model_init: Callable):
        raise NotImplementedError

    def client_grad(self, phi, support, query):
        """ModelTraining on one client: returns (g_u matching φ, metrics)."""
        raise NotImplementedError

    def client_grad_chunk_packed(self, pplane, tplane, phi, support, query,
                                 *, impl=None):
        """ModelTraining for a chunk of C clients on the flat client
        plane: support/query leaves carry a leading C axis; returns
        (G: (C, pplane.n_padded) f32 rows matching the φ plane, metrics
        with leading C)."""
        raise NotImplementedError

    def adapt(self, phi, support, steps: int | None = None):
        """Deployment: adapt θ to a new client's support set."""
        alpha = phi.get("alpha", self.inner_lr)
        return _inner_adapt(self.loss_fn, phi["theta"], alpha, support,
                            steps or self.inner_steps, second_order=False)

    def adapt_packed(self, phi, support, steps: int | None = None, *,
                     impl=None, plane=None):
        """Deployment on the packed plane: same math as ``adapt`` but the
        inner loop runs fused over flat θ (paper §3.2). Returns the
        adapted θ as a pytree."""
        tplane = plane or plane_for(phi["theta"])
        sup = jax.tree.map(lambda x: x[None], support)
        Theta = self.adapt_packed_batch(phi, sup, steps, impl=impl,
                                        plane=tplane)
        return tplane.unpack(Theta[0])

    def adapt_packed_batch(self, phi, supports, steps: int | None = None, *,
                           impl=None, plane=None):
        """Deployment at serving scale: C concurrent clients adapt in
        lockstep on the flat (C, N) client plane — the same fused
        ``inner_update_plane`` kernel that powers training. ``supports``
        leaves carry a leading C axis (client c's support set is row c).
        Rows are independent — row c only enters client c's loss — so
        each adapted row is bit-identical to that client's solo
        ``adapt``/``adapt_packed`` (the serving plane's contract,
        pinned by tests/test_serving.py). Returns the adapted
        (C, n_padded) plane; rows unpack via ``plane_for(phi["theta"])``.
        """
        tplane = plane or plane_for(phi["theta"])
        C = _chunk_len(supports)
        Theta = _broadcast_plane(tplane.pack(phi["theta"]), C)
        alpha = phi.get("alpha")
        alpha = self.inner_lr if alpha is None else tplane.pack(alpha)
        return _inner_adapt_plane(self.loss_fn, tplane, Theta, alpha,
                                  supports, steps or self.inner_steps,
                                  second_order=False, impl=impl)

    def query_metrics(self, phi, support, query):
        theta_u = self.adapt(phi, support)
        loss, m = self.eval_fn(theta_u, query)
        return {"query_loss": loss, **m}


class MAML(MetaAlgorithm):
    def __init__(self, loss_fn, eval_fn, inner_lr, inner_steps=1, order=2,
                 name=None):
        super().__init__(name or ("maml" if order == 2 else "fomaml"),
                         loss_fn, eval_fn, inner_lr, inner_steps)
        assert order in (1, 2)
        self.order = order

    def init_state(self, key, model_init):
        return {"theta": model_init(key)}

    def client_grad(self, phi, support, query):
        def meta_loss(theta):
            theta_u = _inner_adapt(self.loss_fn, theta, self.inner_lr,
                                   support, self.inner_steps,
                                   second_order=(self.order == 2))
            return self.eval_fn(theta_u, query)

        if self.order == 2:
            (loss, metrics), g = jax.value_and_grad(meta_loss,
                                                    has_aux=True)(phi["theta"])
        else:
            # FOMAML: gradient at the adapted parameters
            theta_u = _inner_adapt(self.loss_fn, phi["theta"], self.inner_lr,
                                   support, self.inner_steps,
                                   second_order=False)
            (loss, metrics), g = jax.value_and_grad(
                self.eval_fn, has_aux=True)(theta_u, query)
        return {"theta": g}, {"query_loss": loss, **metrics}

    def client_grad_chunk_packed(self, pplane, tplane, phi, support, query,
                                 *, impl=None):
        # φ = {"theta"}: the φ plane IS the θ plane (same leaves, order)
        assert pplane.n_padded == tplane.n_padded, \
            (pplane.n_padded, tplane.n_padded)
        C = _chunk_len(support)
        Theta0 = _broadcast_plane(tplane.pack(phi["theta"]), C)
        flat_eval = _flat_fn(self.eval_fn, tplane)
        if self.order == 2:
            def chunk_meta_loss(Theta):
                Theta_u = _inner_adapt_plane(
                    self.loss_fn, tplane, Theta, self.inner_lr, support,
                    self.inner_steps, second_order=True, impl=impl)
                losses, mets = jax.vmap(flat_eval)(Theta_u, query)
                return jnp.sum(losses), (losses, mets)

            G, (losses, mets) = jax.grad(chunk_meta_loss,
                                         has_aux=True)(Theta0)
        else:
            Theta_u = _inner_adapt_plane(
                self.loss_fn, tplane, Theta0, self.inner_lr, support,
                self.inner_steps, second_order=False, impl=impl)

            def one(t, q):
                (loss, met), g = jax.value_and_grad(
                    flat_eval, has_aux=True)(t, q)
                return g, loss, met

            G, losses, mets = jax.vmap(one)(Theta_u, query)
        return G, {"query_loss": losses, **mets}


def FOMAML(loss_fn, eval_fn, inner_lr, inner_steps=1):
    return MAML(loss_fn, eval_fn, inner_lr, inner_steps, order=1)


class MetaSGD(MetaAlgorithm):
    def __init__(self, loss_fn, eval_fn, inner_lr, inner_steps=1, order=2):
        super().__init__("meta-sgd" if order == 2 else "meta-sgd-fo",
                         loss_fn, eval_fn, inner_lr, inner_steps)
        self.order = order

    def init_state(self, key, model_init):
        k1, k2 = jax.random.split(jax.random.PRNGKey(key)
                                  if isinstance(key, int) else key)
        theta = model_init(k1)
        # α initialized around inner_lr with small random spread (paper [12])
        rng = Rng(k2)
        alpha = jax.tree.map(
            lambda p: self.inner_lr * (0.5 + jax.random.uniform(
                rng.next(), p.shape, jnp.float32)),
            theta)
        return {"theta": theta, "alpha": alpha}

    def client_grad(self, phi, support, query):
        def meta_loss(phi_):
            theta_u = _inner_adapt(self.loss_fn, phi_["theta"], phi_["alpha"],
                                   support, self.inner_steps,
                                   second_order=(self.order == 2))
            return self.eval_fn(theta_u, query)

        (loss, metrics), g = jax.value_and_grad(meta_loss,
                                                has_aux=True)(phi)
        return g, {"query_loss": loss, **metrics}

    def client_grad_chunk_packed(self, pplane, tplane, phi, support, query,
                                 *, impl=None):
        C = _chunk_len(support)
        Theta0 = _broadcast_plane(tplane.pack(phi["theta"]), C)
        # per-client α copies so grad w.r.t. the (C, N) block is the
        # per-client α-gradient, not the chunk sum
        Alpha0 = _broadcast_plane(tplane.pack(phi["alpha"]), C)
        flat_eval = _flat_fn(self.eval_fn, tplane)

        def chunk_meta_loss(Theta, Alpha):
            Theta_u = _inner_adapt_plane(
                self.loss_fn, tplane, Theta, Alpha, support,
                self.inner_steps, second_order=(self.order == 2), impl=impl)
            losses, mets = jax.vmap(flat_eval)(Theta_u, query)
            return jnp.sum(losses), (losses, mets)

        (_, (losses, mets)), (gT, gA) = jax.value_and_grad(
            chunk_meta_loss, argnums=(0, 1), has_aux=True)(Theta0, Alpha0)
        G = _assemble_phi_rows(pplane, tplane, {"theta": gT, "alpha": gA})
        return G, {"query_loss": losses, **mets}


class Reptile(MetaAlgorithm):
    """Beyond-paper extra: first-order, no support/query split needed."""

    def __init__(self, loss_fn, eval_fn, inner_lr, inner_steps=3):
        super().__init__("reptile", loss_fn, eval_fn, inner_lr, inner_steps)

    def init_state(self, key, model_init):
        return {"theta": model_init(key)}

    def client_grad(self, phi, support, query):
        theta_k = _inner_adapt(self.loss_fn, phi["theta"], self.inner_lr,
                               support, self.inner_steps, second_order=False)
        # one extra pass over the query set (uses all local data, like the
        # original Reptile which has no support/query distinction)
        theta_k = _inner_adapt(self.loss_fn, theta_k, self.inner_lr, query,
                               1, second_order=False)
        g = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                         phi["theta"], theta_k)
        loss, metrics = self.eval_fn(theta_k, query)
        return {"theta": g}, {"query_loss": loss, **metrics}

    def client_grad_chunk_packed(self, pplane, tplane, phi, support, query,
                                 *, impl=None):
        assert pplane.n_padded == tplane.n_padded, \
            (pplane.n_padded, tplane.n_padded)
        C = _chunk_len(support)
        Theta0 = _broadcast_plane(tplane.pack(phi["theta"]), C)
        Theta_k = _inner_adapt_plane(
            self.loss_fn, tplane, Theta0, self.inner_lr, support,
            self.inner_steps, second_order=False, impl=impl)
        Theta_k = _inner_adapt_plane(
            self.loss_fn, tplane, Theta_k, self.inner_lr, query, 1,
            second_order=False, impl=impl)
        G = (Theta0 - Theta_k).astype(jnp.float32)
        losses, mets = jax.vmap(_flat_fn(self.eval_fn, tplane))(Theta_k,
                                                               query)
        return G, {"query_loss": losses, **mets}


def make_algorithm(name: str, loss_fn, eval_fn, inner_lr: float,
                   inner_steps: int = 1) -> MetaAlgorithm:
    name = name.lower()
    if name == "maml":
        return MAML(loss_fn, eval_fn, inner_lr, inner_steps, order=2)
    if name == "fomaml":
        return MAML(loss_fn, eval_fn, inner_lr, inner_steps, order=1)
    if name in ("meta-sgd", "metasgd"):
        return MetaSGD(loss_fn, eval_fn, inner_lr, inner_steps, order=2)
    if name in ("meta-sgd-fo", "metasgd-fo"):
        return MetaSGD(loss_fn, eval_fn, inner_lr, inner_steps, order=1)
    if name == "reptile":
        return Reptile(loss_fn, eval_fn, inner_lr, inner_steps)
    raise ValueError(f"unknown algorithm {name!r}")
