"""The FedMeta server round (paper Algorithm 1, AlgorithmUpdate).

One meta-training round:
  1. a batch of m sampled clients' (support, query) data arrives with a
     leading client axis on every leaf,
  2. every client computes g_u = ModelTraining(φ; D_S^u, D_Q^u),
  3. the server updates φ with the (weighted) average of the g_u via the
     outer optimizer (Adam here, per paper A.2).

Four client execution strategies (memory/throughput tradeoff in
DESIGN.md §4):
  - "vmap": all clients in parallel (paper's `for u in parallel`; right
    choice for small models / CPU simulation),
  - "scan": clients sequential with a meta-gradient accumulator carry —
    memory-optimal (one adapted θ_u lives at a time),
  - "chunked": scan over chunks of vmapped clients — peak memory scales
    with the chunk size, not clients-per-round, while keeping vmap
    throughput inside each chunk. m need not divide the chunk size;
    the tail chunk is padded with zero-weight duplicate clients.
  - "sharded": clients split across the devices of a mesh (shard_map);
    each device reduces its local clients' gradients to a partial
    meta-gradient which is psum-reduced into the aggregate — the client
    half of the round scales with the mesh, and only (N,)-sized partials
    cross the interconnect (DESIGN.md §10).

Two parameter representations:
  - tree (default): φ stays a pytree; aggregation and the outer step run
    per-leaf,
  - packed plane (``make_packed_meta_train_step``): φ lives in one flat
    128-lane-aligned f32 buffer (utils/flat.py); client gradients are
    packed to an (m, N) block, reduced by the fused aggregation kernel,
    and φ is advanced by the fused outer-Adam kernel — the whole server
    side of the round is two passes over flat memory. With
    ``client_plane=True`` the *client* half runs on flat memory too:
    chunks of clients adapt in lockstep on a (C, N) plane with the
    fused inner-update kernel (DESIGN.md §9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.meta_update import ops as mu_ops
from repro.sharding.context import get_mesh
from repro.utils.flat import FlatPlane, plane_for
from repro.utils.pytree import tree_add, tree_scale, tree_zeros_like


def _normalize_weights(weights, m):
    if weights is None:
        return jnp.full((m,), 1.0 / m, jnp.float32)
    weights = weights.astype(jnp.float32)
    return weights / jnp.sum(weights)


def _pad_client_axis(support, query, w, m, multiple):
    """Pad the leading client axis to a multiple of ``multiple`` with
    zero-weight copies of client 0 (w is already normalized, so the
    padding contributes exactly nothing to gradients or metrics)."""
    pad = (-m) % multiple
    if pad:
        idx = jnp.concatenate(
            [jnp.arange(m), jnp.zeros((pad,), jnp.int32)])
        support, query = jax.tree.map(lambda x: x[idx], (support, query))
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return support, query, w, m + pad


def _chunk_client_axis(support, query, w, m, chunk):
    """Reshape the leading client axis m -> (n_chunks, chunk), padding the
    tail with zero-weight copies of client 0 when chunk ∤ m."""
    support, query, w, m_pad = _pad_client_axis(support, query, w, m, chunk)
    n_chunks = m_pad // chunk

    def split(x):
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    support, query = jax.tree.map(split, (support, query))
    return support, query, w.reshape(n_chunks, chunk)


def _resolve_mesh(mesh, mesh_axis):
    """The mesh + axis name clients shard over.

    Precedence: explicit ``mesh=`` > the ambient mesh
    (sharding/context.py, set by the launcher) > a 1-axis "clients"
    mesh over every visible device — so ``client_axis="sharded"`` works
    out of the box on a plain host while launchers keep full control of
    device placement."""
    mesh = mesh or get_mesh()
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("clients",))
    return mesh, (mesh_axis or mesh.axis_names[0])


def _weighted_metrics(w, mets):
    """Per-client metrics (leading m axis) -> weighted scalar summary.

    Identical reduction on every client axis, so vmap/scan/chunked report
    the same numbers (the scan path previously took an unweighted mean)."""
    return jax.tree.map(lambda x: jnp.sum(w * x), mets)


def _scan_chunks(chunk_fn, acc0, add, support, query, w, m, chunk):
    """Scan-of-chunks reduction shared by the "chunked" axis and the
    per-device execution of the "sharded" axis.

    chunk_fn(s, q, wc) -> (partial aggregate, per-chunk weighted
    metrics); ``add`` combines partials into the ``acc0``-shaped carry.
    Returns (aggregate, metric sums)."""
    sup_c, qry_c, w_c = _chunk_client_axis(support, query, w, m, chunk)

    def body(acc, inp):
        partial, mets = chunk_fn(*inp)
        return add(acc, partial), mets

    acc, msums = jax.lax.scan(body, acc0, (sup_c, qry_c, w_c))
    return acc, jax.tree.map(jnp.sum, msums)


def _sharded_reduce(chunk_fn, acc0, add, support, query, w, m, client_chunk,
                    mesh, mesh_axis):
    """shard_map reduction shared by the tree and packed pipelines.

    Clients are padded to a device multiple and split over the mesh
    axis; each device runs chunk_fn on its local clients (scan of
    chunks when client_chunk is set) and the partial aggregates and
    weighted metrics are psum-reduced to replicated outputs. chunk_fn's
    aggregate may be a flat array or a pytree — psum maps over leaves."""
    msh, ax = _resolve_mesh(mesh, mesh_axis)
    sup_p, qry_p, w_p, m_pad = _pad_client_axis(
        support, query, w, m, msh.shape[ax])
    m_loc = m_pad // msh.shape[ax]

    def local_fn(s, q, wl):
        if client_chunk and client_chunk < m_loc:
            partial, pm = _scan_chunks(
                chunk_fn, acc0, add, s, q, wl, m_loc, client_chunk)
        else:
            partial, pm = chunk_fn(s, q, wl)
        psum = lambda t: jax.tree.map(      # noqa: E731
            lambda x: jax.lax.psum(x, ax), t)
        return psum(partial), psum(pm)

    return shard_map(
        local_fn, mesh=msh, in_specs=(P(ax), P(ax), P(ax)),
        out_specs=(P(), P()), check_rep=False)(sup_p, qry_p, w_p)


def federated_meta_step(algo, optimizer, phi, opt_state, support, query,
                        weights=None, *, client_axis: str = "vmap",
                        client_chunk: int | None = None, mesh=None,
                        mesh_axis: str | None = None):
    """support/query: pytrees with leading client axis m on each leaf.
    weights: (m,) aggregation weights (paper A.2 weights by local data
    count); None = uniform 1/m. Returns (phi, opt_state, metrics).
    mesh/mesh_axis: only for client_axis="sharded" (default: the ambient
    mesh from sharding.context, its first axis)."""
    m = jax.tree.leaves(support)[0].shape[0]
    w = _normalize_weights(weights, m)

    def tree_chunk(s, q, wc):
        """Weighted per-leaf partial + weighted metrics for one chunk."""
        gs, mets = jax.vmap(
            lambda s_, q_: algo.client_grad(phi, s_, q_))(s, q)
        partial = jax.tree.map(
            lambda g: jnp.tensordot(wc, g.astype(jnp.float32), axes=1), gs)
        return partial, _weighted_metrics(wc, mets)

    def tree_acc0():
        return tree_zeros_like(
            jax.tree.map(lambda x: x.astype(jnp.float32), phi))

    if client_axis == "vmap":
        meta_g, metrics = tree_chunk(support, query, w)
    elif client_axis == "scan":
        def body(acc, inp):
            s, q, wi = inp
            g, met = algo.client_grad(phi, s, q)
            acc = tree_add(acc, tree_scale(
                jax.tree.map(lambda x: x.astype(jnp.float32), g), wi))
            return acc, met

        meta_g, mets = jax.lax.scan(body, tree_acc0(), (support, query, w))
        metrics = _weighted_metrics(w, mets)
    elif client_axis == "chunked":
        meta_g, metrics = _scan_chunks(
            tree_chunk, tree_acc0(), tree_add, support, query, w, m,
            client_chunk or min(m, 8))
    elif client_axis == "sharded":
        meta_g, metrics = _sharded_reduce(
            tree_chunk, tree_acc0(), tree_add, support, query, w, m,
            client_chunk, mesh, mesh_axis)
    else:
        raise ValueError(client_axis)

    new_phi, new_opt = optimizer.update(phi, meta_g, opt_state)
    return new_phi, new_opt, metrics


def _maybe_jit(step, jit: bool, donate: bool):
    if not jit:
        return step
    # buffer donation lets φ/opt-state update in place; XLA:CPU does not
    # implement donation and would warn on every call, so gate on backend
    if donate and jax.default_backend() != "cpu":
        return jax.jit(step, donate_argnums=(0,))
    return jax.jit(step)


def make_meta_train_step(algo, optimizer, *, client_axis: str = "vmap",
                         client_chunk: int | None = None, mesh=None,
                         mesh_axis: str | None = None, jit: bool = True,
                         donate: bool = True):
    """-> step(state, support, query, weights) with state = {phi, opt}."""

    def step(state, support, query, weights=None):
        phi, opt_state, metrics = federated_meta_step(
            algo, optimizer, state["phi"], state["opt"], support, query,
            weights, client_axis=client_axis, client_chunk=client_chunk,
            mesh=mesh, mesh_axis=mesh_axis)
        return {"phi": phi, "opt": opt_state}, metrics

    return _maybe_jit(step, jit, donate)


# ---- packed parameter plane pipeline ------------------------------------

def init_packed_state(optimizer, plane: FlatPlane, phi, *, staleness=None,
                      clients_per_round=None, block_dtype=None,
                      compression=None, num_clients=None):
    """φ pytree -> {"phi": flat plane, "opt": flat optimizer state}.

    With ``staleness`` set (async_engine.StalenessConfig), the state
    additionally carries the in-flight straggler buffer: a
    ``(delay, k, N)`` ring of not-yet-arrived gradient rows plus their
    ``(delay, k)`` original aggregation weights, zero-initialized so
    the warmup rounds aggregate fresh rows only. With ``jitter`` on, the
    ring rows additionally carry their remaining-rounds counter ``c``
    and original drawn delay ``d`` (per-row γ^d on arrival).

    With ``compression`` set (kernels.meta_update.CompressionConfig)
    and error feedback on, the state carries the per-client residual
    plane: a ``(num_clients, N)`` f32 buffer of quantization errors not
    yet uploaded, zero-initialized (first participation compresses the
    raw gradient). It lives in train state, so checkpoints capture it
    and resumed runs replay bit-identically (DESIGN.md §17)."""
    from repro.optim.optimizers import make_flat_optimizer
    flat = plane.pack(phi)
    state = {"phi": flat, "opt": make_flat_optimizer(optimizer).init(flat)}
    if compression is not None and compression.error_feedback:
        if num_clients is None:
            raise ValueError("error feedback needs num_clients (total "
                             "train clients) to size the residual plane")
        state["ef"] = jnp.zeros((num_clients, plane.n_padded), jnp.float32)
    if staleness is not None:
        if clients_per_round is None:
            raise ValueError("staleness needs clients_per_round to size "
                             "the straggler buffer")
        k = staleness.num_stragglers(clients_per_round)
        bd = block_dtype or jnp.float32
        state["stale"] = {
            "G": jnp.zeros((staleness.delay, k, plane.n_padded), bd),
            "w": jnp.zeros((staleness.delay, k), jnp.float32)}
        if staleness.jitter:
            state["stale"]["c"] = jnp.zeros((staleness.delay, k), jnp.int32)
            state["stale"]["d"] = jnp.zeros((staleness.delay, k), jnp.int32)
    return state


def make_packed_meta_train_step(algo, optimizer, plane: FlatPlane, *,
                                client_axis: str = "vmap",
                                client_chunk: int | None = None,
                                impl: str | None = None,
                                block_dtype=None,
                                client_plane: bool = False,
                                staleness=None,
                                aggregator: str = "mean",
                                screen_factor: float = 3.0,
                                trim: int = 1,
                                faults=None,
                                guard: bool = False,
                                compression=None,
                                dp=None,
                                mesh=None, mesh_axis: str | None = None,
                                jit: bool = True, donate: bool = True):
    """Meta-train step over the packed plane: state = {phi: (N,), opt}.

    φ is unpacked to a pytree exactly once per round (the client model
    needs structured parameters); everything after the per-client grads —
    aggregation and the outer Adam — stays on flat buffers. ``impl``
    picks xla / pallas / pallas_interpret for the fused kernels (None =
    the ``REPRO_META_UPDATE_IMPL`` default). ``block_dtype`` sets the
    dtype of the packed client-gradient block (None = f32, exact;
    bfloat16 halves the aggregation traffic and models a half-precision
    client upload — the fused ops still accumulate in f32; see
    DESIGN.md §2).

    ``client_plane=True`` additionally runs the *inner loop* on flat
    memory: each chunk of clients adapts in lockstep on a (C, N) client
    plane via the fused inner-update kernel, and per-client
    meta-gradients come out flat (``algo.client_grad_chunk_packed``) —
    no per-client pytree pack, the whole round is flat end-to-end
    except the model forward/backward itself (DESIGN.md §9).
    ``client_axis="sharded"`` splits clients over the devices of
    ``mesh`` (default: the ambient mesh); each device reduces its local
    block with the packed aggregation kernel and the (N,) partials are
    psum-reduced into the meta-gradient (DESIGN.md §10).

    ``staleness`` (async_engine.StalenessConfig; vmap axis only) turns
    on staleness-aware aggregation: the step takes an extra
    ``stale_sel=(straggler_idx, fresh_idx)`` input naming which of the
    round's clients straggle. Straggler rows of the (m, N) gradient
    block are detoured through the state's ``(delay, k, N)`` ring
    buffer and replaced in the aggregation by the rows that arrive
    this round — weighted by their original data-count weight times
    ``discount**delay`` and renormalized over the aggregated rows.
    Fresh and stale rows go through the SAME fused weighted-aggregate
    kernel, so the hot path stays one flat pass (DESIGN.md §12).

    The failure plane (DESIGN.md §14) adds four orthogonal knobs, all
    defaulting to off and all leaving the default graph bitwise
    untouched when off:

      * ``aggregator`` ∈ ``kernels.meta_update.ops.AGGREGATORS`` picks
        the (m, N) → (N,) reduction ("mean" = today's exact path;
        masked_mean / screen / trimmed are the robust modes — see
        ``robust_aggregate``). ``screen_factor``/``trim`` parameterize
        the screen threshold and per-coordinate trim count.
      * ``faults`` (federated.faults.FaultConfig; vmap axis only) makes
        the step take an extra per-round ``fault`` mask tuple and
        corrupts the gradient block *before* aggregation — dropped rows
        zero their weight, non-finite rows turn NaN, Byzantine rows are
        adversarially rewritten. Composes with ``staleness``: corrupted
        rows flow through the ring like honest ones.
      * ``guard`` turns on the fused non-finite check: one reduction
        over the flat meta-gradient; if anything is non-finite the
        round is *skipped* — φ and the optimizer state pass through
        unchanged (the staleness ring still advances: arrivals
        happened) — and the round's metrics carry ``skipped=1``.

    The bytes-on-the-wire plane (DESIGN.md §17) adds two more, both
    vmap-axis only and both bitwise no-ops when off:

      * ``compression`` (kernels.meta_update.CompressionConfig) encodes
        each client row of the (m, N) block — int8 per-row-scaled or
        top-k-sparsified — and aggregates the *encoded* uploads through
        the fused weighted kernel (dequantization folds into the
        weights / a scatter). With error feedback the step takes an
        extra ``ef_idx`` input (this round's picked-client indices into
        the state's ``(num_clients, N)`` residual plane): the residual
        rejoins the gradient before encoding and the new residual is
        scattered back. When the same client is picked twice in one
        round, the LAST row's residual wins (one upload channel per
        client per round).
      * ``dp`` (federated.privacy.DPConfig) applies the central-DP clip
        as aggregation-weight scaling — per-row norms are computed in
        the codec domain (s·‖q‖ / ‖topk values‖ / ‖g‖), so clipping
        composes with compression without decoding — and adds
        N(0, σ²·I) with σ = z·S/m to the aggregated meta-gradient
        (noise masked to the n_real live coordinates; the plane's
        alignment padding stays zero). The step then takes an extra
        per-round ``dp_key`` input (pure function of the round index —
        see ``DPConfig.round_key``).

    Composition order with both on: EF-correct → encode → clip (weight
    scale) → fused aggregate → noise (§17).
    """
    from repro.federated.faults import apply_faults
    from repro.federated.privacy import dp_clip_factors
    from repro.kernels.meta_update.compress import (int8_row_norms,
                                                    topk_encode,
                                                    topk_row_norms)
    from repro.optim.optimizers import make_flat_optimizer
    impl = mu_ops.resolve_impl(impl)
    flat_opt = make_flat_optimizer(optimizer, impl=impl)
    bd = block_dtype or jnp.float32
    if aggregator not in mu_ops.AGGREGATORS:
        raise ValueError(f"unknown aggregator {aggregator!r}; expected "
                         f"one of {mu_ops.AGGREGATORS}")
    robust = aggregator != "mean"
    if staleness is not None and client_axis != "vmap":
        raise ValueError("staleness-aware aggregation needs the full "
                         "(m, N) gradient block before the reduce — "
                         "client_axis='vmap' only")
    if (faults is not None or robust) and client_axis != "vmap":
        raise ValueError("fault injection / robust aggregation need the "
                         "full (m, N) gradient block before the reduce — "
                         "client_axis='vmap' only")
    if compression is not None or dp is not None:
        if client_axis != "vmap":
            raise ValueError("compression / DP need the full (m, N) "
                             "gradient block before the reduce — "
                             "client_axis='vmap' only")
        if staleness is not None or faults is not None or robust:
            raise ValueError("compression / DP compose with each other "
                             "but not with staleness, faults, or robust "
                             "aggregators — the codec/clip semantics of "
                             "ring rows and corrupted rows are undefined")

    def aggregate(G, w_agg, *, prenorm):
        """The (m, N) → (N,) reduce. ``prenorm`` marks the staleness
        call sites whose historical mean path normalizes the weights
        itself — kept verbatim so mean mode stays bitwise identical."""
        if aggregator == "mean":
            if prenorm:
                w_agg = w_agg / jnp.sum(w_agg)
            return mu_ops.weighted_aggregate(G, w_agg, impl=impl)
        return mu_ops.robust_aggregate(
            G, w_agg, aggregator=aggregator, impl=impl,
            screen_factor=screen_factor, trim=trim)

    def finish(state, meta_g, metrics, extra=None):
        """Outer optimizer step + optional non-finite guard."""
        new_flat, new_opt = flat_opt.update(state["phi"], meta_g,
                                            state["opt"])
        if guard:
            # one fused reduce over the flat plane; skip-and-log round
            # semantics: a non-finite meta-gradient leaves φ AND the
            # optimizer state (incl. Adam's step count) untouched
            ok = jnp.all(jnp.isfinite(meta_g))
            new_flat = jnp.where(ok, new_flat, state["phi"])
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state["opt"])
            metrics = {**metrics,
                       "skipped": jnp.logical_not(ok).astype(jnp.float32)}
        new_state = {"phi": new_flat, "opt": new_opt}
        if extra is not None:
            new_state.update(extra)
        return new_state, metrics

    def step(state, support, query, weights=None, stale_sel=None,
             fault=None, ef_idx=None, dp_key=None):
        phi = plane.unpack(state["phi"])
        m = jax.tree.leaves(support)[0].shape[0]
        w = _normalize_weights(weights, m)

        if client_plane:
            tplane = plane_for(phi["theta"])

            def chunk_grads(s, q):
                """(C, N) gradient rows + metrics for a chunk of clients,
                computed on the flat client plane."""
                G, mets = algo.client_grad_chunk_packed(
                    plane, tplane, phi, s, q, impl=impl)
                return G.astype(bd), mets
        else:
            def one_packed(s, q):
                g, met = algo.client_grad(phi, s, q)
                return plane.pack(g, bd), met

            def chunk_grads(s, q):
                return jax.vmap(one_packed)(s, q)

        def packed_chunk(s, q, wc):
            """Fused (N,) weighted partial + weighted metrics for one
            chunk of clients."""
            G, mets = chunk_grads(s, q)
            return (mu_ops.weighted_aggregate(G, wc, impl=impl),
                    _weighted_metrics(wc, mets))

        if staleness is not None and staleness.jitter:
            # jittered stragglers: each ring row carries its own drawn
            # delay d ∈ [0, delay] and a remaining-rounds counter c; a
            # row rejoins the aggregation the round its counter hits 0
            # at weight w·γ^d (d = its ACTUAL staleness), then its
            # weight zeroes so it cannot arrive twice before falling
            # off the ring. d = 0 stragglers join their own round like
            # fresh rows (γ^0 = 1). The aggregation block is the m
            # current rows plus ALL delay·k ring rows — still static
            # shapes, still one pass through the fused kernel.
            strag, fresh, delays = stale_sel
            G, mets = chunk_grads(support, query)
            if faults is not None:
                G, w, w_rep = apply_faults(faults, G, w, fault)
            else:
                w_rep = w
            metrics = _weighted_metrics(w_rep, mets)
            buf = state["stale"]
            c = buf["c"] - 1
            arrive = (c <= 0) & (buf["w"] > 0)
            gamma_d = jnp.float32(staleness.discount) ** \
                buf["d"].astype(jnp.float32)
            arrived_w = jnp.where(arrive, buf["w"] * gamma_d, 0.0)
            dk = buf["G"].shape[0] * buf["G"].shape[1]
            agg_G = jnp.concatenate(
                [G[fresh], G[strag],
                 buf["G"].reshape(dk, buf["G"].shape[2])], axis=0)
            agg_w = jnp.concatenate(
                [w[fresh], jnp.where(delays == 0, w[strag], 0.0),
                 arrived_w.reshape(dk)], axis=0)
            meta_g = aggregate(agg_G, agg_w, prenorm=True)
            kept_w = jnp.where(arrive, 0.0, buf["w"])
            new_stale = {
                "G": jnp.concatenate([buf["G"][1:], G[strag][None]], axis=0),
                "w": jnp.concatenate(
                    [kept_w[1:],
                     jnp.where(delays > 0, w[strag], 0.0)[None]], axis=0),
                "c": jnp.concatenate([c[1:], delays[None]], axis=0),
                "d": jnp.concatenate([buf["d"][1:], delays[None]], axis=0)}
            return finish(state, meta_g, metrics, {"stale": new_stale})

        if staleness is not None:
            # straggler rows detour through the delay ring; arrived rows
            # (computed against φ from `delay` rounds ago) rejoin the
            # aggregation block at weight w·γ^delay — still one (m, N)
            # pass through the fused kernel
            strag, fresh = stale_sel
            G, mets = chunk_grads(support, query)
            if faults is not None:
                G, w, w_rep = apply_faults(faults, G, w, fault)
            else:
                w_rep = w
            metrics = _weighted_metrics(w_rep, mets)
            buf = state["stale"]
            arrived_w = buf["w"][0] * jnp.float32(
                staleness.discount ** staleness.delay)
            agg_G = jnp.concatenate([G[fresh], buf["G"][0]], axis=0)
            agg_w = jnp.concatenate([w[fresh], arrived_w], axis=0)
            meta_g = aggregate(agg_G, agg_w, prenorm=True)
            new_stale = {
                "G": jnp.concatenate([buf["G"][1:], G[strag][None]], axis=0),
                "w": jnp.concatenate([buf["w"][1:], w[strag][None]], axis=0)}
            return finish(state, meta_g, metrics, {"stale": new_stale})

        if compression is not None or dp is not None:
            # bytes-on-the-wire plane (§17): EF-correct -> encode ->
            # clip-as-weight-scale -> fused aggregate -> noise. Taken
            # only when a knob is on, so the default graphs below stay
            # bitwise identical.
            G, mets = chunk_grads(support, query)
            metrics = _weighted_metrics(w, mets)
            extra = None
            w_agg = w
            if compression is not None:
                corrected = G.astype(jnp.float32)
                if compression.error_feedback:
                    corrected = corrected + state["ef"][ef_idx]
                if compression.codec == "int8":
                    q, scales, resid = mu_ops.int8_encode(
                        corrected, impl=impl)
                    if dp is not None:
                        w_agg = w * dp_clip_factors(
                            int8_row_norms(q, scales), dp.clip_norm)
                    meta_g = mu_ops.int8_aggregate(
                        q, scales, w_agg, impl=impl)
                else:
                    vals, idx, resid = topk_encode(
                        corrected, compression.k_for(plane.n_real),
                        val_dtype=bd)
                    if dp is not None:
                        w_agg = w * dp_clip_factors(
                            topk_row_norms(vals), dp.clip_norm)
                    meta_g = mu_ops.topk_aggregate(
                        vals, idx, w_agg, plane.n_padded, impl=impl)
                if compression.error_feedback:
                    extra = {"ef": state["ef"].at[ef_idx].set(resid)}
            else:
                norms = jnp.sqrt(jnp.sum(
                    jnp.square(G.astype(jnp.float32)), axis=1))
                w_agg = w * dp_clip_factors(norms, dp.clip_norm)
                meta_g = mu_ops.weighted_aggregate(G, w_agg, impl=impl)
            if dp is not None and dp.noise_multiplier > 0:
                live = (jnp.arange(plane.n_padded)
                        < plane.n_real).astype(jnp.float32)
                meta_g = meta_g + jnp.float32(dp.sigma(m)) * live * \
                    jax.random.normal(dp_key, (plane.n_padded,),
                                      jnp.float32)
            return finish(state, meta_g, metrics, extra)

        if client_axis == "vmap" and (faults is not None or robust):
            # the failure plane needs the (m, N) block before the
            # reduce; taken only when a knob is on, so the default
            # vmap graph below stays bitwise identical
            G, mets = chunk_grads(support, query)
            if faults is not None:
                G, w_agg, w_rep = apply_faults(faults, G, w, fault)
            else:
                w_agg = w_rep = w
            metrics = _weighted_metrics(w_rep, mets)
            meta_g = aggregate(G, w_agg, prenorm=False)
        elif client_axis == "vmap":
            meta_g, metrics = packed_chunk(support, query, w)
        elif client_axis == "scan":
            def body(acc, inp):
                s, q, wi = inp
                if client_plane:
                    G, met = chunk_grads(
                        *jax.tree.map(lambda x: x[None], (s, q)))
                    g, met = G[0], jax.tree.map(lambda x: x[0], met)
                else:
                    g, met = one_packed(s, q)
                return acc + wi * g.astype(jnp.float32), met

            meta_g, mets = jax.lax.scan(
                body, plane.zeros(), (support, query, w))
            metrics = _weighted_metrics(w, mets)
        elif client_axis == "chunked":
            meta_g, metrics = _scan_chunks(
                packed_chunk, plane.zeros(), jnp.add, support, query, w,
                m, client_chunk or min(m, 8))
        elif client_axis == "sharded":
            meta_g, metrics = _sharded_reduce(
                packed_chunk, plane.zeros(), jnp.add, support, query, w,
                m, client_chunk, mesh, mesh_axis)
        else:
            raise ValueError(client_axis)

        return finish(state, meta_g, metrics)

    return _maybe_jit(step, jit, donate)
