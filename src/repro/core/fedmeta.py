"""The FedMeta server round (paper Algorithm 1, AlgorithmUpdate).

One meta-training round:
  1. a batch of m sampled clients' (support, query) data arrives with a
     leading client axis on every leaf,
  2. every client computes g_u = ModelTraining(φ; D_S^u, D_Q^u),
  3. the server updates φ with the (weighted) average of the g_u via the
     outer optimizer (Adam here, per paper A.2).

Three client execution strategies (memory/throughput tradeoff in
DESIGN.md §4):
  - "vmap": all clients in parallel (paper's `for u in parallel`; right
    choice for small models / CPU simulation),
  - "scan": clients sequential with a meta-gradient accumulator carry —
    memory-optimal (one adapted θ_u lives at a time),
  - "chunked": scan over chunks of vmapped clients — peak memory scales
    with the chunk size, not clients-per-round, while keeping vmap
    throughput inside each chunk. m need not divide the chunk size;
    the tail chunk is padded with zero-weight duplicate clients.

Two parameter representations:
  - tree (default): φ stays a pytree; aggregation and the outer step run
    per-leaf,
  - packed plane (``make_packed_meta_train_step``): φ lives in one flat
    128-lane-aligned f32 buffer (utils/flat.py); client gradients are
    packed to an (m, N) block, reduced by the fused aggregation kernel,
    and φ is advanced by the fused outer-Adam kernel — the whole server
    side of the round is two passes over flat memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.meta_update import ops as mu_ops
from repro.utils.flat import FlatPlane
from repro.utils.pytree import tree_add, tree_scale, tree_zeros_like


def _normalize_weights(weights, m):
    if weights is None:
        return jnp.full((m,), 1.0 / m, jnp.float32)
    weights = weights.astype(jnp.float32)
    return weights / jnp.sum(weights)


def _chunk_client_axis(support, query, w, m, chunk):
    """Reshape the leading client axis m -> (n_chunks, chunk), padding the
    tail with zero-weight copies of client 0 when chunk ∤ m."""
    pad = (-m) % chunk
    if pad:
        idx = jnp.concatenate(
            [jnp.arange(m), jnp.zeros((pad,), jnp.int32)])
        support, query = jax.tree.map(lambda x: x[idx], (support, query))
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    n_chunks = (m + pad) // chunk

    def split(x):
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    support, query = jax.tree.map(split, (support, query))
    return support, query, w.reshape(n_chunks, chunk)


def _weighted_metrics(w, mets):
    """Per-client metrics (leading m axis) -> weighted scalar summary.

    Identical reduction on every client axis, so vmap/scan/chunked report
    the same numbers (the scan path previously took an unweighted mean)."""
    return jax.tree.map(lambda x: jnp.sum(w * x), mets)


def federated_meta_step(algo, optimizer, phi, opt_state, support, query,
                        weights=None, *, client_axis: str = "vmap",
                        client_chunk: int | None = None):
    """support/query: pytrees with leading client axis m on each leaf.
    weights: (m,) aggregation weights (paper A.2 weights by local data
    count); None = uniform 1/m. Returns (phi, opt_state, metrics)."""
    m = jax.tree.leaves(support)[0].shape[0]
    w = _normalize_weights(weights, m)

    if client_axis == "vmap":
        gs, mets = jax.vmap(
            lambda s, q: algo.client_grad(phi, s, q))(support, query)
        meta_g = jax.tree.map(
            lambda g: jnp.tensordot(w, g.astype(jnp.float32), axes=1), gs)
        metrics = _weighted_metrics(w, mets)
    elif client_axis == "scan":
        def body(acc, inp):
            s, q, wi = inp
            g, met = algo.client_grad(phi, s, q)
            acc = tree_add(acc, tree_scale(
                jax.tree.map(lambda x: x.astype(jnp.float32), g), wi))
            return acc, met

        acc0 = tree_zeros_like(
            jax.tree.map(lambda x: x.astype(jnp.float32), phi))
        meta_g, mets = jax.lax.scan(body, acc0, (support, query, w))
        metrics = _weighted_metrics(w, mets)
    elif client_axis == "chunked":
        chunk = client_chunk or min(m, 8)
        sup_c, qry_c, w_c = _chunk_client_axis(support, query, w, m, chunk)

        def body(acc, inp):
            s, q, wc = inp
            gs, mets = jax.vmap(
                lambda s_, q_: algo.client_grad(phi, s_, q_))(s, q)
            partial = jax.tree.map(
                lambda g: jnp.tensordot(wc, g.astype(jnp.float32), axes=1),
                gs)
            return tree_add(acc, partial), _weighted_metrics(wc, mets)

        acc0 = tree_zeros_like(
            jax.tree.map(lambda x: x.astype(jnp.float32), phi))
        meta_g, msums = jax.lax.scan(body, acc0, (sup_c, qry_c, w_c))
        metrics = jax.tree.map(jnp.sum, msums)
    else:
        raise ValueError(client_axis)

    new_phi, new_opt = optimizer.update(phi, meta_g, opt_state)
    return new_phi, new_opt, metrics


def _maybe_jit(step, jit: bool, donate: bool):
    if not jit:
        return step
    # buffer donation lets φ/opt-state update in place; XLA:CPU does not
    # implement donation and would warn on every call, so gate on backend
    if donate and jax.default_backend() != "cpu":
        return jax.jit(step, donate_argnums=(0,))
    return jax.jit(step)


def make_meta_train_step(algo, optimizer, *, client_axis: str = "vmap",
                         client_chunk: int | None = None, jit: bool = True,
                         donate: bool = True):
    """-> step(state, support, query, weights) with state = {phi, opt}."""

    def step(state, support, query, weights=None):
        phi, opt_state, metrics = federated_meta_step(
            algo, optimizer, state["phi"], state["opt"], support, query,
            weights, client_axis=client_axis, client_chunk=client_chunk)
        return {"phi": phi, "opt": opt_state}, metrics

    return _maybe_jit(step, jit, donate)


# ---- packed parameter plane pipeline ------------------------------------

def init_packed_state(optimizer, plane: FlatPlane, phi):
    """φ pytree -> {"phi": flat plane, "opt": flat optimizer state}."""
    from repro.optim.optimizers import make_flat_optimizer
    flat = plane.pack(phi)
    return {"phi": flat, "opt": make_flat_optimizer(optimizer).init(flat)}


def make_packed_meta_train_step(algo, optimizer, plane: FlatPlane, *,
                                client_axis: str = "vmap",
                                client_chunk: int | None = None,
                                impl: str | None = None,
                                block_dtype=None, jit: bool = True,
                                donate: bool = True):
    """Meta-train step over the packed plane: state = {phi: (N,), opt}.

    φ is unpacked to a pytree exactly once per round (the client model
    needs structured parameters); everything after the per-client grads —
    aggregation and the outer Adam — stays on flat buffers. ``impl``
    picks xla / pallas / pallas_interpret for both fused server kernels
    (None = the ``REPRO_META_UPDATE_IMPL`` default). ``block_dtype``
    sets the dtype of the packed client-gradient block (None = f32,
    exact; bfloat16 halves the aggregation traffic and models a
    half-precision client upload — the fused ops still accumulate in
    f32; see DESIGN.md §2).
    """
    from repro.optim.optimizers import make_flat_optimizer
    impl = mu_ops.resolve_impl(impl)
    flat_opt = make_flat_optimizer(optimizer, impl=impl)
    bd = block_dtype or jnp.float32

    def step(state, support, query, weights=None):
        phi = plane.unpack(state["phi"])
        m = jax.tree.leaves(support)[0].shape[0]
        w = _normalize_weights(weights, m)

        def one_packed(s, q):
            g, met = algo.client_grad(phi, s, q)
            return plane.pack(g, bd), met

        if client_axis == "vmap":
            G, mets = jax.vmap(one_packed)(support, query)
            meta_g = mu_ops.weighted_aggregate(G, w, impl=impl)
            metrics = _weighted_metrics(w, mets)
        elif client_axis == "scan":
            def body(acc, inp):
                s, q, wi = inp
                g, met = one_packed(s, q)
                return acc + wi * g.astype(jnp.float32), met

            meta_g, mets = jax.lax.scan(
                body, plane.zeros(), (support, query, w))
            metrics = _weighted_metrics(w, mets)
        elif client_axis == "chunked":
            chunk = client_chunk or min(m, 8)
            sup_c, qry_c, w_c = _chunk_client_axis(
                support, query, w, m, chunk)

            def body(acc, inp):
                s, q, wc = inp
                G, mets = jax.vmap(one_packed)(s, q)
                partial = mu_ops.weighted_aggregate(G, wc, impl=impl)
                return acc + partial, _weighted_metrics(wc, mets)

            meta_g, msums = jax.lax.scan(
                body, plane.zeros(), (sup_c, qry_c, w_c))
            metrics = jax.tree.map(jnp.sum, msums)
        else:
            raise ValueError(client_axis)

        new_flat, new_opt = flat_opt.update(state["phi"], meta_g,
                                            state["opt"])
        return {"phi": new_flat, "opt": new_opt}, metrics

    return _maybe_jit(step, jit, donate)
