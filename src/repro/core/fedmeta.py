"""The FedMeta server round (paper Algorithm 1, AlgorithmUpdate).

One meta-training round:
  1. a batch of m sampled clients' (support, query) data arrives with a
     leading client axis on every leaf,
  2. every client computes g_u = ModelTraining(φ; D_S^u, D_Q^u),
  3. the server updates φ with the (weighted) average of the g_u via the
     outer optimizer (Adam here, per paper A.2).

Two client execution strategies:
  - "vmap": all clients in parallel (paper's `for u in parallel`; right
    choice for small models / CPU simulation),
  - "scan": clients sequential with a meta-gradient accumulator carry —
    the TPU-native, memory-optimal mapping used for the large LM configs
    (one adapted θ_u lives at a time; see DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_add, tree_scale, tree_zeros_like


def federated_meta_step(algo, optimizer, phi, opt_state, support, query,
                        weights=None, *, client_axis: str = "vmap"):
    """support/query: pytrees with leading client axis m on each leaf.
    weights: (m,) aggregation weights (paper A.2 weights by local data
    count); None = uniform 1/m. Returns (phi, opt_state, metrics)."""
    m = jax.tree.leaves(support)[0].shape[0]
    if weights is None:
        w = jnp.full((m,), 1.0 / m, jnp.float32)
    else:
        w = weights / jnp.sum(weights)

    if client_axis == "vmap":
        gs, metrics = jax.vmap(
            lambda s, q: algo.client_grad(phi, s, q))(support, query)
        meta_g = jax.tree.map(
            lambda g: jnp.tensordot(w, g.astype(jnp.float32), axes=1), gs)
        metrics = jax.tree.map(lambda x: jnp.sum(w * x), metrics)
    elif client_axis == "scan":
        def body(carry, inp):
            acc, k = carry
            s, q, wi = inp
            g, met = algo.client_grad(phi, s, q)
            acc = tree_add(acc, tree_scale(
                jax.tree.map(lambda x: x.astype(jnp.float32), g), wi))
            return (acc, k + 1), met

        acc0 = tree_zeros_like(
            jax.tree.map(lambda x: x.astype(jnp.float32), phi))
        (meta_g, _), mets = jax.lax.scan(body, (acc0, 0), (support, query, w))
        metrics = jax.tree.map(lambda x: jnp.mean(x), mets)
    else:
        raise ValueError(client_axis)

    new_phi, new_opt = optimizer.update(phi, meta_g, opt_state)
    return new_phi, new_opt, metrics


def make_meta_train_step(algo, optimizer, *, client_axis: str = "vmap",
                         jit: bool = True):
    """-> step(state, support, query, weights) with state = {phi, opt}."""

    def step(state, support, query, weights=None):
        phi, opt_state, metrics = federated_meta_step(
            algo, optimizer, state["phi"], state["opt"], support, query,
            weights, client_axis=client_axis)
        return {"phi": phi, "opt": opt_state}, metrics

    return jax.jit(step) if jit else step
