# The paper's primary contribution: FedMeta — federated meta-learning.
from repro.core.algorithms import (MAML, FOMAML, MetaSGD, Reptile,
                                   MetaAlgorithm, make_algorithm)
from repro.core.fedmeta import federated_meta_step, make_meta_train_step
from repro.core.losses import (classification_loss, lm_loss, lm_pair_loss,
                               softmax_xent, accuracy, topk_accuracy)
