"""Loss / metric functions shared by FedMeta and the baselines."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels):
    """Mean cross entropy. logits: (..., C) f32; labels: (...) int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def topk_accuracy(logits, labels, k: int):
    topk = jax.lax.top_k(logits, k)[1]                       # (..., k)
    hit = jnp.any(topk == labels[..., None], axis=-1)
    return jnp.mean(hit.astype(jnp.float32))


def classification_loss(apply_fn, topk=()):
    """-> loss_fn(params, (x, y)) and eval_fn(params, (x, y))->(loss, metrics).

    ``topk`` adds ``top{k}`` accuracy metrics (paper §4.3 reports Top-1 and
    Top-4 on the production recommendation task). This builder also serves
    the *local-head* convention of that scenario: labels may be client-local
    ids (``data/synth_recommend.localize_clients``) over a small head
    instead of global service ids over the full catalogue — the loss/eval
    math is unchanged, only the label space (and therefore the model's
    output width, the θ-size asymmetry of DESIGN.md §13) differs.
    """

    def loss_fn(params, batch):
        x, y = batch
        return softmax_xent(apply_fn(params, x), y)

    def eval_fn(params, batch):
        x, y = batch
        logits = apply_fn(params, x)
        metrics = {"accuracy": accuracy(logits, y)}
        for k in topk:
            metrics[f"top{k}"] = topk_accuracy(logits, y, k)
        return softmax_xent(logits, y), metrics

    return loss_fn, eval_fn


def lm_loss(apply_fn):
    """Next-token LM loss over token batches.

    Batches are either a (B, L) token array or a dict with "tokens"
    (+ "embeds" for modality archs — consumed by apply_fn).
    apply_fn(params, batch) -> (logits (B, L', V), aux) — aux (e.g. MoE
    load-balance loss) is added to the objective so the router trains in
    both FedMeta loops. L' may include a modality prefix; loss aligns to
    the last L text positions."""

    def _tokens(batch):
        return batch["tokens"] if isinstance(batch, dict) else batch

    def loss_fn(params, batch):
        tokens = _tokens(batch)
        logits, aux = apply_fn(params, batch)
        logits = logits[:, -tokens.shape[1]:]
        return softmax_xent(logits[:, :-1], tokens[:, 1:]) + aux

    def eval_fn(params, batch):
        tokens = _tokens(batch)
        logits, aux = apply_fn(params, batch)
        logits = logits[:, -tokens.shape[1]:]
        loss = softmax_xent(logits[:, :-1], tokens[:, 1:])
        return loss + aux, {"accuracy": accuracy(logits[:, :-1], tokens[:, 1:]),
                            "nll": loss}

    return loss_fn, eval_fn


def lm_pair_loss(apply_fn):
    """`lm_loss` behind the federated (x, y) batch convention.

    The experiment plane's task pipeline (`data/federated.py`) hands every
    loss a ``(x, y)`` pair; for LM personalization tasks x IS the (B, L)
    token batch and the target is the shifted sequence itself, so y is
    ignored. This is the adapter that lets per-client dialect corpora
    (`data/lm_tasks.make_lm_clients`) run through `run_comparison`
    unchanged — FedMeta adapts on support sequences, scores next-token
    accuracy on query sequences.
    """
    base_loss, base_eval = lm_loss(apply_fn)

    def loss_fn(params, batch):
        x, _ = batch
        return base_loss(params, x)

    def eval_fn(params, batch):
        x, _ = batch
        return base_eval(params, x)

    return loss_fn, eval_fn
