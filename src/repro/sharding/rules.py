"""Sharding rules: parameter-path -> PartitionSpec.

Strategy (baseline, see EXPERIMENTS.md §Perf for the optimized variants):
  - 2-D parameter sharding: the "contract" dim of every large matmul is
    FSDP-sharded over DATA (and, in multi-pod meshes, jointly over
    POD+DATA), the "parallel" dim (heads / d_ff / vocab / latents) is
    tensor-sharded over MODEL. XLA GSPMD inserts the per-layer
    all-gathers (FSDP) and the attention/MLP all-reduces (TP).
  - stacked-layer params (leading scan dim) and stacked-expert params
    (leading E dim) get the same rule right-aligned to their trailing
    dims; leading dims are unsharded (TP-MoE baseline).
  - small params (norms, biases <~ d_model, scalars) are replicated.

Rules are right-aligned: a rule (a, b) applied to a rank-4 leaf yields
(None, None, a, b).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"


def fsdp_axes(mesh) -> tuple:
    """FSDP shards over data (and pod when present)."""
    if POD_AXIS in mesh.axis_names:
        return (POD_AXIS, DATA_AXIS)
    return (DATA_AXIS,)


def batch_axes(mesh) -> tuple:
    return fsdp_axes(mesh)


# rule tables: leaf name -> right-aligned axis tuple
# "F" placeholder = FSDP axes, "M" = model axis, None = replicated dim
_COL_PARALLEL = {  # (d_in [F], d_out [M])
    "wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w_gate", "w_up", "w_in",
    "lm_head", "mod_proj", "w_dq",
}
_ROW_PARALLEL = {  # (d_in [M], d_out [F])
    "wo", "w_down", "w_out",
}
_VOCAB_MAJOR = {"embed"}          # (vocab [M], d [F])
_REPLICATED_2D = {"w_router", "w_dkv", "w_kpe", "conv_w",
                  "w", "w1", "w2"}  # small / paper models
_MODEL_VEC = {"bq", "bk", "bv", "conv_b"}  # 1-d aligned with a M-sharded dim
_HEAD_VEC = {"A_log", "D", "dt_bias"}      # per-ssm-head vectors


def _rule_for(name: str, shape) -> tuple:
    if name in _COL_PARALLEL:
        return ("F", "M")
    if name in _ROW_PARALLEL:
        return ("M", "F")
    if name in _VOCAB_MAJOR:
        return ("M", "F")
    if name in _MODEL_VEC:
        return ("M",)
    if name in _HEAD_VEC:
        return ("M",)
    return ()


def _materialize(rule: tuple, rank: int, mesh, shape) -> P:
    F = fsdp_axes(mesh)
    axes: list = [None] * rank
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for i, a in enumerate(rule):
        dim = rank - len(rule) + i
        if dim < 0:
            continue
        if a == "F":
            size = int(np.prod([mesh_sizes[x] for x in F]))
            if shape[dim] % size == 0:
                axes[dim] = F if len(F) > 1 else F[0]
        elif a == "M":
            if shape[dim] % mesh_sizes[MODEL_AXIS] == 0:
                axes[dim] = MODEL_AXIS
    return P(*axes)


def param_pspecs(params, mesh, *, mode: str = "train"):
    """PartitionSpec pytree matching `params` (path-name based rules).

    mode="train": 2-D FSDP("data")+TP("model") sharding (default).
    mode="serve_tp": TP only — weights replicated over the data axis so
    decode steps never all-gather weights (perf lever for small/medium
    archs whose weights fit at 1/16 per chip; EXPERIMENTS.md §Perf H1).

    Divisibility guard: a dim that does not divide by its target axis size
    stays replicated (e.g. 15-head smollm attention on a 16-way model
    axis, odd vocab sizes)."""

    def leaf_spec(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        rule = _rule_for(name, leaf.shape)
        if mode == "serve_tp":
            rule = tuple(None if a == "F" else a for a in rule)
        return _materialize(rule, leaf.ndim, mesh, leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def state_pspecs(state, params_spec, mesh):
    """Specs for the train state {phi: {theta[, alpha]}, opt: {...}}.

    φ's leaves (theta, and alpha for Meta-SGD) mirror the parameter specs;
    optimizer moments (m, v, mu) mirror φ; scalar counters replicate."""
    phi_spec = {k: params_spec for k in state["phi"]}
    opt_spec = {}
    for k in state["opt"]:
        opt_spec[k] = P() if k == "step" else phi_spec
    return {"phi": phi_spec, "opt": opt_spec}


def batch_pspec(mesh, ndim: int, *, batch_dim: int = 0) -> P:
    """Shard the batch dim over pod+data; everything else replicated."""
    axes: list = [None] * ndim
    B = batch_axes(mesh)
    axes[batch_dim] = B if len(B) > 1 else B[0]
    return P(*axes)


def cache_pspecs(cache, mesh, *, batch_sharded: bool = True,
                 seq_shard: bool = False):
    """Decode-cache specs: batch dim over pod+data (when divisible),
    head/width dims over model. Cache layouts (see models/attention.py):
      k/v:   (B, C, Kv, hd)   -> (B_ax, None, model, None)
      c:     (B, C, R)        -> (B_ax, None, model)
      kpe:   (B, C, rope)     -> (B_ax, None, None)
      conv:  (B, W-1, conv_d) -> (B_ax, None, model)
      state: (B, nh, hp, N)   -> (B_ax, model, None, None)
      enc_out: (B, T, d)      -> (B_ax, None, None)

    seq_shard=True (perf lever, EXPERIMENTS.md §Perf H1 iter 2): shard the
    cache *length* dim over the model axis instead of kv heads — when
    kv_heads < model-axis size the head sharding is impossible and the
    cache otherwise replicates 16x; length sharding turns decode attention
    into a flash-decode-style partial softmax that XLA completes with
    small stat collectives.
    """
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    B_ax = batch_axes(mesh)
    bsz_div = int(np.prod([mesh_sizes[a] for a in B_ax]))
    m_div = mesh_sizes[MODEL_AXIS]

    def spec(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        rank = leaf.ndim
        # leading (n_reps,) stacking inside "stack" adds one dim
        stacked = rank > {"k": 4, "v": 4, "c": 3, "kpe": 3, "conv": 3,
                          "state": 4, "enc_out": 3}.get(name, rank)
        axes: list = [None] * rank
        bdim = 1 if stacked else 0
        if name == "length":
            return P()
        if batch_sharded and leaf.shape[bdim] % bsz_div == 0:
            axes[bdim] = B_ax if len(B_ax) > 1 else B_ax[0]
        if seq_shard and name in ("k", "v", "c", "kpe", "enc_out"):
            ldim = bdim + 1               # cache length dim
            if leaf.shape[ldim] % m_div == 0:
                axes[ldim] = MODEL_AXIS
            return P(*axes)
        mdim = {"k": 2, "v": 2, "c": 2, "conv": 2, "state": 1}.get(name)
        if mdim is not None:
            mdim = mdim + (1 if stacked else 0)
            if name in ("c",):            # (B, C, R): R over model
                pass                       # R stays unsharded in baseline
            elif leaf.shape[mdim] % m_div == 0:
                axes[mdim] = MODEL_AXIS
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache)
