from repro.sharding.rules import (param_pspecs, batch_pspec, cache_pspecs,
                                  state_pspecs, POD_AXIS, DATA_AXIS, MODEL_AXIS)
