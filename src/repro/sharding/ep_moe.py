"""Expert-parallel MoE with explicit all-to-all (beyond-paper §Perf
optimization).

Baseline (models/moe.py) is tensor-parallel: every device holds a slice
of EVERY expert's weights, tokens stay data-local, and each expert matmul
all-reduces over the model axis. For fine-grained-expert models
(DeepSeek-V2: 160 experts of d_ff=1536) the TP slice per device is
1536/16 = 96 columns — far below MXU efficiency — and router dispatch
is replicated work.

This variant shards EXPERTS over the model axis (E_local = E / 16 per
device) inside a shard_map:
  1. local top-k routing,
  2. capacity-bucketed dispatch tensors (tokens_local, E, C_local),
  3. all_to_all over the model axis moves token buckets to expert owners,
  4. dense local expert FFN at full d_ff width (MXU-aligned),
  5. reverse all_to_all + weighted combine.

Collective cost: 2 x all_to_all of (tokens * k * d) bytes over the model
axis, replacing per-layer all-reduces of the full activation. See
EXPERIMENTS.md §Perf hillclimb #2 for the measured delta.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import mlp_apply


def _local_dispatch(xt, logits, E, K, capacity):
    """Token->expert dispatch on one shard. xt: (T, d)."""
    T, d = xt.shape
    gate_vals, expert_ids = jax.lax.top_k(logits, K)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    flat_expert = expert_ids.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    counts = jnp.bincount(sorted_expert, length=E)
    start = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - start[sorted_expert]
    keep = rank < capacity
    slot = sorted_expert * capacity + jnp.where(keep, rank, 0)
    buf_tok = jnp.zeros((E * capacity,), jnp.int32).at[slot].set(
        jnp.where(keep, sorted_token, 0).astype(jnp.int32))
    buf_mask = jnp.zeros((E * capacity,), jnp.float32).at[slot].set(
        keep.astype(jnp.float32))
    x_e = (xt[buf_tok] * buf_mask[:, None]).reshape(E, capacity, d)
    return x_e, (sorted_token, sorted_gate, keep, slot)


def ep_moe_apply(params, cfg, x, mesh, *, capacity_factor=None):
    """Expert-parallel MoE layer. x: (B, L, d) sharded (data, None, None).

    Expert weights must be sharded P("model", None, None) — E over the
    model axis. Requires E % model_axis == 0.
    """
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    m_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    assert E % m_size == 0, (E, m_size)
    cf = capacity_factor or cfg.capacity_factor
    B, L, d = x.shape

    def local_fn(x_local, w_router, w_gate, w_up, w_down):
        # x_local: (B/dp, L, d); expert weights: (E/mp, d, ff)
        Bl = x_local.shape[0]
        T = Bl * L
        xt = x_local.reshape(T, d)
        logits = (xt @ w_router).astype(jnp.float32)
        capacity = int(np.ceil(T * K / E * cf))
        x_e, (sorted_token, sorted_gate, keep, slot) = _local_dispatch(
            xt, logits, E, K, capacity)
        # all_to_all (tiled): (E, C, d) -> (E/mp, C*mp, d): expert axis
        # split across the model axis, token buckets concatenated at the
        # expert owner
        x_recv = jax.lax.all_to_all(x_e, "model", split_axis=0,
                                    concat_axis=1, tiled=True)
        # local experts at FULL width
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_recv, w_gate))
             * jnp.einsum("ecd,edf->ecf", x_recv, w_up))
        y_e = jnp.einsum("ecf,efd->ecd", h, w_down)
        # reverse all_to_all: (E/mp, C*mp, d) -> (E, C, d)
        y_back = jax.lax.all_to_all(y_e, "model", split_axis=1,
                                    concat_axis=0, tiled=True)
        y_flat = y_back.reshape(E * capacity, d)
        contrib = jnp.zeros((T, d), y_flat.dtype).at[
            jnp.where(keep, sorted_token, T)
        ].add(jnp.where(keep, sorted_gate, 0.0)[:, None].astype(y_flat.dtype)
              * y_flat[jnp.where(keep, slot, 0)], mode="drop")
        return contrib.reshape(Bl, L, d).astype(x_local.dtype)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P("data", None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P("data", None, None), check_rep=False)
    y = fn(x, params["w_router"], params["w_gate"], params["w_up"],
           params["w_down"])
    if cfg.num_shared_experts > 0:
        y = y + mlp_apply(params["shared"], x, cfg.mlp_act)
    return y
