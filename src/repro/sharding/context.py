"""Ambient mesh context for model-internal sharding decisions.

Model code (shard_map EP-MoE, activation sharding constraints) needs the
mesh at trace time, but model functions are pure and config-driven. The
launcher / dry-run sets the ambient mesh here before tracing; model code
reads it. `None` (default, e.g. in CPU smoke tests) disables all
mesh-dependent paths.
"""
from __future__ import annotations

import contextlib

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev
