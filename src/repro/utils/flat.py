"""The packed parameter plane: φ as one padded, lane-aligned flat buffer.

Every per-round server op (client-gradient aggregation, outer Adam, the
fused inner update) is pure memory traffic over the full parameter set.
Executing those ops per-leaf costs one XLA op pair per tensor and forces
re-flattening on every call; the plane instead computes the layout
*once* — treedef, per-leaf offsets, padded size — and keeps the whole
meta-step on a single ``(n_padded,)`` float32 buffer (see DESIGN.md §2
for the layout and dtype policy).

Alignment: ``n_padded`` is a multiple of ``ALIGN = 8 * 128`` elements so
any slice of the plane reshapes to whole (sublane, lane) = (8, 128) TPU
tiles, which is what the Pallas kernels in ``kernels/meta_update`` and
``optim/fused_adam`` require.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

ALIGN = 8 * 128          # one (sublane, lane) f32 tile


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside the plane."""
    offset: int
    size: int
    shape: tuple
    dtype: str


@dataclasses.dataclass(frozen=True)
class FlatPlane:
    """Cached flattening spec for one pytree structure.

    Hashable and shape-only, so it can be closed over by jitted
    functions without retriggering tracing; ``pack``/``unpack`` are the
    only data-touching methods.
    """
    treedef: Any
    slots: tuple          # tuple[LeafSlot, ...] in treedef leaf order
    n_real: int
    n_padded: int

    @classmethod
    def from_tree(cls, tree, align: int = ALIGN) -> "FlatPlane":
        leaves, treedef = jax.tree.flatten(tree)
        slots, off = [], 0
        for x in leaves:
            size = int(np.prod(x.shape)) if x.shape else 1
            slots.append(LeafSlot(off, size, tuple(x.shape),
                                  jnp.dtype(x.dtype).name))
            off += size
        n_padded = off + ((-off) % align)
        return cls(treedef, tuple(slots), off, max(n_padded, align))

    # ---- data movement --------------------------------------------------
    def pack(self, tree, dtype=jnp.float32):
        """tree -> (n_padded,) plane (zero pad tail).

        dtype defaults to the plane's float32 policy; a reduced-precision
        block (e.g. bfloat16 for the (m, N) client-gradient block) halves
        the aggregation traffic — the fused kernels still accumulate in
        f32 (DESIGN.md §2)."""
        leaves = jax.tree.leaves(tree)
        assert len(leaves) == len(self.slots), \
            f"tree has {len(leaves)} leaves, plane expects {len(self.slots)}"
        flat = jnp.concatenate(
            [x.reshape(-1).astype(dtype) for x in leaves])
        pad = self.n_padded - self.n_real
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat

    def unpack(self, flat):
        """(n_padded,) plane -> tree with original shapes/dtypes."""
        out = [flat[s.offset:s.offset + s.size].reshape(s.shape)
               .astype(s.dtype) for s in self.slots]
        return jax.tree.unflatten(self.treedef, out)

    def pack_batch(self, tree, dtype=jnp.float32):
        """tree with leading batch axis on every leaf -> (B, n_padded)."""
        return jax.vmap(lambda t: self.pack(t, dtype))(tree)

    def zeros(self):
        return jnp.zeros((self.n_padded,), jnp.float32)


# ---- spec cache ---------------------------------------------------------
_PLANE_CACHE: dict = {}


def plane_for(tree, align: int = ALIGN) -> FlatPlane:
    """FlatPlane for ``tree``'s structure, memoized by (treedef, shapes,
    dtypes) so hot paths never recompute offsets."""
    key = (jax.tree.structure(tree),
           tuple((tuple(x.shape), jnp.dtype(x.dtype).name)
                 for x in jax.tree.leaves(tree)), align)
    plane = _PLANE_CACHE.get(key)
    if plane is None:
        plane = _PLANE_CACHE[key] = FlatPlane.from_tree(tree, align)
    return plane
