"""The packed parameter plane: φ as one padded, lane-aligned flat buffer.

Every per-round server op (client-gradient aggregation, outer Adam, the
fused inner update) is pure memory traffic over the full parameter set.
Executing those ops per-leaf costs one XLA op pair per tensor and forces
re-flattening on every call; the plane instead computes the layout
*once* — treedef, per-leaf offsets, padded size — and keeps the whole
meta-step on a single ``(n_padded,)`` float32 buffer (see DESIGN.md §2
for the layout and dtype policy).

Alignment: ``n_padded`` is a multiple of ``ALIGN = 8 * 128`` elements so
any slice of the plane reshapes to whole (sublane, lane) = (8, 128) TPU
tiles, which is what the Pallas kernels in ``kernels/meta_update`` and
``optim/fused_adam`` require.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

ALIGN = 8 * 128          # one (sublane, lane) f32 tile


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside the plane."""
    offset: int
    size: int
    shape: tuple
    dtype: str


@dataclasses.dataclass(frozen=True)
class FlatPlane:
    """Cached flattening spec for one pytree structure.

    Hashable and shape-only, so it can be closed over by jitted
    functions without retriggering tracing; ``pack``/``unpack`` are the
    only data-touching methods.
    """
    treedef: Any
    slots: tuple          # tuple[LeafSlot, ...] in treedef leaf order
    n_real: int
    n_padded: int

    @classmethod
    def from_tree(cls, tree, align: int = ALIGN) -> "FlatPlane":
        leaves, treedef = jax.tree.flatten(tree)
        slots, off = [], 0
        for x in leaves:
            size = int(np.prod(x.shape)) if x.shape else 1
            slots.append(LeafSlot(off, size, tuple(x.shape),
                                  jnp.dtype(x.dtype).name))
            off += size
        n_padded = off + ((-off) % align)
        return cls(treedef, tuple(slots), off, max(n_padded, align))

    # ---- data movement --------------------------------------------------
    def pack(self, tree, dtype=jnp.float32):
        """tree -> (n_padded,) plane (zero pad tail).

        dtype defaults to the plane's float32 policy; a reduced-precision
        block (e.g. bfloat16 for the (m, N) client-gradient block) halves
        the aggregation traffic — the fused kernels still accumulate in
        f32 (DESIGN.md §2).

        f32 planes pack via a dynamic-update-slice chain into a zeroed
        plane rather than an L-way concatenate: XLA:CPU executes the DUS
        chain in place (~6x faster than its many-operand concat,
        measured in BENCH_round), the zero tail comes for free, and the
        transpose of a DUS is a slice, which keeps ``pack`` cheap under
        autodiff. Reduced-precision packs keep the concat — XLA:CPU's
        bf16 DUS is scalar-emulated (~20x slower than concat)."""
        leaves = jax.tree.leaves(tree)
        assert len(leaves) == len(self.slots), \
            f"tree has {len(leaves)} leaves, plane expects {len(self.slots)}"
        if jnp.dtype(dtype) == jnp.float32:
            flat = jnp.zeros((self.n_padded,), dtype)
            for s, x in zip(self.slots, leaves):
                # a short leaf would silently leave stale zeros in the
                # slot (DUS, unlike concat, cannot fail on total length)
                assert x.size == s.size, (x.shape, s)
                flat = jax.lax.dynamic_update_slice(
                    flat, x.reshape(-1).astype(dtype), (s.offset,))
            return flat
        flat = jnp.concatenate(
            [x.reshape(-1).astype(dtype) for x in leaves])
        pad = self.n_padded - self.n_real
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat

    def unpack(self, flat):
        """(n_padded,) plane -> tree with original shapes/dtypes."""
        out = [flat[s.offset:s.offset + s.size].reshape(s.shape)
               .astype(s.dtype) for s in self.slots]
        return jax.tree.unflatten(self.treedef, out)

    def unpack_ad(self, flat):
        """``unpack`` with an efficient reverse-mode rule.

        The built-in transpose of an unpack turns every leaf slice into
        a zero-padded full-plane buffer and sums all of them — L live
        (N,)-sized intermediates per backward pass, which is what makes
        naive grad-through-unpack explode inside the client inner loop.
        The slices are disjoint and cover the real region, so the true
        cotangent is just the concatenation of the leaf cotangents plus
        the zero alignment tail: one pass, no per-leaf planes. Use this
        form wherever the unpack sits under autodiff (the flat client
        loss); plain ``unpack`` is fine outside differentiation.
        Second-order (reverse-over-reverse) composes, because the first
        vjp resolves the custom rule into plain concat/slice ops."""
        return _unpack_ad(self, flat)

    def pack_batch(self, tree, dtype=jnp.float32):
        """tree with leading batch axis on every leaf -> (B, n_padded)."""
        return jax.vmap(lambda t: self.pack(t, dtype))(tree)

    def zeros(self):
        return jnp.zeros((self.n_padded,), jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _unpack_ad(plane, flat):
    return plane.unpack(flat)


def _unpack_ad_fwd(plane, flat):
    return plane.unpack(flat), None


def _unpack_ad_bwd(plane, _res, ct):
    # DUS chain for the same reason as pack: in-place on CPU, and its
    # own transpose (slice) stays cheap under second-order autodiff
    leaves = jax.tree.leaves(ct)
    flat_ct = jnp.zeros((plane.n_padded,), jnp.float32)
    for s, x in zip(plane.slots, leaves):
        flat_ct = jax.lax.dynamic_update_slice(
            flat_ct, x.reshape(-1).astype(jnp.float32), (s.offset,))
    return (flat_ct,)


_unpack_ad.defvjp(_unpack_ad_fwd, _unpack_ad_bwd)


# ---- spec cache ---------------------------------------------------------
_PLANE_CACHE: dict = {}


def plane_for(tree, align: int = ALIGN) -> FlatPlane:
    """FlatPlane for ``tree``'s structure, memoized by (treedef, shapes,
    dtypes) so hot paths never recompute offsets."""
    key = (jax.tree.structure(tree),
           tuple((tuple(x.shape), jnp.dtype(x.dtype).name)
                 for x in jax.tree.leaves(tree)), align)
    plane = _PLANE_CACHE.get(key)
    if plane is None:
        plane = _PLANE_CACHE[key] = FlatPlane.from_tree(tree, align)
    return plane
