"""Pytree utilities used across the framework (pure JAX, no deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_size(a) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    """Total bytes across all leaves (honours per-leaf dtype)."""
    total = 0
    for x in jax.tree.leaves(a):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_norm(a):
    """Global L2 norm of a pytree."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(a)]
    return jnp.sqrt(sum(leaves))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_any_nan(a):
    flags = [jnp.any(~jnp.isfinite(x)) for x in jax.tree.leaves(a)
             if jnp.issubdtype(x.dtype, jnp.floating)]
    if not flags:
        return jnp.asarray(False)
    return jnp.any(jnp.stack(flags))


def tree_axpy(alpha, x, y):
    """y + alpha * x, leafwise."""
    return jax.tree.map(lambda xi, yi: yi + alpha * xi, x, y)
