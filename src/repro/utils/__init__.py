from repro.utils.pytree import (
    tree_add,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    tree_size,
    tree_bytes,
    tree_norm,
    tree_cast,
    tree_any_nan,
)
from repro.utils.flat import ALIGN, FlatPlane, plane_for
