"""Checkpointing: pytrees -> .npz payload + msgpack manifest.

Design: flatten the pytree with '/'-joined key paths; tensors go into a
single compressed .npz; structure + dtypes + scalar metadata go into a
msgpack manifest so restore round-trips exactly (including empty dicts and
python scalars). Works for params, optimizer states, and server states.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{type(tree).__name__}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _set_path(root, path_parts, value):
    cur = root
    for i, part in enumerate(path_parts[:-1]):
        if part not in cur:
            cur[part] = {}
        cur = cur[part]
    cur[path_parts[-1]] = value


_LIST_RE = re.compile(r"^__(list|tuple)(\d+)$")


def _rebuild_sequences(node):
    """Convert {'__list0': .., '__list1': ..} dicts back into lists/tuples."""
    if not isinstance(node, dict):
        return node
    keys = list(node.keys())
    if keys and all(_LIST_RE.match(k) for k in keys):
        matches = [_LIST_RE.match(k) for k in keys]
        kind = matches[0].group(1)
        items = sorted(((int(m.group(2)), node[k]) for k, m in zip(keys, matches)))
        seq = [_rebuild_sequences(v) for _, v in items]
        return tuple(seq) if kind == "tuple" else seq
    return {k: _rebuild_sequences(v) for k, v in node.items()}


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"keys": [], "scalars": {}}
    for k, v in flat.items():
        if isinstance(v, (jnp.ndarray, np.ndarray)):
            arrays[k] = np.asarray(v)
            manifest["keys"].append(k)
        else:
            manifest["scalars"][k] = v
    np.savez_compressed(path + ".npz", **arrays)
    with open(path + ".manifest", "wb") as f:
        f.write(msgpack.packb(manifest))


def load_pytree(path: str) -> Any:
    with open(path + ".manifest", "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(path + ".npz")
    root: dict = {}
    for k in manifest["keys"]:
        _set_path(root, k.split("/"), jnp.asarray(data[k]))
    for k, v in manifest["scalars"].items():
        _set_path(root, k.split("/"), v)
    return _rebuild_sequences(root)


def save_server_state(ckpt_dir: str, step: int, state: Any) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    save_pytree(path, state)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)\.manifest$", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_server_state(ckpt_dir: str, step: int | None = None) -> Any:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    return load_pytree(os.path.join(ckpt_dir, f"step_{step:08d}"))
