"""Checkpointing: pytrees -> .npz payload + msgpack manifest.

Design: flatten the pytree with '/'-joined key paths; tensors go into a
single compressed .npz; structure + dtypes + scalar metadata go into a
msgpack manifest so restore round-trips exactly (including empty dicts and
python scalars). Works for params, optimizer states, and server states.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{type(tree).__name__}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _set_path(root, path_parts, value):
    cur = root
    for i, part in enumerate(path_parts[:-1]):
        if part not in cur:
            cur[part] = {}
        cur = cur[part]
    cur[path_parts[-1]] = value


_LIST_RE = re.compile(r"^__(list|tuple)(\d+)$")


def _rebuild_sequences(node):
    """Convert {'__list0': .., '__list1': ..} dicts back into lists/tuples."""
    if not isinstance(node, dict):
        return node
    keys = list(node.keys())
    if keys and all(_LIST_RE.match(k) for k in keys):
        matches = [_LIST_RE.match(k) for k in keys]
        kind = matches[0].group(1)
        items = sorted(((int(m.group(2)), node[k]) for k, m in zip(keys, matches)))
        seq = [_rebuild_sequences(v) for _, v in items]
        return tuple(seq) if kind == "tuple" else seq
    return {k: _rebuild_sequences(v) for k, v in node.items()}


def _to_packable(v):
    """msgpack can't pack numpy scalar types (np.int64 step counters,
    np.float32 metrics); unwrap them to native python scalars. Exact:
    .item() preserves the value, and load-side jnp users re-cast."""
    if isinstance(v, np.generic):
        return v.item()
    return v


def save_pytree(path: str, tree: Any) -> None:
    """Atomic: both files are written to temp names and ``os.replace``d
    into place, payload first, manifest last — ``latest_step`` keys on
    manifests, so a crash mid-save leaves either nothing visible or a
    complete checkpoint (at worst an orphaned ``.npz``), never a
    manifest pointing at a torn payload."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"keys": [], "scalars": {}, "bf16": []}
    for k, v in flat.items():
        if isinstance(v, (jnp.ndarray, np.ndarray)):
            a = np.asarray(v)
            if a.dtype == jnp.bfloat16:
                # numpy's npz format can't serialize ml_dtypes; f32 is
                # a superset of bf16 so the round-trip stays exact
                a = a.astype(np.float32)
                manifest["bf16"].append(k)
            arrays[k] = a
            manifest["keys"].append(k)
        else:
            manifest["scalars"][k] = _to_packable(v)
    # np.savez appends ".npz" unless the name already ends with it, so
    # the temp name must keep the suffix for os.replace to find it
    tmp_npz = path + ".tmp.npz"
    np.savez_compressed(tmp_npz, **arrays)
    os.replace(tmp_npz, path + ".npz")
    tmp_man = path + ".tmp.manifest"
    with open(tmp_man, "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp_man, path + ".manifest")


def load_pytree(path: str) -> Any:
    with open(path + ".manifest", "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(path + ".npz")
    bf16 = set(manifest.get("bf16", ()))
    root: dict = {}
    for k in manifest["keys"]:
        a = jnp.asarray(data[k])
        if k in bf16:
            a = a.astype(jnp.bfloat16)
        _set_path(root, k.split("/"), a)
    for k, v in manifest["scalars"].items():
        _set_path(root, k.split("/"), v)
    return _rebuild_sequences(root)


def save_server_state(ckpt_dir: str, step: int, state: Any,
                      keep_last: int | None = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    save_pytree(path, state)
    if keep_last:
        steps = sorted(s for s in _all_steps(ckpt_dir) if s != step)
        for old in steps[:max(0, len(steps) - (keep_last - 1))]:
            for suffix in (".manifest", ".npz"):
                try:  # retention is best-effort; a vanished file is fine
                    os.remove(os.path.join(
                        ckpt_dir, f"step_{old:08d}{suffix}"))
                except OSError:
                    pass
    return path


def _all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        # temp names ("step_X.tmp.manifest") deliberately don't match:
        # a crashed half-write is invisible to discovery
        m = re.match(r"step_(\d+)\.manifest$", name)
        if m:
            steps.append(int(m.group(1)))
    return steps


def latest_step(ckpt_dir: str) -> int | None:
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def load_server_state(ckpt_dir: str, step: int | None = None) -> Any:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    return load_pytree(os.path.join(ckpt_dir, f"step_{step:08d}"))
