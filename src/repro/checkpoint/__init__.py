from repro.checkpoint.io import save_pytree, load_pytree, latest_step, save_server_state, load_server_state
