"""Pallas contract rules (``pallas-*``).

Every Pallas kernel in this repo ships with a pure-jnp reference
oracle, and every ``pl.pallas_call`` site encodes layout contracts the
runtime only checks partially (wrong index-map arity fails at trace
time on some paths, silently indexes garbage on others; an aliased
operand read after the call observes donated/overwritten memory under
jit). The rules make those contracts static:

  pallas-grid-mismatch  every ``pl.BlockSpec`` index map at a
                        ``pallas_call`` site must accept exactly the
                        grid's rank (index maps may carry extra
                        defaulted params — the closure-capture idiom
                        ``lambda b, h, i, j, G=G: ...``), and a literal
                        block shape must be the same rank as a literal
                        index-map return tuple. Specs or grids that
                        resolve outside the function are skipped, not
                        guessed.
  pallas-alias-reuse    ``input_output_aliases`` donates the aliased
                        operand's buffer to the output; any read of
                        that operand *after* the call observes
                        overwritten memory under jit. Flags aliased
                        operands whose base name is read in any later
                        statement of the enclosing function.
  pallas-missing-ref    every ``src/repro/kernels/<pkg>/`` package must
                        ship ``ref.py`` (the oracle) and an ``ops.py``
                        dispatcher that imports it — kernel↔ref parity
                        is only testable when the oracle is registered
                        in the dispatch (DESIGN.md §5).
"""
from __future__ import annotations

import ast
import os
from typing import Optional

from repro.analysis.core import (ModuleInfo, Violation, attr_chain,
                                 base_name, enclosing_function,
                                 containing_stmt, project_rule, rule)


def _kw(call: ast.Call, name: str):
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _local_assigns(fn) -> dict:
    """name -> value expr for simple single-target assignments in the
    function body (one level — enough for the `spec = pl.BlockSpec(...)`
    / `grid = (B, nh, nc)` idiom)."""
    if fn is None:
        return {}
    out = {}
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name):
            out[stmt.targets[0].id] = stmt.value
    return out


def _resolve(node, env: dict, depth: int = 3):
    while isinstance(node, ast.Name) and depth > 0:
        if node.id not in env:
            return None
        node = env[node.id]
        depth -= 1
    return node


def _grid_rank(call: ast.Call, env: dict) -> Optional[int]:
    grid = _resolve(_kw(call, "grid"), env)
    if grid is None:
        return None
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts)
    if isinstance(grid, ast.Constant) and isinstance(grid.value, int):
        return 1
    return None


def _iter_specs(call: ast.Call, env: dict):
    for kw_name in ("in_specs", "out_specs"):
        val = _resolve(_kw(call, kw_name), env)
        if val is None:
            continue
        elems = val.elts if isinstance(val, (ast.Tuple, ast.List)) \
            else [val]
        for e in elems:
            e = _resolve(e, env)
            if isinstance(e, ast.Call) and \
                    (attr_chain(e.func) or "").endswith("BlockSpec"):
                yield kw_name, e


def _pallas_calls(module: ModuleInfo):
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call) and \
                (attr_chain(node.func) or "").endswith("pallas_call"):
            yield node


@rule("pallas-grid-mismatch",
      "BlockSpec index map inconsistent with the call's grid")
def check_grid(module: ModuleInfo):
    out = []
    for call in _pallas_calls(module):
        fn = enclosing_function(module, call)
        env = _local_assigns(fn)
        rank = _grid_rank(call, env)
        for kw_name, spec in _iter_specs(call, env):
            shape = spec.args[0] if spec.args else None
            imap = (spec.args[1] if len(spec.args) > 1
                    else _kw(spec, "index_map"))
            if not isinstance(imap, ast.Lambda):
                continue
            required = len(imap.args.args) - len(imap.args.defaults)
            total = len(imap.args.args)
            if rank is not None and not (required <= rank <= total):
                out.append(Violation(
                    "pallas-grid-mismatch", module.relpath, spec.lineno,
                    spec.col_offset + 1,
                    f"{kw_name} index map takes {required} required "
                    f"arg(s) but the grid has rank {rank} — index maps "
                    f"receive exactly one index per grid axis"))
            if isinstance(shape, (ast.Tuple, ast.List)) and \
                    isinstance(imap.body, (ast.Tuple, ast.List)) and \
                    len(shape.elts) != len(imap.body.elts):
                out.append(Violation(
                    "pallas-grid-mismatch", module.relpath, spec.lineno,
                    spec.col_offset + 1,
                    f"{kw_name} block shape has rank "
                    f"{len(shape.elts)} but its index map returns "
                    f"{len(imap.body.elts)} indices — block index and "
                    f"block shape must agree per dimension"))
    return out


@rule("pallas-alias-reuse",
      "aliased pallas_call operand read after the call (donated buffer)")
def check_alias_reuse(module: ModuleInfo):
    out = []
    for call in _pallas_calls(module):
        aliases = _kw(call, "input_output_aliases")
        if not isinstance(aliases, ast.Dict):
            continue
        parents = module.parents()
        outer = parents.get(call)
        if not (isinstance(outer, ast.Call) and outer.func is call):
            continue            # pallas_call(...) not immediately applied
        fn = enclosing_function(module, call)
        if fn is None:
            continue
        idx = containing_stmt(fn, outer)
        if idx is None:
            continue
        aliased_idx = [k.value for k in aliases.keys
                       if isinstance(k, ast.Constant)
                       and isinstance(k.value, int)]
        for i in aliased_idx:
            if i >= len(outer.args):
                continue
            name = base_name(outer.args[i])
            if name in (None, "self"):
                continue
            for later in fn.body[idx + 1:]:
                reads = [n for n in ast.walk(later)
                         if isinstance(n, ast.Name) and n.id == name
                         and isinstance(n.ctx, ast.Load)]
                if reads:
                    out.append(Violation(
                        "pallas-alias-reuse", module.relpath,
                        reads[0].lineno, reads[0].col_offset + 1,
                        f"operand {i} (`{name}`) of this pallas_call "
                        f"is input_output-aliased (its buffer is "
                        f"donated) but `{name}` is read after the "
                        f"call — under jit that read observes "
                        f"overwritten memory"))
                    break
    return out


@project_rule("pallas-missing-ref",
              "kernels/<pkg>/ without a ref.py oracle wired into ops.py")
def check_missing_ref(modules):
    out = []
    pkgs = {}
    for m in modules:
        rel = m.relpath.replace(os.sep, "/")
        marker = "repro/kernels/"
        if marker not in rel:
            continue
        tail = rel.split(marker, 1)[1]
        if "/" not in tail:
            continue                     # kernels/__init__.py itself
        pkg, fname = tail.split("/", 1)
        pkgs.setdefault(pkg, {})[fname] = m
    for pkg, files in sorted(pkgs.items()):
        init = files.get("__init__.py")
        anchor = init or next(iter(files.values()))
        if "ref.py" not in files:
            out.append(Violation(
                "pallas-missing-ref", anchor.relpath, 1, 1,
                f"kernels package `{pkg}` has no ref.py — every kernel "
                f"family ships a pure-jnp oracle for parity tests"))
        if "ops.py" not in files:
            out.append(Violation(
                "pallas-missing-ref", anchor.relpath, 1, 1,
                f"kernels package `{pkg}` has no ops.py dispatcher"))
            continue
        ops = files["ops.py"]
        imports_ref = False
        for node in ast.walk(ops.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.endswith(".ref") or node.level and mod == "ref":
                    imports_ref = True
                if any(a.name == "ref" for a in node.names):
                    imports_ref = True
            elif isinstance(node, ast.Import):
                if any(a.name.endswith(".ref") for a in node.names):
                    imports_ref = True
        if "ref.py" in files and not imports_ref:
            out.append(Violation(
                "pallas-missing-ref", ops.relpath, 1, 1,
                f"kernels package `{pkg}`'s ops.py never imports its "
                f"ref module — the oracle must be registered in the "
                f"dispatch, not just sit next to it"))
    return out
