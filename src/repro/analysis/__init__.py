"""Invariant plane: repo-specific static analysis + runtime sanitizers.

The repo's load-bearing guarantees — bit-identical replay across
sync/prefetch/fused-K/resume (DESIGN.md §14–15), seeded-only
randomness, lock-guarded shared state in `WorkerPool` / `ClientRegistry`,
and Pallas kernel↔ref parity — are enforced here as machine-checked
rules rather than tribal knowledge (DESIGN.md §16):

  * `repro.analysis.lint` — the AST lint pass
    (``python -m repro.analysis.lint --strict``) with four rule
    families: RNG discipline (``rng-*``), determinism (``det-*``),
    thread safety (``thread-*``) and Pallas contracts (``pallas-*``).
  * `repro.analysis.sanitizers` — the opt-in runtime half: a
    lock-assert proxy that records unguarded cross-thread access to
    shared state, and a tracer-leak guard for the experiment plane.
"""
from repro.analysis.core import (LintReport, Violation, lint_paths,
                                 lint_source)

__all__ = ["LintReport", "Violation", "lint_paths", "lint_source"]
