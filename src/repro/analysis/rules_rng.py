"""RNG discipline rules (``rng-*``).

The paper's headline numbers (2.82–4.33× less communication than
FedAvg) are only meaningful when every method sees *identical sampling
streams* — the experiment plane's whole design (DESIGN.md §8). One
bare ``np.random.*`` call, one unseeded ``RandomState`` or one
time-derived seed anywhere in a data/round path silently detaches a
run from its stream. Policy: seeded streams (``RandomState(seed)``,
``default_rng(seed)``) or ``SeedSequence`` entropy only.

  rng-bare       module-level numpy RNG calls (``np.random.<draw>``) —
                 global-state draws, unseedable per stream
  rng-stdlib     any ``import random`` — the stdlib global RNG has no
                 place in this repo
  rng-unseeded   ``RandomState()`` / ``default_rng()`` with no
                 arguments — seeded from the OS, never reproducible
  rng-time-seed  a seed derived from wall-clock (``time.time``,
                 ``datetime.now``, ``os.urandom``, ``uuid4``)
"""
from __future__ import annotations

import ast

from repro.analysis.core import (ModuleInfo, Violation, attr_chain,
                                 numpy_aliases, rule)

#: numpy.random attributes that are stream constructors / entropy
#: plumbing — everything else on the module is a global-state draw.
ALLOWED_RANDOM_ATTRS = frozenset({
    "RandomState", "Generator", "default_rng", "BitGenerator",
    "MT19937", "SeedSequence", "Philox", "PCG64", "PCG64DXSM", "SFC64",
})

_RNG_CONSTRUCTORS = frozenset({"RandomState", "default_rng", "PRNGKey",
                               "SeedSequence", "Generator", "MT19937"})
_TIME_SOURCES = frozenset({"time.time", "time.time_ns", "datetime.now",
                           "datetime.datetime.now", "datetime.utcnow",
                           "datetime.datetime.utcnow", "os.urandom",
                           "uuid.uuid4", "uuid.uuid1"})


def _is_np_random(chain: str, aliases: dict) -> bool:
    if chain is None:
        return False
    head, _, _ = chain.rpartition(".")
    return (head in {f"{m}.random" for m in aliases["module"]}
            or head in aliases["random"])


@rule("rng-bare",
      "module-level numpy RNG draw (unseedable global state)")
def check_bare_numpy(module: ModuleInfo):
    aliases = numpy_aliases(module.tree)
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Attribute):
            continue
        chain = attr_chain(node)
        if not _is_np_random(chain, aliases):
            continue
        if node.attr in ALLOWED_RANDOM_ATTRS:
            continue
        out.append(Violation(
            "rng-bare", module.relpath, node.lineno, node.col_offset + 1,
            f"`{chain}` draws from numpy's global RNG — use a seeded "
            f"`RandomState`/`default_rng` stream instead"))
    return out


@rule("rng-stdlib", "stdlib `random` import (global, unseeded per stream)")
def check_stdlib_random(module: ModuleInfo):
    out = []
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            names = [node.module or ""]
        else:
            continue
        if "random" in names:
            out.append(Violation(
                "rng-stdlib", module.relpath, node.lineno,
                node.col_offset + 1,
                "stdlib `random` is banned — every stream in this repo "
                "is an explicitly seeded numpy/jax stream"))
    return out


@rule("rng-unseeded", "RandomState()/default_rng() with no seed")
def check_unseeded(module: ModuleInfo):
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call) or node.args or node.keywords:
            continue
        chain = attr_chain(node.func) or ""
        tail = chain.rpartition(".")[2]
        if tail in ("RandomState", "default_rng"):
            out.append(Violation(
                "rng-unseeded", module.relpath, node.lineno,
                node.col_offset + 1,
                f"`{chain}()` seeds from the OS — pass an explicit "
                f"seed (or a SeedSequence-derived bit generator)"))
    return out


def _time_calls(node):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = attr_chain(sub.func)
            if chain and (chain in _TIME_SOURCES or
                          chain.rpartition(".")[2] in ("urandom", "uuid4")):
                yield sub, chain


@rule("rng-time-seed", "seed derived from wall-clock / OS entropy")
def check_time_seed(module: ModuleInfo):
    out = []
    for node in ast.walk(module.tree):
        hits = []
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func) or ""
            is_ctor = chain.rpartition(".")[2] in _RNG_CONSTRUCTORS
            for arg in list(node.args) + [k.value for k in node.keywords]:
                for _, src in _time_calls(arg):
                    kw_seed = any(k.arg and "seed" in k.arg.lower()
                                  and any(_time_calls(k.value))
                                  for k in node.keywords)
                    if is_ctor or kw_seed:
                        hits.append(src)
        elif isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
            if any("seed" in t.lower() for t in targets):
                hits.extend(src for _, src in _time_calls(node.value))
        for src in hits:
            out.append(Violation(
                "rng-time-seed", module.relpath, node.lineno,
                node.col_offset + 1,
                f"seed derived from `{src}` — wall-clock/OS entropy "
                f"seeds are unreproducible; thread an explicit seed"))
    return out
