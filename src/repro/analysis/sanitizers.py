"""Runtime sanitizers — the dynamic half of the invariant plane.

The lint rules prove lock discipline *lexically*; these sanitizers
prove it *at runtime*, catching what static analysis structurally
cannot (writes through helpers, monkeypatched methods, attribute
access from code the linter never saw):

  * :func:`guard_shared_state` — swaps an object's lock for a
    `SanitizedLock` (same blocking semantics, plus owner tracking) and
    its class for a recording subclass whose ``__setattr__`` logs every
    guarded-attribute write performed without holding the lock.
    :func:`cross_thread_violations` then returns the unguarded writes
    made off the owning thread — the data races. Overhead is one dict
    lookup per attribute write; strictly opt-in (tests, debug runs).
  * :func:`no_tracer_leaks` / :func:`assert_no_tracers` — the
    experiment plane's tracer-leak guard: history records and artifact
    payloads must hold host floats, never ``jax.core.Tracer``s (a
    tracer in a record means a jitted function leaked an abstract value
    out of its trace — it would poison every later ``float()`` and
    checkpoint). The context manager additionally turns on JAX's own
    leak checking around a block.

Opt-in wiring: ``REPRO_SANITIZE=1`` makes the experiment plane run the
tracer guard on every record it flushes (see
``federated/experiment.py``); the lock sanitizer is constructed
explicitly by tests/tools (see ``tests/test_sanitizers.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import traceback
from typing import List, Optional


def sanitizers_enabled() -> bool:
    """True when ``REPRO_SANITIZE=1`` — the opt-in env gate."""
    return os.environ.get("REPRO_SANITIZE", "") == "1"


class UnguardedAccessError(AssertionError):
    """Shared state was written without the class's lock held."""


class TracerLeakError(AssertionError):
    """A jax tracer escaped into host-side state."""


class SanitizedLock:
    """`threading.Lock` work-alike that records its owning thread.

    ``held_by_me()`` answers the question a plain Lock cannot:
    *does the current thread hold this lock* (``locked()`` only says
    somebody does). Context-manager and acquire/release compatible with
    the ``with self._lock:`` sites it replaces.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._owner: Optional[int] = None

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
        return got

    def release(self):
        self._owner = None
        self._lock.release()

    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


@dataclasses.dataclass(frozen=True)
class UnguardedWrite:
    attr: str
    thread_id: int
    thread_name: str
    owner_thread: int
    where: str            # "file:line in func" of the writing frame

    @property
    def cross_thread(self) -> bool:
        return self.thread_id != self.owner_thread


_RECORDS_ATTR = "__repro_sanitizer_records__"
_LOCK_ATTR = "__repro_sanitizer_lock_attr__"
_OWNER_ATTR = "__repro_sanitizer_owner__"


def guard_shared_state(obj, lock_attr: str = "_lock",
                       guarded=None):
    """Instrument ``obj`` so unguarded writes to shared state are
    recorded (not blocked — the sanitizer observes, the test asserts).

    The object's ``lock_attr`` is replaced with a `SanitizedLock` (it
    must not be held during the swap) and its class with a one-off
    recording subclass. ``guarded`` selects the attributes under
    contract; None means every underscore-prefixed attribute except the
    lock itself. Returns ``obj``. Example::

        reg = ClientRegistry(src, 10, cache_clients=8)
        guard_shared_state(reg)
        pool.map(range(64))                  # hammer it from K threads
        assert not cross_thread_violations(reg)
    """
    lock = getattr(obj, lock_attr, None)
    if lock is not None and getattr(lock, "locked", lambda: False)():
        raise RuntimeError("cannot instrument while the lock is held")
    records: List[UnguardedWrite] = []
    base = type(obj)
    guarded_set = None if guarded is None else frozenset(guarded)

    def _guarded(name: str) -> bool:
        if name.startswith("__repro_sanitizer"):
            return False
        if name == lock_attr:
            return False
        if guarded_set is not None:
            return name in guarded_set
        return name.startswith("_")

    class Guarded(base):
        def __setattr__(self, name, value):
            if _guarded(name):
                sl = self.__dict__.get(lock_attr)
                if isinstance(sl, SanitizedLock) and not sl.held_by_me():
                    frame = traceback.extract_stack(limit=3)[0]
                    records.append(UnguardedWrite(
                        attr=name,
                        thread_id=threading.get_ident(),
                        thread_name=threading.current_thread().name,
                        owner_thread=getattr(
                            self, _OWNER_ATTR, threading.get_ident()),
                        where=f"{frame.filename}:{frame.lineno} "
                              f"in {frame.name}"))
            object.__setattr__(self, name, value)

    Guarded.__name__ = f"Sanitized{base.__name__}"
    Guarded.__qualname__ = Guarded.__name__
    object.__setattr__(obj, _OWNER_ATTR, threading.get_ident())
    object.__setattr__(obj, _RECORDS_ATTR, records)
    object.__setattr__(obj, _LOCK_ATTR, lock_attr)
    object.__setattr__(obj, lock_attr, SanitizedLock())
    obj.__class__ = Guarded
    return obj


def unguarded_writes(obj) -> List[UnguardedWrite]:
    """Every recorded unguarded write (any thread)."""
    return list(getattr(obj, _RECORDS_ATTR, []))


def cross_thread_violations(obj) -> List[UnguardedWrite]:
    """Unguarded writes made off the owning thread — the races the
    thread-safety invariant (DESIGN.md §15/§16) forbids."""
    return [r for r in unguarded_writes(obj) if r.cross_thread]


def assert_guarded(obj, *, cross_thread_only: bool = True):
    """Raise `UnguardedAccessError` listing every recorded violation."""
    bad = (cross_thread_violations(obj) if cross_thread_only
           else unguarded_writes(obj))
    if bad:
        lines = [f"  {r.attr!r} by {r.thread_name} at {r.where}"
                 for r in bad[:20]]
        raise UnguardedAccessError(
            f"{len(bad)} unguarded shared-state write(s) on "
            f"{type(obj).__name__}:\n" + "\n".join(lines))


# ---- tracer-leak guard (experiment plane) -------------------------------

def _tracer_type():
    import jax
    return jax.core.Tracer


def assert_no_tracers(tree, where: str = "") -> None:
    """Raise `TracerLeakError` if any leaf of ``tree`` is a jax Tracer.

    ``tree`` is anything ``jax.tree.leaves`` accepts — a history
    record, a results dict, a checkpoint payload."""
    import jax
    tracer = _tracer_type()
    for leaf in jax.tree.leaves(tree):
        if isinstance(leaf, tracer):
            raise TracerLeakError(
                f"jax tracer leaked into host-side state"
                f"{f' ({where})' if where else ''}: {leaf!r} — a jitted "
                f"function let an abstract value escape its trace")


@contextlib.contextmanager
def no_tracer_leaks():
    """Context manager arming JAX's own transform-level leak checking
    for the enclosed block (compose with `assert_no_tracers` for
    host-side containers)."""
    import jax
    with jax.check_tracer_leaks():
        yield
