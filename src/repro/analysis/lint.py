"""repro-lint CLI — the invariant plane's static gate (DESIGN.md §16).

    PYTHONPATH=src python -m repro.analysis.lint --strict

Lints ``src/ examples/ benchmarks/ tests/`` (or explicit paths) with
the repo-specific rule families:

  rng-*      seeded-streams-only randomness
  det-*      no wall-clock / unordered iteration in round-loop paths
  thread-*   lock-guarded shared state + leaf-lock ordering
  pallas-*   grid↔BlockSpec consistency, alias-donation safety,
             kernel↔ref oracle wiring

Exit status: 0 clean, 1 violations (or, under ``--strict``, a
non-empty baseline), 2 usage errors. Suppressions are inline
``# repro-lint: disable=<rule> (<reason>)`` comments — the reason is
mandatory — or baseline entries; ``--strict`` (CI) accepts only the
inline, reasoned kind.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import core
from repro.analysis.core import RULE_DOCS, lint_paths

DEFAULT_PATHS = ("src", "examples", "benchmarks", "tests")
DEFAULT_BASELINE = ".repro-lint-baseline.json"


def _find_root(start: str) -> str:
    """Walk up to the repo root (the dir holding src/repro) so the CLI
    works from any cwd inside the tree."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific static analysis (invariant plane)")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: "
                         f"{' '.join(DEFAULT_PATHS)} under the repo root)")
    ap.add_argument("--strict", action="store_true",
                    help="CI mode: non-empty baseline is an error")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default: {DEFAULT_BASELINE} "
                         f"at the repo root)")
    ap.add_argument("--rule", action="append", dest="rules",
                    help="run only this rule id (repeatable)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable violation list on stdout")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        # registries populate on rule-module import
        from repro.analysis import (rules_determinism,  # noqa: F401
                                    rules_pallas, rules_rng,
                                    rules_threading)
        for rid in sorted(set(core.RULES) | set(core.PROJECT_RULES)):
            print(f"{rid:24s} {RULE_DOCS.get(rid, '')}")
        return 0

    root = _find_root(os.getcwd())
    paths = args.paths or [os.path.join(root, p) for p in DEFAULT_PATHS
                           if os.path.isdir(os.path.join(root, p))]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"repro-lint: no such path(s): {missing}", file=sys.stderr)
        return 2
    baseline = args.baseline
    if baseline is None:
        cand = os.path.join(root, DEFAULT_BASELINE)
        baseline = cand if os.path.exists(cand) else None

    report = lint_paths(paths, root=root, baseline=baseline,
                        strict=args.strict, rules=args.rules)
    if args.as_json:
        print(json.dumps([v.__dict__ for v in report.violations],
                         indent=2))
    else:
        print(report.format())
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
