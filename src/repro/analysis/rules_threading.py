"""Thread-safety rules (``thread-*``) — a two-pass AST check over
``with self._lock:`` scopes.

The concurrent plane is three classes: `WorkerPool` / `Prefetcher`
(worker threads writing results the consumer reads) and
`ClientRegistry` (an LRU cache hammered by K workers). Their invariant
(DESIGN.md §15) is that every write to shared instance state happens
under the class's lock. These rules encode it:

  thread-unguarded-write  Pass 1 collects, per class: the lock
                          attribute(s) (``self.X = threading.Lock()``),
                          the worker-entry methods (any method passed as
                          ``threading.Thread(target=self.m)``), and —
                          when the class has a lock — every method that
                          touches a locked attribute. Pass 2 flags any
                          write to a ``self.`` attribute in those
                          methods that is not lexically inside
                          ``with self.<lock>:`` (``__init__`` /
                          ``__post_init__`` run single-threaded and are
                          exempt). A worker-entry method in a class with
                          NO lock flags every ``self.`` write.
  thread-lock-order       Stub of the acquired-order contract
                          (async_engine docstrings): instance locks are
                          LEAF locks — never block while holding one.
                          Flags, inside a ``with self.<lock>:`` scope:
                          a nested ``with`` on another lock-like
                          attribute, or a call to ``.wait()`` /
                          ``.join()`` / ``.acquire()`` / ``.map()``
                          (the blocking calls that park a thread while
                          the lock starves every other worker — the
                          WorkerPool-gather vs registry-in-flight-Event
                          deadlock shape).
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import ModuleInfo, Violation, attr_chain, rule

_EXEMPT_METHODS = frozenset({"__init__", "__post_init__", "__new__"})
_BLOCKING_CALLS = frozenset({"wait", "join", "acquire", "map"})


def _lock_attrs(cls: ast.ClassDef) -> set:
    """Attributes assigned a Lock()/RLock()-like object anywhere in the
    class body (``self.X = threading.Lock()`` et al.)."""
    locks = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not isinstance(node.value, ast.Call):
            continue
        chain = attr_chain(node.value.func) or ""
        if not chain.rpartition(".")[2].endswith("Lock"):
            continue
        for t in node.targets:
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                locks.add(t.attr)
    return locks


def _worker_methods(cls: ast.ClassDef) -> set:
    """Methods handed to ``threading.Thread(target=self.m, ...)`` —
    code that runs on a thread the class itself spawned."""
    targets = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func) or ""
        if chain.rpartition(".")[2] != "Thread":
            continue
        for kw in node.keywords:
            if kw.arg == "target" and isinstance(kw.value, ast.Attribute) \
                    and isinstance(kw.value.value, ast.Name) \
                    and kw.value.value.id == "self":
                targets.add(kw.value.attr)
    return targets


def _self_attr(node) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _write_targets(stmt):
    """(attr, node) pairs for self-attribute writes in one statement:
    assignment, augmented assignment, subscript store, delete."""
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.Delete):
        targets = stmt.targets
    else:
        return
    for t in targets:
        if isinstance(t, (ast.Subscript, ast.Starred)):
            t = t.value
        attr = _self_attr(t)
        if attr is not None:
            yield attr, stmt


def _is_lock_with(item, locks: set) -> bool:
    attr = _self_attr(item.context_expr)
    return attr is not None and attr in locks


def _walk_method(method, locks, under_lock, visit):
    """Recursive walk tracking `with self.<lock>:` containment.
    ``visit(stmt, under_lock)`` sees every statement once."""
    for stmt in method if isinstance(method, list) else method.body:
        visit(stmt, under_lock)
        inner = under_lock
        if isinstance(stmt, ast.With):
            inner = under_lock or any(
                _is_lock_with(it, locks) for it in stmt.items)
            _walk_method(stmt.body, locks, inner, visit)
        elif isinstance(stmt, (ast.If, ast.For, ast.While)):
            _walk_method(stmt.body, locks, under_lock, visit)
            _walk_method(stmt.orelse, locks, under_lock, visit)
        elif isinstance(stmt, ast.Try):
            for blk in (stmt.body, stmt.orelse, stmt.finalbody):
                _walk_method(blk, locks, under_lock, visit)
            for h in stmt.handlers:
                _walk_method(h.body, locks, under_lock, visit)
        # nested function defs get a fresh thread context — skipped


def _guarded_methods(cls, locks) -> set:
    """Methods that touch any attribute some OTHER site writes under
    the lock — the class's shared-state surface."""
    locked_attrs = set()

    for method in cls.body:
        if not isinstance(method, ast.FunctionDef):
            continue

        def note(stmt, under_lock):
            if under_lock:
                for attr, _ in _write_targets(stmt):
                    locked_attrs.add(attr)

        _walk_method(method, locks, False, note)

    touches = set()
    for method in cls.body:
        if not isinstance(method, ast.FunctionDef) or \
                method.name in _EXEMPT_METHODS:
            continue
        for node in ast.walk(method):
            attr = _self_attr(node)
            if attr in locked_attrs:
                touches.add(method.name)
                break
    return touches


@rule("thread-unguarded-write",
      "shared-state write outside `with self._lock:`")
def check_unguarded_write(module: ModuleInfo):
    out = []
    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        locks = _lock_attrs(cls)
        workers = _worker_methods(cls)
        if not locks and not workers:
            continue
        checked = set(workers)
        if locks:
            checked |= _guarded_methods(cls, locks)
        for method in cls.body:
            if not isinstance(method, ast.FunctionDef):
                continue
            if method.name in _EXEMPT_METHODS or \
                    method.name not in checked:
                continue

            def visit(stmt, under_lock, method=method):
                if under_lock:
                    return
                for attr, node in _write_targets(stmt):
                    if attr in locks:
                        continue
                    why = ("with no lock attribute on the class"
                           if not locks else
                           f"outside `with self.{sorted(locks)[0]}:`")
                    out.append(Violation(
                        "thread-unguarded-write", module.relpath,
                        node.lineno, node.col_offset + 1,
                        f"`{cls.name}.{method.name}` writes "
                        f"`self.{attr}` {why} — worker threads and "
                        f"cache paths must write shared state under "
                        f"the class's lock"))

            _walk_method(method, locks, False, visit)
    return out


@rule("thread-lock-order",
      "blocking call / nested lock inside a leaf-lock scope (stub)")
def check_lock_order(module: ModuleInfo):
    out = []
    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        locks = _lock_attrs(cls)
        if not locks:
            continue
        for method in cls.body:
            if not isinstance(method, ast.FunctionDef):
                continue

            def visit(stmt, under_lock):
                if not under_lock:
                    return
                if isinstance(stmt, ast.With):
                    for it in stmt.items:
                        attr = attr_chain(it.context_expr)
                        if attr and "lock" in attr.lower() and \
                                not _is_lock_with(it, locks):
                            out.append(Violation(
                                "thread-lock-order", module.relpath,
                                stmt.lineno, stmt.col_offset + 1,
                                f"`{cls.name}` acquires `{attr}` while "
                                f"holding its own lock — instance locks "
                                f"are leaf locks (async_engine lock-"
                                f"order contract); acquire in "
                                f"pool/event → registry order, never "
                                f"nested the other way"))
                # compound statements are visited per child by
                # _walk_method; only walk the leaves here, so nested
                # calls are reported exactly once
                if isinstance(stmt, (ast.With, ast.If, ast.For,
                                     ast.While, ast.Try)):
                    return
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        tail = (attr_chain(node.func) or
                                "").rpartition(".")[2]
                        if tail in _BLOCKING_CALLS:
                            out.append(Violation(
                                "thread-lock-order", module.relpath,
                                node.lineno, node.col_offset + 1,
                                f"`.{tail}()` while holding "
                                f"`{cls.name}`'s lock can park this "
                                f"thread with the lock held (deadlock "
                                f"shape: pool gather vs registry "
                                f"in-flight Events) — release the "
                                f"lock before blocking"))

            _walk_method(method, locks, False, visit)
    return out
