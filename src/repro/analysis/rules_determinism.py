"""Determinism rules (``det-*``) — scoped to the round-loop and
registry paths (`core.DET_CRITICAL`).

The async engine's contract is *bit-identical history* across
prefetch depth, fused-K blocking and resume (DESIGN.md §12, §14). Two
host-side hazards can silently break it:

  det-wallclock       ``time.time()`` / ``datetime.now()`` in a
                      determinism-critical module. Epoch wall-clock
                      reads leak non-reproducible values into whatever
                      consumes them; interval timing belongs to
                      ``time.perf_counter``/``time.monotonic`` (which
                      stay legal — timeouts and benchmarks need them).
  det-unordered-iter  iterating a ``set`` (or dict ``.keys/.values/
                      .items``) into numeric accumulation (``sum`` over
                      it, or a loop body with augmented assignment).
                      Set order is hash-randomized across processes and
                      dict order is insertion order — thread-schedule-
                      dependent when workers fill the dict — so the
                      accumulated float depends on the run, not the
                      data. Wrap the iterable in ``sorted(...)``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (ModuleInfo, Violation, attr_chain,
                                 enclosing_function, rule)

_WALLCLOCK = frozenset({"time.time", "time.time_ns"})
_WALLCLOCK_ATTRS = frozenset({"now", "utcnow", "today"})


@rule("det-wallclock",
      "wall-clock read in a determinism-critical path")
def check_wallclock(module: ModuleInfo):
    if not module.det_critical:
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func)
        if chain is None:
            continue
        head, _, tail = chain.rpartition(".")
        if chain in _WALLCLOCK or (
                tail in _WALLCLOCK_ATTRS and
                ("datetime" in head or head in ("date", "dt"))):
            out.append(Violation(
                "det-wallclock", module.relpath, node.lineno,
                node.col_offset + 1,
                f"`{chain}()` in a determinism-critical module — use "
                f"`time.perf_counter()` for intervals, or thread the "
                f"timestamp in from the caller"))
    return out


def _unordered(node):
    """The syntactically-unordered iterables we can prove: set displays,
    set()/frozenset() calls, set comprehensions, dict view methods."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return "set"
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func) or ""
        if chain in ("set", "frozenset"):
            return chain
        tail = chain.rpartition(".")[2]
        if tail in ("keys", "values", "items") and not node.args:
            return f".{tail}()"
    return None


def _accumulates(body) -> bool:
    for stmt in body:
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.AugAssign) and isinstance(
                    sub.op, (ast.Add, ast.Sub, ast.Mult)):
                return True
    return False


@rule("det-unordered-iter",
      "dict/set iteration feeding numeric accumulation")
def check_unordered_iter(module: ModuleInfo):
    if not module.det_critical:
        return []
    out = []

    def flag(node, kind, how):
        out.append(Violation(
            "det-unordered-iter", module.relpath, node.lineno,
            node.col_offset + 1,
            f"iteration over {kind} feeds numeric accumulation "
            f"({how}) — order is not reproducible; wrap the iterable "
            f"in sorted(...)"))

    for node in ast.walk(module.tree):
        if isinstance(node, ast.For):
            kind = _unordered(node.iter)
            if kind and _accumulates(node.body):
                flag(node, kind, "augmented assignment in the loop body")
        elif isinstance(node, ast.Call):
            chain = attr_chain(node.func) or ""
            if chain.rpartition(".")[2] != "sum" and chain != "sum":
                continue
            for arg in node.args:
                gens = (arg.generators if isinstance(
                    arg, (ast.GeneratorExp, ast.ListComp)) else [])
                iters = [g.iter for g in gens] or [arg]
                for it in iters:
                    kind = _unordered(it)
                    if kind:
                        flag(node, kind, f"`{chain}(...)` over it")
    # an unordered iterable wrapped in sorted() never reaches the
    # checks above: sorted(...) is a Call that is not itself unordered,
    # so the For/sum sees an ordered expression — nothing to exempt.
    _ = enclosing_function   # imported for rule modules' shared surface
    return out
