"""Lint engine: file walking, disable comments, baseline, rule registry.

A rule is a function ``check(module: ModuleInfo) -> list[Violation]``
registered under a kebab-case id via :func:`rule`. Project-level rules
(``project_rule``) run once over the whole file set instead of per
module — the Pallas ``ref.py``-counterpart check is one.

Suppression has exactly two mechanisms, both deliberately loud:

  * an inline ``# repro-lint: disable=<rule> (<reason>)`` comment on
    the offending line (or the line above). The parenthesized reason
    is REQUIRED — a bare disable is itself a violation
    (``lint-bad-disable``), so every waiver carries its justification
    in the diff.
  * a JSON baseline file (a list of ``{"rule", "path", "line"}``
    entries) for grandfathered debt. ``--strict`` refuses a non-empty
    baseline, and the repo ships it empty — the gate holds at zero
    suppressions.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Dict, List, Optional, Sequence

#: relpath prefixes (or exact files) whose host-side code feeds the
#: bit-identical round history — the determinism rules apply here.
DET_CRITICAL = (
    "src/repro/federated/",
    "src/repro/core/",
    "src/repro/checkpoint/",
    "src/repro/data/registry.py",
    "src/repro/data/federated.py",
)

_DISABLE_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,-]+)\s*(\(([^)]*)\))?")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"[{self.rule}] {self.message}"


class ModuleInfo:
    """One parsed source file plus its per-line disable directives."""

    def __init__(self, path: str, source: str, relpath: str = None):
        self.path = path
        self.relpath = (relpath if relpath is not None else path)
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        # line -> set of disabled rule ids; a disable on its own line
        # also covers the next line (the statement it precedes)
        self.disables: Dict[int, set] = {}
        self.disable_errors: List[Violation] = []
        self._parse_disables()
        self._parents: Optional[dict] = None

    def _parse_disables(self):
        for i, text in enumerate(self.lines, start=1):
            m = _DISABLE_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            reason = (m.group(3) or "").strip()
            if not reason:
                self.disable_errors.append(Violation(
                    "lint-bad-disable", self.relpath, i, m.start() + 1,
                    "disable comment without a reason — write "
                    "'# repro-lint: disable=<rule> (<why>)'"))
                continue
            self.disables.setdefault(i, set()).update(rules)
            if text[:m.start()].strip() == "":   # standalone comment line
                self.disables.setdefault(i + 1, set()).update(rules)

    def disabled(self, rule: str, line: int) -> bool:
        return rule in self.disables.get(line, ())

    @property
    def det_critical(self) -> bool:
        rel = self.relpath.replace(os.sep, "/")
        return any(rel.endswith(p) or (p.endswith("/") and p in rel)
                   for p in DET_CRITICAL)

    def parents(self) -> dict:
        """child AST node -> parent map (built lazily, cached)."""
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents


RULES: Dict[str, Callable] = {}
PROJECT_RULES: Dict[str, Callable] = {}
RULE_DOCS: Dict[str, str] = {}


def rule(rule_id: str, doc: str):
    """Register a per-module rule: ``check(module) -> [Violation]``."""
    def deco(fn):
        RULES[rule_id] = fn
        RULE_DOCS[rule_id] = doc
        return fn
    return deco


def project_rule(rule_id: str, doc: str):
    """Register a whole-tree rule: ``check(modules) -> [Violation]``."""
    def deco(fn):
        PROJECT_RULES[rule_id] = fn
        RULE_DOCS[rule_id] = doc
        return fn
    return deco


# ---- shared AST helpers (used by several rule modules) ------------------

def attr_chain(node) -> Optional[str]:
    """Dotted-name string for Name/Attribute chains, else None.
    ``np.random.RandomState`` -> "np.random.RandomState"."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def base_name(node) -> Optional[str]:
    """Leftmost Name of an expression: ``phi.reshape(x)`` -> "phi"."""
    while True:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Attribute):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, (ast.Subscript, ast.Starred)):
            node = node.value
        else:
            return None


def numpy_aliases(tree) -> dict:
    """{"np": {"np"}, "np.random": {...}} — names bound to the numpy
    module and to numpy.random by the file's imports."""
    mods, rand = set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    mods.add(a.asname or "numpy")
                elif a.name == "numpy.random":
                    rand.add(a.asname or "numpy")   # numpy.random usable
        elif isinstance(node, ast.ImportFrom):
            if node.module == "numpy":
                for a in node.names:
                    if a.name == "random":
                        rand.add(a.asname or "random")
    return {"module": mods, "random": rand}


def enclosing_function(module: ModuleInfo, node):
    """Nearest FunctionDef/AsyncFunctionDef ancestor, or None."""
    parents = module.parents()
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def containing_stmt(fn, node) -> Optional[int]:
    """Index into ``fn.body`` of the top-level statement holding
    ``node`` (statements inside nested defs don't count)."""
    for i, stmt in enumerate(fn.body):
        for sub in ast.walk(stmt):
            if sub is node:
                return i
    return None


# ---- driver -------------------------------------------------------------

@dataclasses.dataclass
class LintReport:
    violations: List[Violation]
    files: int
    baseline_entries: int = 0
    baseline_suppressed: int = 0

    @property
    def clean(self) -> bool:
        return not self.violations

    def format(self) -> str:
        out = [v.format() for v in self.violations]
        tail = (f"{len(self.violations)} violation(s) in "
                f"{self.files} file(s)")
        if self.baseline_suppressed:
            tail += f" ({self.baseline_suppressed} baseline-suppressed)"
        out.append(tail)
        return "\n".join(out)


def iter_py_files(paths: Sequence[str]) -> List[str]:
    files = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
        elif os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".py"))
    return files


def load_baseline(path: Optional[str]) -> list:
    if not path or not os.path.exists(path):
        return []
    with open(path) as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    return entries


def _lint_module(module: ModuleInfo,
                 rules: Optional[Sequence[str]] = None) -> List[Violation]:
    out = list(module.disable_errors)
    for rule_id, check in RULES.items():
        if rules is not None and rule_id not in rules:
            continue
        for v in check(module):
            if not module.disabled(v.rule, v.line):
                out.append(v)
    return out


def lint_source(source: str, path: str = "<fixture>",
                relpath: str = None,
                rules: Optional[Sequence[str]] = None) -> List[Violation]:
    """Lint one source string — the fixture/test entry point."""
    return _lint_module(ModuleInfo(path, source, relpath=relpath),
                        rules=rules)


def lint_paths(paths: Sequence[str], *, root: str = ".",
               baseline: Optional[str] = None,
               strict: bool = False,
               rules: Optional[Sequence[str]] = None) -> LintReport:
    """Lint every ``.py`` under ``paths`` (files or directories).

    ``baseline`` entries suppress matching violations unless
    ``strict``, in which case a non-empty baseline is itself reported.
    """
    # rule modules register on import; import here so `lint_paths` is
    # usable without importing repro.analysis.lint (the CLI)
    from repro.analysis import (rules_determinism,  # noqa: F401
                                rules_pallas, rules_rng, rules_threading)

    files = iter_py_files(paths)
    modules, violations = [], []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as f:
            src = f.read()
        try:
            module = ModuleInfo(path, src, relpath=rel)
        except SyntaxError as e:
            violations.append(Violation(
                "lint-parse-error", rel, e.lineno or 1, 1, str(e)))
            continue
        modules.append(module)
        violations.extend(_lint_module(module, rules=rules))
    for rule_id, check in PROJECT_RULES.items():
        if rules is not None and rule_id not in rules:
            continue
        for v in check(modules):
            continue_ = False
            for m in modules:
                if m.relpath == v.path and m.disabled(v.rule, v.line):
                    continue_ = True
            if not continue_:
                violations.append(v)

    entries = load_baseline(baseline)
    suppressed = 0
    if entries and strict:
        violations.append(Violation(
            "lint-baseline-nonempty", baseline or "<baseline>", 1, 1,
            f"strict mode forbids baseline entries ({len(entries)} "
            f"found) — fix or inline-disable with a reason instead"))
    elif entries:
        keyed = {(e["rule"], e["path"], int(e["line"])) for e in entries}
        kept = []
        for v in violations:
            if (v.rule, v.path, v.line) in keyed:
                suppressed += 1
            else:
                kept.append(v)
        violations = kept

    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return LintReport(violations=violations, files=len(files),
                      baseline_entries=len(entries),
                      baseline_suppressed=suppressed)
