"""Production training launcher.

On a real TPU slice this drives FedMeta meta-training for any assigned
architecture at any train shape on the production mesh; on CPU use
--reduced (reduced config + host mesh + small shape) to execute the same
code path end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --shape train_4k --algo fomaml --steps 20 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_server_state
from repro.configs import INPUT_SHAPES, get_config, list_archs, reduced_config
from repro.data.lm_tasks import make_lm_task_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import input_specs, make_train_step, train_batch_layout
from repro.sharding.rules import param_pspecs, state_pspecs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--algo", default="fomaml",
                    choices=["maml", "fomaml", "meta-sgd", "meta-sgd-fo",
                             "reptile"])
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--inner-lr", type=float, default=0.01)
    ap.add_argument("--outer-lr", type=float, default=1e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + host mesh (CPU execution)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    assert shape.kind == "train", "use serve.py for inference shapes"

    if args.reduced:
        cfg = reduced_config(cfg)
        shape = dataclasses.replace(shape, seq_len=64, global_batch=4,
                                    clients_per_round=2, seqs_per_client=2)
        mesh = make_host_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    train_step, init_state, algo, _ = make_train_step(
        cfg, algo_name=args.algo, inner_lr=args.inner_lr,
        outer_lr=args.outer_lr)
    spec = input_specs(cfg, shape, mesh)
    state_sds = jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0)))
    pspec = param_pspecs(state_sds["phi"]["theta"], mesh)
    sspec = state_pspecs(state_sds, pspec, mesh)
    nm = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    step = jax.jit(train_step, in_shardings=(nm(sspec), nm(spec["pspec"])),
                   out_shardings=(nm(sspec), None), donate_argnums=(0,))

    with mesh:
        state = jax.jit(init_state, out_shardings=nm(sspec))(
            jax.random.PRNGKey(0))
        G, C, S_sup, S_qry, L_text, n_mod = train_batch_layout(
            cfg, shape, mesh.devices.shape[0]
            if "pod" in mesh.axis_names else 1)
        for it in range(args.steps):
            tasks = make_lm_task_batch(G * C, S_sup, S_qry, L_text,
                                       cfg.vocab_size, seed=it)
            batch = {
                "support": {"tokens": jnp.asarray(
                    tasks.support_tokens.reshape(G, C, S_sup, L_text))},
                "query": {"tokens": jnp.asarray(
                    tasks.query_tokens.reshape(G, C, S_qry, L_text))},
            }
            if cfg.modality:
                rngd = np.random.RandomState(it)
                for part, S in (("support", S_sup), ("query", S_qry)):
                    batch[part]["embeds"] = jnp.asarray(rngd.normal(
                        0, 0.1, (G, C, S, n_mod, cfg.d_model)),
                        jnp.dtype(cfg.dtype))
            t0 = time.time()
            state, metrics = step(state, batch)
            jax.block_until_ready(metrics)
            if (it + 1) % args.log_every == 0:
                print(f"step {it+1:4d}  loss="
                      f"{float(metrics['query_loss']):.4f}  acc="
                      f"{float(metrics['accuracy']):.4f}  "
                      f"({time.time()-t0:.2f}s)", flush=True)
        if args.ckpt:
            host_state = jax.device_get(state)
            path = save_server_state(args.ckpt, args.steps, host_state)
            print("checkpoint:", path)


if __name__ == "__main__":
    main()
