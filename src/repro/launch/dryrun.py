"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production mesh with 512 placeholder host devices; capture memory and
cost analysis + the collective schedule for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k [--multi-pod] [--algo fomaml] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --json results/
"""
# The XLA device-count override MUST precede any other import (jax locks
# the platform device count on first initialization).
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                              # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (input_specs, make_decode_step,  # noqa: E402
                                make_prefill_step, make_train_step,
                                resolve_serving_config)
from repro.sharding.rules import param_pspecs, state_pspecs  # noqa: E402

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"
    r"((?:\(|)[a-z0-9_]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by type."""
    totals: dict = {}
    counts: dict = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        key = op.replace("-start", "")
        totals[key] = totals.get(key, 0) + nbytes
        counts[key] = counts.get(key, 0) + 1
    return {"bytes_by_type": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               algo: str = "fomaml", remat: bool = True,
               donate: bool = True, extra_tag: str = "",
               moe_impl: str = "tp", shard_seq: bool = False,
               opt_state_dtype: str = "float32") -> dict:
    import dataclasses
    from repro.sharding.context import set_mesh
    cfg = get_config(arch)
    if moe_impl != "tp" or shard_seq:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl,
                                  shard_seq=shard_seq)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)), "algo": algo,
           "tag": extra_tag, "moe_impl": moe_impl, "shard_seq": shard_seq,
           "opt_state_dtype": opt_state_dtype}
    t0 = time.time()

    if shape.kind == "train":
        train_step, init_state, _, _ = make_train_step(
            cfg, algo_name=algo, remat=remat,
            opt_state_dtype=opt_state_dtype)
        state_sds = jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0)))
        pspec = param_pspecs(state_sds["phi"]["theta"], mesh)
        state_spec = state_pspecs(state_sds, pspec, mesh)
        spec = input_specs(cfg, shape, mesh)
        fn = jax.jit(train_step,
                     in_shardings=(_named(mesh, state_spec),
                                   _named(mesh, spec["pspec"])),
                     out_shardings=(_named(mesh, state_spec), None),
                     donate_argnums=(0,) if donate else ())
        args = (state_sds, spec["batch"])
    elif shape.kind == "prefill":
        scfg = resolve_serving_config(cfg, shape)
        step = make_prefill_step(scfg)
        params_sds = jax.eval_shape(
            lambda: __import__("repro.models", fromlist=["init_lm"]).init_lm(
                jax.random.PRNGKey(0), scfg))
        pspec = param_pspecs(params_sds, mesh)
        spec = input_specs(scfg, shape, mesh)
        fn = jax.jit(step, in_shardings=(_named(mesh, pspec),
                                         _named(mesh, spec["pspec"])))
        args = (params_sds, spec["batch"])
    else:  # decode
        spec = input_specs(cfg, shape, mesh)
        if spec is None:
            rec["status"] = "skipped"
            return rec
        scfg = spec["serving_cfg"]
        step = make_decode_step(scfg)
        from repro.models import init_lm
        params_sds = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0),
                                                    scfg))
        pspec = param_pspecs(params_sds, mesh)
        fn = jax.jit(step,
                     in_shardings=(_named(mesh, pspec),
                                   _named(mesh, spec["pspec"]["cache"]),
                                   _named(mesh, spec["pspec"]["tokens"])),
                     out_shardings=(None, _named(mesh, spec["pspec"]["cache"])),
                     donate_argnums=(1,) if donate else ())
        args = (params_sds, spec["batch"]["cache"], spec["batch"]["tokens"])

    with mesh:
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    # ---- memory analysis (proves it fits)
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        }
    except Exception as e:     # CPU backend may not expose it
        rec["memory"] = {"error": str(e)}

    # ---- cost analysis (FLOPs / bytes for the roofline)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed", "transcendentals",
                                "utilization operand 0", "optimal_seconds")
                       or k.startswith("bytes accessed")}
    except Exception as e:
        rec["cost"] = {"error": str(e)}

    # ---- collective schedule from the post-SPMD HLO
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["hlo_bytes"] = len(hlo)
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--algo", default="fomaml")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-impl", default="tp", choices=["tp", "ep"])
    ap.add_argument("--shard-seq", action="store_true")
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", default=None,
                    help="output file (single) or directory (--all)")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                pairs.append((arch, shape))
    else:
        pairs.append((args.arch, args.shape))

    results = []
    for arch, shape in pairs:
        # documented skip (DESIGN.md §6): enc-dec @ 512k decode
        if arch == "seamless-m4t-medium" and shape == "long_500k":
            rec = {"arch": arch, "shape": shape, "status": "skipped",
                   "reason": "enc-dec decoder is full-attention; 512k "
                             "decode outside operating regime (DESIGN.md)"}
        else:
            try:
                rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                                 algo=args.algo, remat=not args.no_remat,
                                 extra_tag=args.tag, moe_impl=args.moe_impl,
                                 shard_seq=args.shard_seq,
                                 opt_state_dtype=args.opt_dtype)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
        results.append(rec)
        slim = {k: v for k, v in rec.items() if k not in ("trace",)}
        print(json.dumps(slim), flush=True)
        if args.json:
            if args.all:
                os.makedirs(args.json, exist_ok=True)
                mesh_tag = "pod2" if args.multi_pod else "pod1"
                path = os.path.join(
                    args.json, f"{arch}__{shape}__{mesh_tag}"
                               f"{('__' + args.tag) if args.tag else ''}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            else:
                with open(args.json, "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
