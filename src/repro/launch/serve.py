"""Personalized serving launcher: prefill + batched decode on the
production mesh (or --reduced on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --shape decode_32k --steps 4 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs, reduced_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import (input_specs, make_decode_step,
                                make_prefill_step, resolve_serving_config)
from repro.models import init_lm
from repro.sharding.rules import param_pspecs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    assert shape.kind == "decode"

    if args.reduced:
        cfg = reduced_config(cfg)
        shape = dataclasses.replace(shape, seq_len=128, global_batch=2)
        mesh = make_host_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    spec = input_specs(cfg, shape, mesh)
    scfg = spec["serving_cfg"]
    decode = make_decode_step(scfg)
    nm = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    pspec = param_pspecs(
        jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), scfg)), mesh)
    step = jax.jit(decode,
                   in_shardings=(nm(pspec), nm(spec["pspec"]["cache"]),
                                 nm(spec["pspec"]["tokens"])),
                   out_shardings=(None, nm(spec["pspec"]["cache"])),
                   donate_argnums=(1,))

    with mesh:
        params = jax.jit(lambda k: init_lm(k, scfg),
                         out_shardings=nm(pspec))(jax.random.PRNGKey(0))
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec["batch"]["cache"])
        cache["length"] = jnp.asarray(min(64, shape.seq_len), jnp.int32)
        tok = jnp.zeros((shape.global_batch, 1), jnp.int32)
        for it in range(args.steps):
            t0 = time.time()
            logits, cache = step(params, cache, tok)
            jax.block_until_ready(logits)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            print(f"decode step {it}: {time.time()-t0:.2f}s  "
                  f"logits {logits.shape}", flush=True)


if __name__ == "__main__":
    main()
