"""Personalized serving launcher: prefill + batched decode on the
production mesh (or --reduced on CPU), plus the builders that wire an
LM config into `federated.serving.ServingEngine` (adaptation-on-demand,
DESIGN.md §18).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --shape decode_32k --steps 4 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs, reduced_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import (input_specs, make_apply_fn, make_decode_step,
                                make_prefill_step, resolve_serving_config)
from repro.models import init_lm
from repro.sharding.rules import param_pspecs


def build_serving_fns(cfg, *, unroll_layers: bool = False):
    """(prefill, decode) entry points for `ServingEngine` — the same
    builders the dry-run lowers at production scale."""
    return (make_prefill_step(cfg, unroll_layers=unroll_layers),
            make_decode_step(cfg, unroll_layers=unroll_layers))


def build_engine(cfg, phi=None, *, algo_name: str = "fomaml",
                 inner_lr: float = 0.05, inner_steps: int = 1,
                 adapt_batch: int = 4, cache_capacity: Optional[int] = 64,
                 adapt_impl: Optional[str] = None,
                 decode_impl: Optional[str] = None, seed: int = 0):
    """Wire an LM config into a `ServingEngine`: FedMeta algorithm over
    `lm_loss`, prefill/decode serve steps, bounded adaptation cache.
    `phi` defaults to a fresh init (tests/benches); production passes
    the meta-trained state. `decode_impl` pins the decode-attention
    kernel ("xla" | "pallas" | "pallas_interpret") for everything this
    engine traces."""
    from repro.core import make_algorithm
    from repro.core.losses import lm_loss
    from repro.federated.serving import AdaptationCache, ServingEngine
    from repro.kernels.decode_attention import ops as dec_ops

    loss_fn, eval_fn = lm_loss(make_apply_fn(cfg, remat=False))
    algo = make_algorithm(algo_name, loss_fn, eval_fn, inner_lr, inner_steps)
    if phi is None:
        phi = {"theta": init_lm(jax.random.PRNGKey(seed), cfg)}
        if algo_name.startswith("meta-sgd"):
            phi = algo.init_state(jax.random.PRNGKey(seed),
                                  lambda k: init_lm(k, cfg))
    prefill, decode = build_serving_fns(cfg)
    if decode_impl is not None:
        raw = decode

        def decode(params, cache, tokens):
            with dec_ops.use_impl(decode_impl):
                return raw(params, cache, tokens)

    return ServingEngine(algo, phi, adapt_batch=adapt_batch,
                         adapt_steps=inner_steps,
                         cache=AdaptationCache(cache_capacity),
                         prefill_fn=prefill, decode_fn=decode)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    assert shape.kind == "decode"

    if args.reduced:
        cfg = reduced_config(cfg)
        shape = dataclasses.replace(shape, seq_len=128, global_batch=2)
        mesh = make_host_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    spec = input_specs(cfg, shape, mesh)
    scfg = spec["serving_cfg"]
    decode = make_decode_step(scfg)
    nm = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    pspec = param_pspecs(
        jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), scfg)), mesh)
    step = jax.jit(decode,
                   in_shardings=(nm(pspec), nm(spec["pspec"]["cache"]),
                                 nm(spec["pspec"]["tokens"])),
                   out_shardings=(None, nm(spec["pspec"]["cache"])),
                   donate_argnums=(1,))

    with mesh:
        params = jax.jit(lambda k: init_lm(k, scfg),
                         out_shardings=nm(pspec))(jax.random.PRNGKey(0))
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec["batch"]["cache"])
        cache["length"] = jnp.asarray(min(64, shape.seq_len), jnp.int32)
        tok = jnp.zeros((shape.global_batch, 1), jnp.int32)
        for it in range(args.steps):
            t0 = time.perf_counter()
            logits, cache = step(params, cache, tok)
            jax.block_until_ready(logits)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            print(f"decode step {it}: {time.perf_counter()-t0:.2f}s  "
                  f"logits {logits.shape}", flush=True)


if __name__ == "__main__":
    main()
