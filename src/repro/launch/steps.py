"""Entry-point builders for the production LM configs.

  train_step    FedMeta meta-training round over a task batch of clients
                (G client-groups over the pod axis x C clients scanned x
                S sequences data-parallel)
  prefill_step  (params, batch) -> (next-token logits, decode cache)
  decode_step   (params, cache, tokens) -> (logits, cache)

`input_specs` builds ShapeDtypeStruct stand-ins + PartitionSpecs for every
entry point — the dry-run lowers against these (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, InputShape, ModelConfig
from repro.core.algorithms import make_algorithm
from repro.core.fedmeta import federated_meta_step
from repro.core.losses import lm_loss
from repro.models import init_lm, lm_apply, init_decode_cache, lm_decode_step
from repro.optim import Optimizer, adam
from repro.sharding.rules import (batch_axes, batch_pspec, cache_pspecs,
                                  param_pspecs, state_pspecs)

LONG_CONTEXT_WINDOW = 8192   # SWA window applied to dense archs @ long_500k


def resolve_serving_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k requires sub-quadratic attention: dense/full-attention
    archs run their sliding-window variant (DESIGN.md §6)."""
    if (shape.name == "long_500k" and cfg.sliding_window is None
            and cfg.attention == "gqa" and any(k == "attn"
                                               for k in cfg.layer_pattern)):
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def make_apply_fn(cfg: ModelConfig, *, remat: bool = True,
                  unroll_layers: bool = False):
    """apply(params, batch) -> (logits, aux); batch = tokens or dict."""

    def apply_fn(params, batch):
        if isinstance(batch, dict):
            return lm_apply(params, cfg, batch["tokens"],
                            modality_embeds=batch.get("embeds"), remat=remat,
                            unroll_layers=unroll_layers)
        return lm_apply(params, cfg, batch, remat=remat,
                        unroll_layers=unroll_layers)

    return apply_fn


# ------------------------------------------------------------- train step

def make_train_step(cfg: ModelConfig, *, algo_name: str = "fomaml",
                    inner_lr: float = 0.01, outer_lr: float = 1e-4,
                    inner_steps: int = 1, remat: bool = True,
                    scan_clients: bool = True, unroll_layers: bool = False,
                    opt_state_dtype="float32"):
    """FedMeta meta-training step for an LM arch.

    state = {"phi": {...}, "opt": {...}}
    batch = {"support": leaf(G, C, S, ...), "query": ...} — G client groups
    (pod-parallel), C clients (scanned), S sequences (data-parallel).
    scan_clients=False / unroll_layers=True produce scan-free HLO for the
    roofline cost probes (XLA cost analysis counts loop bodies once).
    """
    loss_fn, eval_fn = lm_loss(make_apply_fn(cfg, remat=remat,
                                             unroll_layers=unroll_layers))
    algo = make_algorithm(algo_name, loss_fn, eval_fn, inner_lr, inner_steps)
    optimizer = adam(outer_lr, state_dtype=jnp.dtype(opt_state_dtype))

    def init_state(key):
        phi = algo.init_state(key, lambda k: init_lm(k, cfg))
        return {"phi": phi, "opt": optimizer.init(phi)}

    def train_step(state, batch):
        def per_group(sup, qry):
            # scan over clients with a meta-gradient accumulator: only one
            # adapted θ_u is live at a time (DESIGN.md §4)
            def body(acc, sq):
                s, q = sq
                g, met = algo.client_grad(state["phi"], s, q)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, met

            acc0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                state["phi"] if algo_name.startswith("meta-sgd")
                                else {"theta": state["phi"]["theta"]})
            C = jax.tree.leaves(sup)[0].shape[0]
            if scan_clients:
                meta_g, mets = jax.lax.scan(body, acc0, (sup, qry))
            else:   # scan-free variant for cost probes
                meta_g, mets_list = acc0, []
                for i in range(C):
                    sq = jax.tree.map(lambda x: x[i], (sup, qry))
                    meta_g, met = body(meta_g, sq)
                    mets_list.append(met)
                mets = jax.tree.map(lambda *xs: jnp.stack(xs), *mets_list)
            meta_g = jax.tree.map(lambda x: x / C, meta_g)
            mets = jax.tree.map(jnp.mean, mets)
            return meta_g, mets

        meta_g, mets = jax.vmap(per_group)(batch["support"], batch["query"])
        meta_g = jax.tree.map(lambda x: jnp.mean(x, axis=0), meta_g)
        mets = jax.tree.map(lambda x: jnp.mean(x, axis=0), mets)
        phi, opt = optimizer.update(state["phi"], meta_g, state["opt"])
        return {"phi": phi, "opt": opt}, mets

    return train_step, init_state, algo, optimizer


# ------------------------------------------------------------ serve steps

def make_prefill_step(cfg: ModelConfig, *, unroll_layers: bool = False):
    def prefill_step(params, batch):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        embeds = batch.get("embeds") if isinstance(batch, dict) else None
        logits, aux, cache = lm_apply(params, cfg, tokens,
                                      modality_embeds=embeds, remat=False,
                                      collect_cache=True, logits_mode="last",
                                      unroll_layers=unroll_layers)
        return logits[:, 0], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, unroll_layers: bool = False):
    def decode_step(params, cache, tokens):
        logits, new_cache = lm_decode_step(params, cfg, tokens, cache,
                                           unroll_layers=unroll_layers)
        return logits[:, 0], new_cache

    return decode_step


# ------------------------------------------------------------ input specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_layout(cfg: ModelConfig, shape: InputShape, n_pods: int):
    """(G, C, S_support, S_query, L_text, n_mod)."""
    G = n_pods
    S = shape.seqs_per_client
    C = shape.global_batch // (G * S)
    assert C * G * S == shape.global_batch, (shape.name, G, S)
    n_mod = cfg.num_modality_tokens if cfg.modality else 0
    L_text = shape.seq_len - (n_mod if cfg.modality == "vision" else 0)
    return G, C, S // 2, S - S // 2, L_text, n_mod


def input_specs(cfg: ModelConfig, shape: InputShape, mesh, *,
                cache_seq_shard: bool = False) -> dict:
    """ShapeDtypeStructs + PartitionSpecs for the entry point of `shape`.

    Returns {"args": (...sds...), "pspecs": (...matching specs...)}.
    """
    n_pods = mesh.devices.shape[0] if "pod" in mesh.axis_names else 1
    act_dtype = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        G, C, S_sup, S_qry, L_text, n_mod = train_batch_layout(
            cfg, shape, n_pods)

        def part(S):
            leaf = {"tokens": _sds((G, C, S, L_text), jnp.int32)}
            spec = {"tokens": P("pod" if n_pods > 1 else None, None,
                                "data", None)}
            if cfg.modality:
                leaf["embeds"] = _sds((G, C, S, n_mod, cfg.d_model), act_dtype)
                spec["embeds"] = P("pod" if n_pods > 1 else None, None,
                                   "data", None, None)
            return leaf, spec

        sup, sup_spec = part(S_sup)
        qry, qry_spec = part(S_qry)
        return {"batch": {"support": sup, "query": qry},
                "pspec": {"support": sup_spec, "query": qry_spec}}

    B = shape.global_batch
    baxes = batch_axes(mesh)
    bsize = int(np.prod([dict(zip(mesh.axis_names,
                                  mesh.devices.shape))[a] for a in baxes]))
    b_ax = (baxes if len(baxes) > 1 else baxes[0]) if B % bsize == 0 else None

    if shape.kind == "prefill":
        n_mod = cfg.num_modality_tokens if cfg.modality else 0
        L_text = shape.seq_len - (n_mod if cfg.modality == "vision" else 0)
        batch = {"tokens": _sds((B, L_text), jnp.int32)}
        spec = {"tokens": P(b_ax, None)}
        if cfg.modality:
            batch["embeds"] = _sds((B, n_mod, cfg.d_model), act_dtype)
            spec["embeds"] = P(b_ax, None, None)
        return {"batch": batch, "pspec": spec}

    # decode: one token against a seq_len cache
    serving_cfg = resolve_serving_config(cfg, shape)

    def build_cache():
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = jnp.zeros((B, cfg.num_modality_tokens, cfg.d_model),
                                act_dtype)
        return init_decode_cache(serving_cfg, B, shape.seq_len,
                                 dtype=act_dtype, enc_out=enc_out)

    cache = jax.eval_shape(build_cache)
    cache_spec = cache_pspecs(cache, mesh, seq_shard=cache_seq_shard)
    tokens = _sds((B, 1), jnp.int32)
    return {"batch": {"tokens": tokens, "cache": cache},
            "pspec": {"tokens": P(b_ax, None), "cache": cache_spec},
            "serving_cfg": serving_cfg}
