"""Production mesh construction.

Functions, not module-level constants — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees its 512 placeholder host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over the actually-available devices (tests)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
