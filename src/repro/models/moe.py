"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Baseline sharding story (tensor-parallel experts): stacked expert weights
(E, d, d_ff) are sharded on d/d_ff over ("data","model"); dispatch keeps
tokens shard-local. An expert-parallel all-to-all variant lives in
`repro/sharding/ep_moe.py` as the §Perf optimization.

Dispatch algorithm (jit-stable shapes, standard Switch-style capacity):
  1. router logits -> top-k experts + renormalized gates (Mixtral style),
  2. flatten (token, slot) pairs, stable-sort by expert id,
  3. within-expert rank via cumsum; tokens with rank >= capacity drop,
  4. gather tokens into (E, capacity, d), run all experts as one batched
     einsum (MXU-friendly), scatter-add back weighted by gates.

Also computes the Switch/ST-MoE load-balance auxiliary loss — kept inside
both FedMeta loops so the router adapts per client.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Rng, dense_init, mlp_apply, mlp_init


def moe_init(rng: Rng, cfg, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {"w_router": dense_init(rng, d, E, dtype)}
    # stacked expert weights: (E, ...) so experts run as one batched matmul
    def stack(maker):
        return jnp.stack([maker() for _ in range(E)])
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = stack(lambda: dense_init(rng, d, ff, dtype))
    p["w_up"] = stack(lambda: dense_init(rng, d, ff, dtype))
    p["w_down"] = stack(lambda: dense_init(rng, ff, d, dtype))
    if cfg.num_shared_experts > 0:
        p["shared"] = mlp_init(rng, d, ff * cfg.num_shared_experts,
                               cfg.mlp_act, dtype)
    return p


def _expert_ffn(params, cfg, x_e):
    """x_e: (E, C, d) -> (E, C, d): all experts as batched einsums."""
    if cfg.mlp_act == "swiglu":
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, params["w_gate"]))
             * jnp.einsum("ecd,edf->ecf", x_e, params["w_up"]))
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", x_e,
                                              params["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x_e, params["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def moe_apply(params, cfg, x, *, capacity_factor: float | None = None):
    """x: (B, L, d) -> (y, aux_loss)."""
    B, L, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    cf = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    T = B * L
    xt = x.reshape(T, d)

    logits = (xt @ params["w_router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(logits, K)             # (T, K)
    gates = jax.nn.softmax(gate_vals, axis=-1)                   # renorm top-k

    # ---- load-balance aux loss (Switch): E * mean(frac_tokens * mean_prob)
    onehot = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    frac_tokens = onehot.mean(axis=0)
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * mean_prob) * cfg.router_aux_coef

    # ---- capacity dispatch
    capacity = int(np.ceil(T * K / E * cf))
    flat_expert = expert_ids.reshape(-1)                          # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # rank within expert group
    counts = jnp.bincount(sorted_expert, length=E)
    group_start = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - group_start[sorted_expert]
    keep = rank < capacity
    slot = sorted_expert * capacity + jnp.where(keep, rank, 0)

    # gather tokens -> (E*capacity, d); dropped slots read token 0, masked
    buf_tok = jnp.zeros((E * capacity,), jnp.int32).at[slot].set(
        jnp.where(keep, sorted_token, 0).astype(jnp.int32))
    buf_mask = jnp.zeros((E * capacity,), jnp.float32).at[slot].set(
        keep.astype(jnp.float32))
    x_e = (xt[buf_tok] * buf_mask[:, None]).reshape(E, capacity, d)

    y_e = _expert_ffn(params, cfg, x_e).reshape(E * capacity, d)

    # combine: scatter-add weighted outputs back to tokens
    contrib = jnp.zeros((T, d), y_e.dtype).at[
        jnp.where(keep, sorted_token, T)  # dropped -> scratch row T
    ].add(jnp.where(keep, sorted_gate, 0.0)[:, None].astype(y_e.dtype)
          * y_e[jnp.where(keep, slot, 0)],
          mode="drop")
    y = contrib.reshape(B, L, d)

    if cfg.num_shared_experts > 0:
        y = y + mlp_apply(params["shared"], x, cfg.mlp_act)
    return y.astype(x.dtype), aux
