"""Attention modules: GQA (opt. bias / sliding window / M-RoPE), MLA
(DeepSeek-V2 latent attention with compressed KV cache), cross-attention
for the encoder-decoder, plus one-token decode paths.

Cache layouts (per layer):
  GQA:  {"k": (B, C, Kv, hd), "v": (B, C, Kv, hd)}  C = cache capacity
        (ring buffer when sliding window is active: C == window)
  MLA:  {"c": (B, C, R), "kpe": (B, C, rope_dim)}   — compressed latents
Both carry "length": () int32 — number of valid tokens already cached —
and the ring write position is length % C.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.attention import ops as attn_ops
from repro.models.layers import (Rng, apply_mrope, apply_rope, dense_init,
                                 rmsnorm, rmsnorm_init, text_mrope_positions)


# ================================================================= GQA

def gqa_init(rng: Rng, cfg, dtype, *, cross: bool = False):
    d, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(rng, d, H * hd, dtype),
        "wk": dense_init(rng, d, Kv * hd, dtype),
        "wv": dense_init(rng, d, Kv * hd, dtype),
        "wo": dense_init(rng, H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Kv * hd,), dtype)
        p["bv"] = jnp.zeros((Kv * hd,), dtype)
    return p


def _qkv(params, cfg, x, kv_input=None):
    B, L, _ = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_in = x if kv_input is None else kv_input
    Lk = kv_in.shape[1]
    q = x @ params["wq"]
    k = kv_in @ params["wk"]
    v = kv_in @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(B, L, H, hd), k.reshape(B, Lk, Kv, hd),
            v.reshape(B, Lk, Kv, hd))


def _rope_qk(cfg, q, k, q_positions, k_positions):
    if cfg.mrope:
        qp = (q_positions if q_positions.shape[-1:] == (3,)
              else text_mrope_positions(q_positions))
        kp = (k_positions if k_positions.shape[-1:] == (3,)
              else text_mrope_positions(k_positions))
        q = apply_mrope(q, qp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, kp, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, k_positions, cfg.rope_theta)
    return q, k


def gqa_forward(params, cfg, x, positions, *, causal: bool = True,
                window=None, return_kv: bool = False):
    """Training / prefill self-attention. x: (B, L, d)."""
    B, L, _ = x.shape
    q, k, v = _qkv(params, cfg, x)
    q, k = _rope_qk(cfg, q, k, positions, positions)
    o = attn_ops.flash_attention(q, k, v, causal=causal, window=window)
    y = o.reshape(B, L, cfg.num_heads * cfg.head_dim) @ params["wo"]
    return (y, (k, v)) if return_kv else y


def cross_attn_forward(params, cfg, x, enc_out):
    """Decoder->encoder cross attention (no rope, no causal mask)."""
    B, L, _ = x.shape
    q, k, v = _qkv(params, cfg, x, kv_input=enc_out)
    o = attn_ops.flash_attention(q, k, v, causal=False)
    return o.reshape(B, L, cfg.num_heads * cfg.head_dim) @ params["wo"]


def gqa_init_cache(cfg, batch: int, capacity: int, dtype):
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, capacity, Kv, hd), dtype),
        "v": jnp.zeros((batch, capacity, Kv, hd), dtype),
    }


def gqa_decode(params, cfg, x, cache, length, *, window=None):
    """One-token decode. x: (B, 1, d); length: () valid tokens in cache.

    The new token's position is `length`; it is written into the ring slot
    length % C. Attention runs over the cache with positional masking
    handled via kv_length (cache is position-coherent because either
    C >= seq (full) or C == window (ring stores exactly the live window)).
    """
    B = x.shape[0]
    C = cache["k"].shape[1]
    q, k, v = _qkv(params, cfg, x)
    pos = jnp.full((B, 1), length, jnp.int32)
    q, k = _rope_qk(cfg, q, k, pos, pos)
    slot = (length % C).astype(jnp.int32)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    valid = jnp.minimum(length + 1, C)
    # Ring semantics: every valid slot is within the window by
    # construction, so decode attends to all valid slots uniformly.
    # Routed through the flash-decode kernel dispatcher (GQA-packed,
    # single cache pass on TPU; pure-jnp oracle on CPU/dry-run).
    from repro.kernels.decode_attention import ops as dec_ops
    o = dec_ops.decode_attention(q[:, 0], new_k, new_v, valid)[:, None]
    y = o.reshape(B, 1, cfg.num_heads * cfg.head_dim) @ params["wo"]
    return y, {"k": new_k, "v": new_v}


# ================================================================= MLA

def mla_init(rng: Rng, cfg, dtype):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, R = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    p = {}
    if cfg.q_lora_rank > 0:
        p["w_dq"] = dense_init(rng, d, cfg.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["w_uq"] = dense_init(rng, cfg.q_lora_rank, H * (nope + rope_d), dtype)
    else:
        p["wq"] = dense_init(rng, d, H * (nope + rope_d), dtype)
    p["w_dkv"] = dense_init(rng, d, R, dtype)
    p["kv_norm"] = rmsnorm_init(R, dtype)
    p["w_kpe"] = dense_init(rng, d, rope_d, dtype)
    p["w_uk"] = dense_init(rng, R, H * nope, dtype)
    p["w_uv"] = dense_init(rng, R, H * nope, dtype)
    p["wo"] = dense_init(rng, H * nope, d, dtype)
    return p


def _mla_q(params, cfg, x):
    B, L, _ = x.shape
    H, nope, rope_d = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank > 0:
        q = rmsnorm(params["q_norm"], x @ params["w_dq"]) @ params["w_uq"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, L, H, nope + rope_d)
    return q[..., :nope], q[..., nope:]


def _mla_latents(params, cfg, x, positions):
    c = rmsnorm(params["kv_norm"], x @ params["w_dkv"])      # (B, L, R)
    kpe = x @ params["w_kpe"]                                # (B, L, rope_d)
    kpe = apply_rope(kpe[:, :, None, :], positions,
                     cfg.rope_theta)[:, :, 0, :]
    return c, kpe


def mla_forward(params, cfg, x, positions, *, causal: bool = True,
                return_latents: bool = False):
    """Training / prefill MLA: materialize per-head k,v from latents."""
    B, L, _ = x.shape
    H, nope, rope_d = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q_nope, q_pe = _mla_q(params, cfg, x)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    c, kpe = _mla_latents(params, cfg, x, positions)
    k_nope = (c @ params["w_uk"]).reshape(B, L, H, nope)
    v = (c @ params["w_uv"]).reshape(B, L, H, nope)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kpe[:, :, None, :],
                                                  (B, L, H, rope_d))], axis=-1)
    # scale uses the full qk dim (nope + rope_d)
    o = attn_ops.flash_attention(q, k, v, causal=causal)
    y = o.reshape(B, L, H * nope) @ params["wo"]
    return (y, (c, kpe)) if return_latents else y


def mla_init_cache(cfg, batch: int, capacity: int, dtype):
    return {
        "c": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, capacity, cfg.rope_head_dim), dtype),
    }


def mla_decode(params, cfg, x, cache, length):
    """Absorbed one-token MLA decode: attention runs directly over the
    compressed latent cache (never materializes per-head K/V) —
    scores = (W_uk^T q_nope)·c + q_pe·k_pe, out = W_uv^T-projected attn·c.
    This is the TPU adaptation of DeepSeek-V2's weight-absorption trick.
    """
    B = x.shape[0]
    H, nope, rope_d, R = (cfg.num_heads, cfg.head_dim, cfg.rope_head_dim,
                          cfg.kv_lora_rank)
    C = cache["c"].shape[1]
    pos = jnp.full((B, 1), length, jnp.int32)
    q_nope, q_pe = _mla_q(params, cfg, x)                   # (B,1,H,·)
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)
    c_new, kpe_new = _mla_latents(params, cfg, x, pos)
    slot = (length % C).astype(jnp.int32)
    c = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype),
                                     (0, slot, 0))
    kpe = jax.lax.dynamic_update_slice(cache["kpe"],
                                       kpe_new.astype(cache["kpe"].dtype),
                                       (0, slot, 0))
    valid = jnp.minimum(length + 1, C)
    # absorb W_uk into q: q_lat (B,H,R)
    w_uk = params["w_uk"].reshape(R, H, nope)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = jnp.einsum("bhr,bjr->bhj", q_lat, c.astype(jnp.float32))
    s = s + jnp.einsum("bhd,bjd->bhj", q_pe[:, 0].astype(jnp.float32),
                       kpe.astype(jnp.float32))
    s = s / np.sqrt(nope + rope_d)
    mask = jnp.arange(C)[None, None, :] < valid
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhj,bjr->bhr", p, c.astype(jnp.float32))  # (B,H,R)
    w_uv = params["w_uv"].reshape(R, H, nope)
    o = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32))
    y = o.reshape(B, 1, H * nope).astype(x.dtype) @ params["wo"]
    return y, {"c": c, "kpe": kpe}
