"""Decoder/encoder blocks assembled from mixers (attention / mamba / MoE).

A *layer spec* is (kind, ffn) with kind in {"attn", "mamba"} and ffn in
{"none", "mlp", "moe"}; the LM groups layers with identical specs into
scanned stacks (see lm.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import Rng, mlp_apply, mlp_init, rmsnorm, rmsnorm_init


def layer_spec(cfg, i: int):
    kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
    if cfg.d_ff == 0:
        ffn = "none"
    elif cfg.num_experts > 0 and cfg.is_moe_layer(i):
        ffn = "moe"
    else:
        ffn = "mlp"
    return (kind, ffn)


def block_init(rng: Rng, cfg, spec, dtype, *, cross: bool = False):
    kind, ffn = spec
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.attention == "mla":
            p["mixer"] = attn.mla_init(rng, cfg, dtype)
        else:
            p["mixer"] = attn.gqa_init(rng, cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = ssm.mamba_init(rng, cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_cross"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn.gqa_init(rng, cfg, dtype, cross=True)
    if ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if ffn == "moe":
            p["ffn"] = moe_mod.moe_init(rng, cfg, dtype)
        else:
            p["ffn"] = mlp_init(rng, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def block_forward(params, cfg, spec, x, positions, *, causal: bool = True,
                  enc_out=None):
    """Full-sequence forward. Returns (y, aux_loss)."""
    kind, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention == "mla":
            y = attn.mla_forward(params["mixer"], cfg, h, positions,
                                 causal=causal)
        else:
            y = attn.gqa_forward(params["mixer"], cfg, h, positions,
                                 causal=causal, window=cfg.sliding_window)
    else:
        y = ssm.mamba_forward(params["mixer"], cfg, h)
    x = x + y
    if "cross" in params:
        h = rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        x = x + attn.cross_attn_forward(params["cross"], cfg, h, enc_out)
    if ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = _moe(params["ffn"], cfg, h)
        else:
            y = mlp_apply(params["ffn"], h, cfg.mlp_act)
        x = x + y
    return x, aux


def _moe(params, cfg, h):
    """Dispatch to the configured MoE implementation (perf lever)."""
    if cfg.moe_impl == "ep":
        from repro.sharding.context import get_mesh
        mesh = get_mesh()
        if mesh is not None:
            from repro.sharding.ep_moe import ep_moe_apply
            return ep_moe_apply(params, cfg, h, mesh), jnp.zeros((),
                                                                 jnp.float32)
    return moe_mod.moe_apply(params, cfg, h)


def _ring_place(full, capacity: int):
    """Place the last min(L, capacity) of (B, L, ...) into a (B, capacity,
    ...) ring buffer at slots (j % capacity) — decode-coherent."""
    B, L = full.shape[:2]
    m = min(L, capacity)
    base = L - m
    slots = (base + jnp.arange(m)) % capacity
    buf = jnp.zeros((B, capacity) + full.shape[2:], full.dtype)
    return buf.at[:, slots].set(full[:, base:])


def block_prefill(params, cfg, spec, x, positions, capacity: int, *,
                  enc_out=None):
    """Forward that also emits a decode-ready cache. Returns (y, aux, cache)."""
    kind, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention == "mla":
            y, (c, kpe) = attn.mla_forward(params["mixer"], cfg, h, positions,
                                           return_latents=True)
            cache = {"c": _ring_place(c, capacity),
                     "kpe": _ring_place(kpe, capacity)}
        else:
            cap = (min(capacity, cfg.sliding_window)
                   if cfg.sliding_window else capacity)
            y, (k, v) = attn.gqa_forward(params["mixer"], cfg, h, positions,
                                         window=cfg.sliding_window,
                                         return_kv=True)
            cache = {"k": _ring_place(k, cap), "v": _ring_place(v, cap)}
    else:
        y, cache = ssm.mamba_forward(params["mixer"], cfg, h,
                                     return_cache=True)
    x = x + y
    if "cross" in params:
        h = rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        x = x + attn.cross_attn_forward(params["cross"], cfg, h, enc_out)
    if ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = _moe(params["ffn"], cfg, h)
        else:
            y = mlp_apply(params["ffn"], h, cfg.mlp_act)
        x = x + y
    return x, aux, cache


def block_init_cache(cfg, spec, batch: int, capacity: int, dtype):
    kind, _ = spec
    if kind == "attn":
        if cfg.attention == "mla":
            return attn.mla_init_cache(cfg, batch, capacity, dtype)
        cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
        return attn.gqa_init_cache(cfg, batch, cap, dtype)
    return ssm.mamba_init_cache(cfg, batch, dtype)


def block_decode(params, cfg, spec, x, cache, length, *, enc_out=None):
    """One-token decode. x: (B, 1, d). Returns (y, new_cache)."""
    kind, ffn = spec
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention == "mla":
            y, cache = attn.mla_decode(params["mixer"], cfg, h, cache, length)
        else:
            y, cache = attn.gqa_decode(params["mixer"], cfg, h, cache, length,
                                       window=cfg.sliding_window)
    else:
        y, cache = ssm.mamba_decode(params["mixer"], cfg, h, cache)
    x = x + y
    if "cross" in params:
        h = rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        x = x + attn.cross_attn_forward(params["cross"], cfg, h, enc_out)
    if ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, _ = _moe(params["ffn"], cfg, h)
        else:
            y = mlp_apply(params["ffn"], h, cfg.mlp_act)
        x = x + y
    return x, cache
