from repro.models.lm import init_lm, lm_apply, init_decode_cache, lm_decode_step
