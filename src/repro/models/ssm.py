"""Mamba2 block (SSD form) — forward (chunked scan) and one-token decode.

Structure (arXiv:2405.21060):
  in_proj x -> [z | xc | B | C | dt]   (gate, conv channels, proj, step)
  causal depthwise conv over [xc|B|C] + silu
  SSD scan over per-head (x, dt, A, B, C)
  gated RMSNorm (y * silu(z)), out_proj

ngroups = 1 (B/C shared across heads). Decode carries a conv ring state
(last conv_width-1 inputs) and the (nh, hp, N) SSM state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ops as ssd_ops
from repro.models.layers import Rng, dense_init, rmsnorm, rmsnorm_init


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads
    hp = cfg.ssm_head_dim
    assert nh * hp == d_in, (nh, hp, d_in)
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return d_in, nh, hp, N, conv_dim


def mamba_init(rng: Rng, cfg, dtype):
    d = cfg.d_model
    d_in, nh, hp, N, conv_dim = _dims(cfg)
    proj_out = 2 * d_in + 2 * N + nh          # z, xc, B, C, dt
    p = {
        "w_in": dense_init(rng, d, proj_out, dtype),
        "conv_w": (jax.random.normal(rng.next(), (cfg.conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),     # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus ~ 0.12
        "norm": rmsnorm_init(d_in, dtype),
        "w_out": dense_init(rng, d_in, d, dtype),
    }
    return p


def _split_proj(cfg, proj):
    d_in, nh, hp, N, _ = _dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(params, xbc, cfg):
    """Depthwise causal conv over (B, L, conv_dim)."""
    W = cfg.conv_width
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for w in range(W):
        out = out + pad[:, w:w + xbc.shape[1]] * params["conv_w"][W - 1 - w]
    return out + params["conv_b"]


def mamba_forward(params, cfg, x, *, ssd_impl: str | None = None,
                  return_cache: bool = False):
    """x: (B, L, d) -> (B, L, d) via the chunked SSD scan.

    With return_cache, also returns the decode cache after the last token
    (conv tail + final SSM state) for prefill -> decode handoff."""
    Bsz, L, _ = x.shape
    d_in, nh, hp, N, conv_dim = _dims(cfg)
    proj = x @ params["w_in"]
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc_pre = jnp.concatenate([xc, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv(params, xbc_pre, cfg))
    xc, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xc.reshape(Bsz, L, nh, hp)
    # pad L to a chunk multiple; dt=0 padding is exact (decay 1, no input)
    chunk = min(cfg.ssm_chunk, L)
    pad = (-L) % chunk
    if pad:
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    else:
        xh_p, dt_p, Bm_p, Cm_p = xh, dt, Bm, Cm
    out = ssd_ops.ssd_chunked(xh_p, dt_p, A, Bm_p, Cm_p, chunk=chunk,
                              impl=ssd_impl, return_final_state=return_cache)
    if return_cache:
        y, state = out
    else:
        y = out
    if pad:
        y = y[:, :L]
    y = (y + xh * params["D"][None, None, :, None]).astype(x.dtype)
    y = y.reshape(Bsz, L, d_in)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = y @ params["w_out"]
    if return_cache:
        cache = {"conv": xbc_pre[:, -(cfg.conv_width - 1):], "state": state}
        return y, cache
    return y


def mamba_init_cache(cfg, batch: int, dtype):
    d_in, nh, hp, N, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, hp, N), jnp.float32),
    }


def mamba_decode(params, cfg, x, cache):
    """One-token decode. x: (B, 1, d)."""
    Bsz = x.shape[0]
    d_in, nh, hp, N, conv_dim = _dims(cfg)
    proj = x[:, 0] @ params["w_in"]
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xc, Bm, Cm], axis=-1)   # (B, conv_dim)
    # conv ring: full window = [cache, new]
    window = jnp.concatenate([cache["conv"],
                              xbc[:, None, :].astype(cache["conv"].dtype)],
                             axis=1)               # (B, W, conv_dim)
    # forward conv applies conv_w[lag] to x[t-lag]; window[i] holds lag
    # (W-1-i), so the kernel is flipped here to match (see _causal_conv)
    conv_out = (jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                           params["conv_w"][::-1].astype(jnp.float32))
                + params["conv_b"].astype(jnp.float32))
    xbc_act = jax.nn.silu(conv_out).astype(x.dtype)
    xc2, Bm2, Cm2 = jnp.split(xbc_act, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xc2.reshape(Bsz, nh, hp)
    y, state = ssd_ops.ssd_decode_step(cache["state"], xh, dt, A, Bm2, Cm2)
    y = (y + xh * params["D"][None, :, None]).astype(x.dtype)
    y = y.reshape(Bsz, d_in)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ params["w_out"])[:, None, :]
    return out, {"conv": window[:, 1:], "state": state}
