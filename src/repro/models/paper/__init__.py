from repro.models.paper.models import (Model, femnist_cnn, char_lstm,
                                       sent_lstm, rec_lr, rec_nn)
