"""The paper's experiment models (Appendix A.1), in pure JAX.

- FEMNIST CNN: two 5x5 conv layers (32, 64 ch) each + 2x2 maxpool, then a
  dense layer (2048 in the paper; configurable) and a 62-way softmax.
- Shakespeare: stacked 2-layer char-LSTM, 256 hidden, 8-d embedding.
- Sent140: 2-layer LSTM, 100 hidden, learned embeddings (the paper uses
  frozen 300-d GloVe; no pretrained vectors offline — noted in DESIGN.md).
- Recommendation: LR and one-hidden-layer NN (64 units), paper §4.3.

Each factory returns a `Model(init, apply)`; apply(params, x) -> logits.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Rng, dense_init, embed_init


class Model(NamedTuple):
    init: Callable          # (key) -> params
    apply: Callable         # (params, x) -> logits
    name: str


# ------------------------------------------------------------------ CNN

def femnist_cnn(num_classes: int = 62, image_size: int = 28,
                hidden: int = 256, dtype=jnp.float32) -> Model:
    """Paper's CNN (hidden=2048 in the paper; default reduced for the
    CPU-scale repro — benchmarks can pass hidden=2048)."""

    def conv(x, w, b):
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y + b

    def maxpool(x):
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    feat_hw = image_size // 4

    def init(key):
        rng = Rng(key)
        def conv_w(kh, kw, cin, cout):
            fan = kh * kw * cin
            return (jax.random.truncated_normal(
                rng.next(), -2, 2, (kh, kw, cin, cout), jnp.float32)
                / np.sqrt(fan)).astype(dtype)
        return {
            "c1": {"w": conv_w(5, 5, 1, 32), "b": jnp.zeros((32,), dtype)},
            "c2": {"w": conv_w(5, 5, 32, 64), "b": jnp.zeros((64,), dtype)},
            "fc1": {"w": dense_init(rng, feat_hw * feat_hw * 64, hidden, dtype),
                    "b": jnp.zeros((hidden,), dtype)},
            "out": {"w": dense_init(rng, hidden, num_classes, dtype),
                    "b": jnp.zeros((num_classes,), dtype)},
        }

    def apply(params, x):
        if x.ndim == 3:
            x = x[..., None]                      # (B, H, W, 1)
        x = maxpool(jax.nn.relu(conv(x, params["c1"]["w"], params["c1"]["b"])))
        x = maxpool(jax.nn.relu(conv(x, params["c2"]["w"], params["c2"]["b"])))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return Model(init, apply, "femnist_cnn")


# ----------------------------------------------------------------- LSTM

def _lstm_layer_init(rng: Rng, d_in: int, hidden: int, dtype):
    return {"w": dense_init(rng, d_in + hidden, 4 * hidden, dtype),
            "b": jnp.zeros((4 * hidden,), dtype)}


def _lstm_layer(params, xs, hidden: int):
    """xs: (B, L, d_in) -> (B, L, hidden)."""
    B = xs.shape[0]

    def step(carry, x_t):
        h, c = carry
        z = jnp.concatenate([x_t, h], axis=-1) @ params["w"] + params["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((B, hidden), xs.dtype)
    (_, _), hs = jax.lax.scan(step, (h0, h0), jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1)


def _stacked_lstm(vocab: int, embed_dim: int, hidden: int, num_layers: int,
                  num_classes: int, dtype, name: str) -> Model:
    def init(key):
        rng = Rng(key)
        p = {"embed": embed_init(rng, vocab, embed_dim, dtype)}
        d_in = embed_dim
        for l in range(num_layers):
            p[f"lstm{l}"] = _lstm_layer_init(rng, d_in, hidden, dtype)
            d_in = hidden
        p["out"] = {"w": dense_init(rng, hidden, num_classes, dtype),
                    "b": jnp.zeros((num_classes,), dtype)}
        return p

    def apply(params, x):
        h = jnp.take(params["embed"], x, axis=0)   # (B, L, e)
        for l in range(num_layers):
            h = _lstm_layer(params[f"lstm{l}"], h, hidden)
        return h[:, -1] @ params["out"]["w"] + params["out"]["b"]

    return Model(init, apply, name)


def char_lstm(vocab: int = 70, num_classes: int | None = None,
              hidden: int = 256, embed_dim: int = 8,
              dtype=jnp.float32) -> Model:
    return _stacked_lstm(vocab, embed_dim, hidden, 2,
                         num_classes or vocab, dtype, "char_lstm")


def sent_lstm(vocab: int = 2000, hidden: int = 100, embed_dim: int = 64,
              dtype=jnp.float32) -> Model:
    return _stacked_lstm(vocab, embed_dim, hidden, 2, 2, dtype, "sent_lstm")


# -------------------------------------------------------------- rec task

def rec_lr(feat_dim: int, num_classes: int, dtype=jnp.float32) -> Model:
    def init(key):
        rng = Rng(key)
        return {"w": dense_init(rng, feat_dim, num_classes, dtype),
                "b": jnp.zeros((num_classes,), dtype)}

    def apply(params, x):
        return x @ params["w"] + params["b"]

    return Model(init, apply, "rec_lr")


def rec_nn(feat_dim: int, num_classes: int, hidden: int = 64,
           dtype=jnp.float32) -> Model:
    def init(key):
        rng = Rng(key)
        return {"w1": dense_init(rng, feat_dim, hidden, dtype),
                "b1": jnp.zeros((hidden,), dtype),
                "w2": dense_init(rng, hidden, num_classes, dtype),
                "b2": jnp.zeros((num_classes,), dtype)}

    def apply(params, x):
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    return Model(init, apply, "rec_nn")
