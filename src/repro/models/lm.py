"""Causal LM assembly: embeddings -> (lead blocks; scanned layer stacks)
-> final norm -> logits. Supports every assigned architecture family:

- homogeneous dense/MoE/SSM stacks: one scanned stack (fast compile),
- hybrid (jamba): scan over repetitions of the layer *pattern period*,
- first_k_dense (deepseek-v2): leading layers unrolled,
- encoder-decoder (seamless): bidirectional encoder over modality frames
  + decoder with cross-attention,
- modality stubs (vlm/audio): precomputed embeddings enter through
  `mod_proj` (the one sanctioned stub — no ViT/conformer here),
- M-RoPE position synthesis for vlm prefix+text layout.

Entry points:
  init_lm            parameter init
  lm_apply           training / prefill forward (optionally emits cache)
  init_decode_cache  decode cache pytree
  lm_decode_step     one-token decode against the cache
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.blocks import (block_decode, block_forward, block_init,
                                 block_init_cache, block_prefill, layer_spec)
from repro.models.layers import (Rng, dense_init, embed_init, rmsnorm,
                                 rmsnorm_init, text_mrope_positions)


# ---------------------------------------------------------------- grouping

def layer_groups(cfg):
    """-> (lead_specs, period_specs, n_reps): lead layers are unrolled,
    the rest is a scanned stack of `n_reps` repetitions of the period."""
    specs = [layer_spec(cfg, i) for i in range(cfg.num_layers)]
    lead = specs[:cfg.first_k_dense]
    rest = specs[cfg.first_k_dense:]
    P = len(cfg.layer_pattern)
    if cfg.num_experts > 0:
        P = math.lcm(P, cfg.moe_layer_period)
    assert len(rest) % P == 0, (cfg.name, len(rest), P)
    for i, s in enumerate(rest):
        assert s == rest[i % P], f"{cfg.name}: aperiodic layer stack"
    return lead, rest[:P], len(rest) // P


# ---------------------------------------------------------------- init

def init_lm(key, cfg):
    rng = Rng(key)
    dtype = jnp.dtype(cfg.dtype)
    d, vocab = cfg.d_model, cfg.vocab_size
    params = {"embed": embed_init(rng, vocab, d, dtype)}
    lead, period, n_reps = layer_groups(cfg)
    for i, spec in enumerate(lead):
        params[f"lead_{i}"] = block_init(rng, cfg, spec, dtype)
    stack = {}
    for j, spec in enumerate(period):
        reps = [block_init(rng, cfg, spec, dtype,
                           cross=cfg.is_encoder_decoder)
                for _ in range(n_reps)]
        stack[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    params["stack"] = stack
    params["final_norm"] = rmsnorm_init(d, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(rng, d, vocab, dtype)
    if cfg.modality is not None:
        params["mod_proj"] = dense_init(rng, d, d, dtype)
    if cfg.is_encoder_decoder:
        enc_spec = ("attn", "mlp")
        reps = [block_init(rng, cfg, enc_spec, dtype)
                for _ in range(cfg.num_encoder_layers)]
        params["encoder"] = {
            "stack": jax.tree.map(lambda *xs: jnp.stack(xs), *reps),
            "final_norm": rmsnorm_init(d, dtype),
        }
    return params


# ---------------------------------------------------------------- positions

def _positions(cfg, n_mod: int, L_text: int, batch: int):
    """Position ids for the [modality prefix | text] layout."""
    if cfg.mrope:
        grid = max(1, int(math.ceil(math.sqrt(max(n_mod, 1)))))
        if n_mod > 0:
            idx = jnp.arange(n_mod)
            ppos = jnp.stack([jnp.zeros_like(idx), idx // grid, idx % grid],
                             axis=-1)
        else:
            ppos = jnp.zeros((0, 3), jnp.int32)
        t = jnp.arange(L_text) + grid
        tpos = jnp.stack([t, t, t], axis=-1)
        pos = jnp.concatenate([ppos, tpos], axis=0).astype(jnp.int32)
        return jnp.broadcast_to(pos, (batch,) + pos.shape)
    pos = jnp.arange(n_mod + L_text, dtype=jnp.int32)
    return jnp.broadcast_to(pos, (batch, n_mod + L_text))


# ---------------------------------------------------------------- forward

def _run_encoder(params, cfg, frames):
    """Bidirectional encoder over modality frame embeddings."""
    x = frames @ params["mod_proj"]
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    enc_spec = ("attn", "mlp")

    def body(carry, rep_params):
        h, _ = block_forward(rep_params, cfg, enc_spec, carry, pos,
                             causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["stack"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _maybe_shard_seq(cfg, h):
    """Megatron-style sequence sharding of the residual stream at block
    boundaries (perf lever; EXPERIMENTS.md §Perf): with remat, the stored
    per-layer activation shrinks by the model-axis size, at the cost of
    an all-gather before each block's attention."""
    if not cfg.shard_seq:
        return h
    from repro.sharding.context import get_mesh
    mesh = get_mesh()
    if mesh is None:
        return h
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = ([None] * h.ndim)
    axes[0] = ("pod", "data") if "pod" in mesh.axis_names else "data"
    axes[1] = "model"
    return jax.lax.with_sharding_constraint(
        h, NamedSharding(mesh, P(*axes)))


def _logits(params, cfg, x):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x @ head).astype(jnp.float32)


def lm_apply(params, cfg, tokens, *, modality_embeds=None, remat: bool = True,
             collect_cache: bool = False, cache_capacity: int | None = None,
             logits_mode: str = "all", unroll_layers: bool = False):
    """Training / prefill forward.

    tokens: (B, L_text) int32. modality_embeds: (B, n_mod, d_model) for
    vlm/audio archs (the stub frontend's output). Returns
    (logits, aux_loss[, cache]). For vlm, logits cover the full
    [prefix|text] sequence; the caller slices text positions for loss.
    """
    B, L_text = tokens.shape
    lead, period, n_reps = layer_groups(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    enc_out = None
    n_mod = 0
    if cfg.is_encoder_decoder:
        assert modality_embeds is not None
        enc_out = _run_encoder(params, cfg, modality_embeds)
    elif cfg.modality is not None:
        assert modality_embeds is not None
        n_mod = modality_embeds.shape[1]
        x = jnp.concatenate(
            [modality_embeds.astype(x.dtype) @ params["mod_proj"], x], axis=1)
    positions = _positions(cfg, n_mod, L_text, B)
    aux = jnp.zeros((), jnp.float32)
    L_total = n_mod + L_text
    capacity = cache_capacity or L_total

    caches = {}
    for i, spec in enumerate(lead):
        if collect_cache:
            x, a, caches[f"lead_{i}"] = block_prefill(
                params[f"lead_{i}"], cfg, spec, x, positions, capacity,
                enc_out=enc_out)
        else:
            x, a = block_forward(params[f"lead_{i}"], cfg, spec, x, positions,
                                 enc_out=enc_out)
        aux = aux + a

    if collect_cache:
        def body(carry, rep_params):
            h, acc = carry
            rep_caches = {}
            for j, spec in enumerate(period):
                h, a, rep_caches[f"pos{j}"] = block_prefill(
                    rep_params[f"pos{j}"], cfg, spec, h, positions, capacity,
                    enc_out=enc_out)
                acc = acc + a
            return (h, acc), rep_caches

        if unroll_layers:
            outs = []
            for r in range(n_reps):
                rep = jax.tree.map(lambda p: p[r], params["stack"])
                (x, aux), rc = body((x, aux), rep)
                outs.append(rc)
            stack_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            (x, aux), stack_caches = jax.lax.scan(body, (x, aux),
                                                  params["stack"])
        caches["stack"] = stack_caches
    else:
        def body(carry, rep_params):
            h, acc = carry
            for j, spec in enumerate(period):
                h, a = block_forward(rep_params[f"pos{j}"], cfg, spec, h,
                                     positions, enc_out=enc_out)
                acc = acc + a
            h = _maybe_shard_seq(cfg, h)
            return (h, acc), None

        if remat:
            body = jax.checkpoint(body)
        if unroll_layers:
            # scan-free variant for HLO cost probes (see benchmarks/roofline)
            for r in range(n_reps):
                rep = jax.tree.map(lambda p: p[r], params["stack"])
                (x, aux), _ = body((x, aux), rep)
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["stack"])

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_mode == "last":
        x = x[:, -1:]          # serving prefill: next-token logits only
    logits = _logits(params, cfg, x)
    if collect_cache:
        caches["length"] = jnp.asarray(L_total, jnp.int32)
        if enc_out is not None:
            caches["enc_out"] = enc_out
        return logits, aux, caches
    return logits, aux


# ---------------------------------------------------------------- decode

def init_decode_cache(cfg, batch: int, capacity: int, dtype=None,
                      enc_out=None, *, full: bool = True):
    """Decode cache pytree sized for `capacity` cached tokens. With
    full=True the cache is marked as already holding `capacity` tokens
    (steady-state decode, as in the assigned decode shapes)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    lead, period, n_reps = layer_groups(cfg)
    caches = {}
    for i, spec in enumerate(lead):
        caches[f"lead_{i}"] = block_init_cache(cfg, spec, batch, capacity,
                                               dtype)
    stack = {}
    for j, spec in enumerate(period):
        one = block_init_cache(cfg, spec, batch, capacity, dtype)
        stack[f"pos{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_reps,) + x.shape), one)
    caches["stack"] = stack
    caches["length"] = jnp.asarray(capacity if full else 0, jnp.int32)
    if enc_out is not None:
        caches["enc_out"] = enc_out
    return caches


def lm_decode_step(params, cfg, tokens, cache, *, unroll_layers: bool = False):
    """One-token decode. tokens: (B, 1) int32. Returns (logits, cache)."""
    lead, period, n_reps = layer_groups(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    length = cache["length"]
    enc_out = cache.get("enc_out")
    new_cache = {"length": length + 1}
    if enc_out is not None:
        new_cache["enc_out"] = enc_out

    for i, spec in enumerate(lead):
        x, new_cache[f"lead_{i}"] = block_decode(
            params[f"lead_{i}"], cfg, spec, x, cache[f"lead_{i}"], length,
            enc_out=enc_out)

    def body(h, inp):
        rep_params, rep_caches = inp
        out_caches = {}
        for j, spec in enumerate(period):
            h, out_caches[f"pos{j}"] = block_decode(
                rep_params[f"pos{j}"], cfg, spec, h, rep_caches[f"pos{j}"],
                length, enc_out=enc_out)
        return h, out_caches

    if unroll_layers:
        outs = []
        for r in range(n_reps):
            rep = jax.tree.map(lambda p: p[r],
                               (params["stack"], cache["stack"]))
            x, oc = body(x, rep)
            outs.append(oc)
        stack_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, stack_caches = jax.lax.scan(body, x,
                                       (params["stack"], cache["stack"]))
    new_cache["stack"] = stack_caches
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), new_cache
