"""Shared model primitives: init helpers, norms, MLP variants, rotary
embeddings (incl. M-RoPE). Functional style: params are nested dicts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class Rng:
    """Splitting helper so init code doesn't thread keys manually."""

    def __init__(self, key):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._key = key

    def next(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def dense_init(rng: Rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-ish), stored in `dtype`."""
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    w = jax.random.truncated_normal(rng.next(), -2.0, 2.0, (d_in, d_out),
                                    jnp.float32) * scale
    return w.astype(dtype)


def embed_init(rng: Rng, vocab: int, d: int, dtype):
    w = jax.random.normal(rng.next(), (vocab, d), jnp.float32) * 0.02
    return w.astype(dtype)


# ---------------------------------------------------------------- norms

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- MLPs

def mlp_init(rng: Rng, d: int, d_ff: int, act: str, dtype):
    p = {"w_down": dense_init(rng, d_ff, d, dtype)}
    if act == "swiglu":
        p["w_gate"] = dense_init(rng, d, d_ff, dtype)
        p["w_up"] = dense_init(rng, d, d_ff, dtype)
    else:
        p["w_up"] = dense_init(rng, d, d_ff, dtype)
    return p


def mlp_apply(params, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    elif act == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(f"unknown act {act}")
    return h @ params["w_down"]


# ---------------------------------------------------------------- rotary

def rope_frequencies(head_dim: int, theta: float):
    """Inverse frequencies for half the head dim."""
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float):
    """Standard RoPE. x: (..., L, H, hd); positions: (..., L) int32."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(hd, theta))          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., L, hd/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., L, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """M-RoPE (Qwen2-VL): rotary dims split into (t, h, w) sections.

    x: (..., L, H, hd); positions3: (..., L, 3) int32; sections sum to hd/2.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    inv = jnp.asarray(rope_frequencies(hd, theta))          # (half,)
    # pick which position component drives each rotary dim
    comp = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.asarray(comp)[None, :].astype(jnp.int32) *
        jnp.ones(positions3.shape[:-1] + (half,), jnp.int32),
        axis=-1)                                            # (..., L, half)
    ang = pos * inv
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(positions):
    """Text tokens use identical (t,h,w) components."""
    return jnp.stack([positions, positions, positions], axis=-1)
