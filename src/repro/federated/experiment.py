"""Scenario plane: FedMeta vs FedAvg on any registered workload, under
identical conditions.

The paper's headline claims (Fig. 3 / §4, Table 3 / §4.3) are
*comparisons*: FedMeta reaches a target accuracy with 2.82–4.33× less
communication than FedAvg, with higher final accuracy — and on the
production recommendation workload a small per-client local-head model
beats FedAvg's global-service classifier on both accuracy and bytes. A
comparison is only meaningful when every method runs under the same
client split, the same per-round client sampling stream, and honest
per-method communication accounting — the evaluation discipline urged by
Li et al. (2019). This module is the one place that enforces those
invariants:

  * one `FederatedDataset`, one `split_clients(seed)` call, shared by
    every method; scenarios may expose a per-method *view* of it (e.g.
    the recommend scenario's local-label view for FedMeta) but views
    preserve client order and sizes, so sampling streams stay identical;
  * every trainer consumes an identical task-sampling stream: one
    `sample_task_batch` per round from a `RandomState(seed)` that both
    `FederatedTrainer` and `FedAvgTrainer` advance with the exact same
    call pattern (FedAvg's local minibatch indices come from a separate
    stream), so round r samples the same clients for every method;
  * per-round history (train loss, eval accuracy, cumulative
    upload/download bytes, client GFLOPs) recorded by the trainers
    themselves at full round resolution — with per-METHOD θ sizes, so a
    method shipping a smaller model pays fewer bytes per round
    (`CommTracker.phi_MB`, the paper's §4.3 size argument);
  * the paper's comm-to-target-accuracy metric (`comm_to_target`)
    computed from those histories against one shared target;
  * per-method fairness accounting (`fairness_stats`): the distribution
    of per-client accuracies at final eval — deciles, variance, and the
    worst-10% mean — following the federated-fairness lens of Li et
    al.'s survey.

`run_comparison(plan)` is the entry point; it emits a JSON artifact
under ``results/experiments/`` with the full curves, the comm-to-target
table, and the fairness blocks (schema documented field-by-field in
DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional, Sequence

import jax

from repro.analysis.sanitizers import assert_no_tracers, sanitizers_enabled
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.faults import FaultConfig
from repro.federated.population import UnreliabilityConfig
from repro.federated.privacy import DPConfig
from repro.kernels.meta_update.compress import CompressionConfig
from repro.federated.server import (FederatedTrainer, evaluate_global,
                                    evaluate_meta)

FEDMETA_METHODS = ("maml", "fomaml", "meta-sgd", "reptile")
FEDAVG_METHODS = ("fedavg", "fedavg(meta)")
DEFAULT_METHODS = FEDAVG_METHODS + ("maml", "fomaml", "meta-sgd")


def _femnist_data(num_clients, seed, **lazy_kw):
    from repro.data import make_femnist
    return make_femnist(num_clients=num_clients, mean_samples=60, seed=seed, **lazy_kw)


def _femnist_model():
    from repro.models.paper import femnist_cnn
    return femnist_cnn(num_classes=62, hidden=128)


def _sent140_data(num_clients, seed, **lazy_kw):
    from repro.data import make_sent140
    return make_sent140(num_clients=num_clients, seed=seed, **lazy_kw)


def _sent140_model():
    from repro.models.paper import sent_lstm
    return sent_lstm(vocab=2000, hidden=32, embed_dim=16)


def _shakespeare_data(num_clients, seed, **lazy_kw):
    from repro.data import make_shakespeare
    return make_shakespeare(num_clients=num_clients, mean_samples=150,
                            seed=seed, **lazy_kw)


def _shakespeare_model():
    from repro.models.paper import char_lstm
    return char_lstm(vocab=70, hidden=64, embed_dim=8)


# ---- recommend scenario (paper §4.3 / Table 3) --------------------------
# Scaled constants of the synthetic production dataset: the paper has
# 2,400 services with 2–36 per client and a 40-way local head; we keep
# the 40-way head and the 2–36-per-client structure over a 120-service
# catalogue (data/synth_recommend.py).
REC_SERVICES, REC_CTX, REC_HEAD = 120, 24, 40
REC_FEAT = REC_CTX + REC_SERVICES


def _recommend_data(num_clients, seed, **lazy_kw):
    from repro.data import make_recommend
    return make_recommend(num_clients=num_clients, num_services=REC_SERVICES,
                          ctx_dim=REC_CTX, seed=seed, **lazy_kw)


def _recommend_model():
    """The GLOBAL-head recommender FedAvg must ship: one classifier over
    the whole service catalogue (the paper's 2420-way MIXED model)."""
    from repro.models.paper import rec_nn
    return rec_nn(REC_FEAT, REC_SERVICES)


def _recommend_meta_model(plan):
    """The LOCAL-head recommender FedMeta ships: same trunk, but a
    ``local_head``-way output over the client's own services — the θ-size
    asymmetry behind the paper's Table-3 bytes advantage."""
    from repro.models.paper import rec_nn
    return rec_nn(REC_FEAT, plan.local_head or REC_HEAD)


def _recommend_meta_data(clients, plan):
    from repro.data import localize_clients
    return localize_clients(clients, plan.local_head or REC_HEAD)


def _recommend_loss(model):
    from repro.core import classification_loss
    return classification_loss(model.apply, topk=(4,))   # Table 3: Top-1/Top-4


# ---- LM personalization scenario ----------------------------------------
# Per-client dialect corpora (data/lm_tasks.make_lm_clients) on a reduced
# assigned LM architecture — small vocab/seq so the path runs in CI.
LM_VOCAB, LM_SEQ = 64, 16


def _lm_data(num_clients, seed, **lazy_kw):
    from repro.data import make_lm_clients
    return make_lm_clients(num_clients=num_clients, seq_len=LM_SEQ,
                           vocab=LM_VOCAB, seed=seed, **lazy_kw)


def _lm_model():
    import dataclasses as dc

    from repro.configs import get_config, reduced_config
    from repro.launch.steps import make_apply_fn
    from repro.models import init_lm
    from repro.models.paper import Model
    cfg = dc.replace(reduced_config(get_config("smollm-360m")),
                     num_layers=2, d_model=64, num_heads=2, num_kv_heads=1,
                     head_dim=32, d_ff=128, vocab_size=LM_VOCAB,
                     dtype="float32")
    return Model(lambda key: init_lm(key, cfg), make_apply_fn(cfg),
                 f"lm-{cfg.name}")


def _lm_loss(model):
    from repro.core import lm_pair_loss
    return lm_pair_loss(model.apply)


# dataset name -> builders + paper-Table-4-shaped hyperparameters
# (CPU-scaled, same values as benchmarks/table2_leaf.py). Like the
# paper's Table 4, learning rates may be tuned per algorithm
# (method_overrides) — the sharing discipline is about data splits,
# sampling streams, and comm accounting, not about forcing one lr onto
# algorithms with different update geometries.
#
# Scenario extension points (all optional; DESIGN.md §13):
#   loss        loss(model) -> (loss_fn, eval_fn); default
#               classification_loss(model.apply)
#   meta_model  meta_model(plan) -> Model for the FedMeta methods (the
#               baselines keep `model`) — the recommend local head
#   meta_data   meta_data(clients, plan) -> clients view the FedMeta
#               methods train/eval on (order- and size-preserving)
#   support_frac / local_head   extra per-dataset plan defaults
DATASETS = {
    "femnist": dict(data=_femnist_data, model=_femnist_model,
                    inner_lr=0.01, outer_lr=1e-3, local_lr=1e-3,
                    clients_per_round=4, support_size=16, query_size=16,
                    num_clients=100,
                    # first-order MAML stagnates at inner_lr=0.01 on
                    # synthetic femnist; 0.05 converges (probed in PR 3)
                    method_overrides={"fomaml": {"inner_lr": 0.05}}),
    "sent140": dict(data=_sent140_data, model=_sent140_model,
                    inner_lr=0.01, outer_lr=1e-3, local_lr=1e-3,
                    clients_per_round=8, support_size=16, query_size=16,
                    num_clients=100),
    "shakespeare": dict(data=_shakespeare_data, model=_shakespeare_model,
                        inner_lr=0.1, outer_lr=1e-2, local_lr=1e-3,
                        clients_per_round=8, support_size=24, query_size=24,
                        num_clients=48),
    "recommend": dict(data=_recommend_data, model=_recommend_model,
                      loss=_recommend_loss, meta_model=_recommend_meta_model,
                      meta_data=_recommend_meta_data,
                      # the local head's label semantics are per-client
                      # (local id 0 = the client's first service), so
                      # META models lean on real local adaptation — the
                      # paper trains them with 100 local steps; 5 inner
                      # steps at lr 0.1 is the CPU-scaled analogue
                      # (probed: 1 step 0.11, 5 steps 0.24 test acc vs
                      # FedAvg 0.046)
                      inner_lr=0.1, inner_steps=5,
                      outer_lr=1e-3, local_lr=1e-3,
                      clients_per_round=8, support_size=32, query_size=16,
                      num_clients=120, support_frac=0.5,
                      local_head=REC_HEAD),
    "lm": dict(data=_lm_data, model=_lm_model, loss=_lm_loss,
               inner_lr=0.1, outer_lr=3e-3, local_lr=1e-2,
               clients_per_round=4, support_size=4, query_size=4,
               num_clients=32, support_frac=0.5),
}


@dataclasses.dataclass
class ExperimentPlan:
    """Everything needed to reproduce one FedMeta-vs-FedAvg comparison.

    ``pipeline`` selects the FedMeta execution substrate: "tree" (pytree
    φ), "packed" (flat parameter plane, PR 1) or "client_plane" (flat
    inner loop too, PR 2) — the baselines are substrate-independent.
    ``data_fn(num_clients, seed)`` / ``model_fn()`` / ``loss_builder
    (model)`` / ``meta_model_fn(plan)`` / ``meta_data_fn(clients, plan)``
    override the named registry for custom scenarios (callables are not
    serialized). ``local_head`` is the FedMeta head width for scenarios
    with a per-method model-size asymmetry (recommend: 40, the paper's
    §4.3 local classifier; None = no asymmetry).

    Example — the committed recommend artifact's plan::

        plan = default_plan("recommend", rounds=60, eval_every=2)
        out = run_comparison(plan, log=print)
        print(format_table(out))
    """
    dataset: str
    methods: Sequence[str] = DEFAULT_METHODS
    rounds: int = 100
    eval_every: int = 10
    num_clients: int = 100
    clients_per_round: int = 4
    support_frac: float = 0.2
    support_size: int = 16
    query_size: int = 16
    inner_lr: float = 0.01
    inner_steps: int = 1           # FedMeta inner-loop steps (adapt + train)
    outer_lr: float = 1e-3
    local_lr: float = 1e-3
    local_steps: int = 3
    target_acc: Optional[float] = None   # None = shared reachable target
    # a target counts as reached only when held for this many
    # consecutive evals — single-eval noise spikes must not set the
    # comm-to-target table (charged at the window's last round)
    sustain_evals: int = 2
    pipeline: str = "tree"               # tree | packed | client_plane
    client_chunk: Optional[int] = None
    # async round engine (DESIGN.md §12): staged round blocks ahead of
    # the device (0 = the synchronous loop) and the deferred-metrics
    # flush cadence. Bit-identity of the pipelined loop means the
    # comparison artifacts regenerate unchanged at any depth — the
    # depth-0 invariant is pinned by test_experiment_plane.
    prefetch_depth: int = 0
    flush_every: int = 1
    fuse_rounds: int = 1                 # lax.scan round blocks (packed)
    # failure plane (DESIGN.md §14): FedMeta (m, N) aggregation mode and
    # optional per-round client-failure injection. Applies to the
    # FedMeta methods only (the FedAvg baselines have no (m, N) gradient
    # plane); requires pipeline="packed"/"client_plane". The faults
    # config is a frozen dataclass and serializes into the artifact, so
    # a robustness sweep's JSON records its exact failure model.
    aggregator: str = "mean"             # mean|masked_mean|screen|trimmed
    screen_factor: float = 3.0
    trim: int = 1
    faults: Optional["FaultConfig"] = None
    # population plane (DESIGN.md §15): lazy client registry +
    # deadline/over-selection staging. ``lazy_population`` builds the
    # dataset as a bounded-memory `ClientRegistry` (sequential mode is
    # bit-identical to eager; ``independent_population=True`` switches
    # to O(1) per-client seeding for 10^5+ populations).
    # ``eval_clients_cap`` bounds the val/test cohorts — at population
    # scale "evaluate on all test clients" is neither feasible nor
    # meaningful. The unreliability/deadline/over-selection knobs apply
    # to the FedMeta methods only (like faults: they need the (m, N)
    # gradient plane).
    lazy_population: bool = False
    independent_population: bool = False
    cache_clients: Optional[int] = None
    eval_clients_cap: Optional[int] = None
    over_select: float = 0.0
    round_deadline: Optional[float] = None
    unreliability: Optional["UnreliabilityConfig"] = None
    pool_workers: int = 0
    # bytes-on-the-wire plane (DESIGN.md §17): upload compression +
    # central DP for the FedMeta methods (they need the (m, N) gradient
    # plane, like faults — pipeline="packed"/"client_plane" only; the
    # FedAvg baselines ship dense full models by construction).
    # ``block_dtype``/``opt_state_dtype`` are dtype NAMES ("bfloat16")
    # so plans stay JSON-serializable: the gradient-block wire dtype
    # and the fused-Adam m/v state dtype (None = float32 for both).
    compression: Optional["CompressionConfig"] = None
    dp: Optional["DPConfig"] = None
    block_dtype: Optional[str] = None
    opt_state_dtype: Optional[str] = None
    # FedMeta head width for local-head scenarios (DESIGN.md §13)
    local_head: Optional[int] = None
    # per-method lr/step overrides, paper-Table-4 style:
    # {"fomaml": {"inner_lr": 0.05}}
    method_overrides: dict = dataclasses.field(default_factory=dict)
    seed: int = 0
    name: str = ""
    data_fn: Optional[Callable] = None
    model_fn: Optional[Callable] = None
    loss_builder: Optional[Callable] = None
    meta_model_fn: Optional[Callable] = None
    meta_data_fn: Optional[Callable] = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        for fn in ("data_fn", "model_fn", "loss_builder", "meta_model_fn",
                   "meta_data_fn"):
            d.pop(fn)
        d["methods"] = list(self.methods)
        return d


def default_plan(dataset: str, **overrides) -> ExperimentPlan:
    """Plan with the registry hyperparameters for a named dataset.

    >>> plan = default_plan("recommend", rounds=8, eval_every=2)
    >>> plan.local_head, plan.clients_per_round
    (40, 8)
    """
    su = DATASETS[dataset]
    base = dict(clients_per_round=su["clients_per_round"],
                support_size=su["support_size"],
                query_size=su["query_size"], inner_lr=su["inner_lr"],
                outer_lr=su["outer_lr"], local_lr=su["local_lr"],
                num_clients=su["num_clients"],
                # copy: plans must not alias (and mutate) the registry
                method_overrides={k: dict(v) for k, v in
                                  su.get("method_overrides", {}).items()})
    for opt in ("support_frac", "local_head", "inner_steps"):
        if opt in su:
            base[opt] = su[opt]
    base.update(overrides)
    return ExperimentPlan(dataset=dataset, **base)


def make_trainer(plan: ExperimentPlan, method: str, loss_fn, eval_fn,
                 train_clients):
    """One trainer per method, all sharing plan-level sampling config.

    FedAvg methods get a `FedAvgTrainer` (full-model shipping), FedMeta
    methods a `FederatedTrainer` on the plan's pipeline; `method_overrides`
    apply per method. Example::

        tr = make_trainer(plan, "fomaml", loss_fn, eval_fn, train_clients)
        state = tr.init(jax.random.PRNGKey(0), model.init)
        state = tr.run(state, plan.rounds, eval_every=plan.eval_every,
                       eval_clients=val_clients)
    """
    common = dict(clients_per_round=plan.clients_per_round,
                  support_frac=plan.support_frac,
                  support_size=plan.support_size,
                  query_size=plan.query_size, seed=plan.seed,
                  prefetch_depth=plan.prefetch_depth,
                  flush_every=plan.flush_every)
    over = plan.method_overrides.get(method, {})
    if method in FEDAVG_METHODS:
        return FedAvgTrainer(
            loss_fn, eval_fn,
            local_lr=over.get("local_lr", plan.local_lr),
            local_steps=over.get("local_steps", plan.local_steps),
            train_clients=train_clients, client_chunk=plan.client_chunk,
            meta_eval=(method == "fedavg(meta)"), **common)
    from repro.core import make_algorithm
    from repro.optim import adam
    algo = make_algorithm(method, loss_fn, eval_fn,
                          inner_lr=over.get("inner_lr", plan.inner_lr),
                          inner_steps=over.get("inner_steps",
                                               plan.inner_steps))
    packed = plan.pipeline in ("packed", "client_plane")
    if (plan.faults is not None or plan.aggregator != "mean") and not packed:
        raise ValueError("plan.faults / plan.aggregator need the packed "
                         "pipeline — set pipeline='packed' or "
                         "'client_plane'")
    if (plan.compression is not None or plan.dp is not None
            or plan.block_dtype) and not packed:
        raise ValueError("plan.compression / plan.dp / plan.block_dtype "
                         "need the packed pipeline — set pipeline="
                         "'packed' or 'client_plane'")
    import jax.numpy as jnp
    opt_kw = {}
    if plan.opt_state_dtype:
        # quantized optimizer state (§17): fused Adam keeps m/v in this
        # dtype and dequantizes inside the kernel (the olmax trick)
        opt_kw["state_dtype"] = jnp.dtype(plan.opt_state_dtype)
    pop = {}
    if (plan.unreliability is not None or plan.over_select
            or plan.round_deadline is not None or plan.pool_workers):
        if (plan.unreliability is not None or plan.over_select
                or plan.round_deadline is not None) and not packed:
            raise ValueError("plan.unreliability / over_select / "
                             "round_deadline need the packed pipeline — "
                             "set pipeline='packed' or 'client_plane'")
        pop = dict(unreliability=plan.unreliability,
                   over_select=plan.over_select,
                   round_deadline=plan.round_deadline,
                   pool_workers=plan.pool_workers)
    return FederatedTrainer(
        algo, adam(over.get("outer_lr", plan.outer_lr), **opt_kw),
        train_clients,
        client_axis="chunked" if plan.client_chunk else "vmap",
        client_chunk=plan.client_chunk, packed=packed,
        client_plane=(plan.pipeline == "client_plane"),
        block_dtype=(jnp.dtype(plan.block_dtype)
                     if plan.block_dtype else None),
        compression=plan.compression, dp=plan.dp,
        fuse_rounds=plan.fuse_rounds if packed else 1,
        aggregator=plan.aggregator, screen_factor=plan.screen_factor,
        trim=plan.trim, faults=plan.faults, **pop, **common)


@dataclasses.dataclass
class _View:
    """One method family's view of the scenario: the client splits it
    trains/evals on plus the model and loss that go with them."""
    train: list
    val: list
    test: list
    model: object
    loss_fn: Callable
    eval_fn: Callable


def _build_views(plan: ExperimentPlan, su: dict):
    """-> (global_view, meta_view): identical unless the scenario defines
    a per-method asymmetry (meta_model / meta_data), in which case the
    FedMeta methods get their own model and client-data view while the
    baselines keep the global one. Views preserve client order and sizes,
    so both consume identical seeded sampling streams."""
    from repro.core import classification_loss
    data_fn = plan.data_fn or su["data"]
    model_fn = plan.model_fn or su["model"]
    loss_builder = plan.loss_builder or su.get("loss") or (
        lambda model: classification_loss(model.apply))
    lazy_kw = {}
    if plan.lazy_population:
        # registry datasets: the builders forward these to make_*; a
        # custom plan.data_fn must accept the same keywords
        lazy_kw = dict(lazy=True, independent=plan.independent_population,
                       cache_clients=plan.cache_clients)
    ds = data_fn(plan.num_clients, plan.seed, **lazy_kw)
    train, val, test = ds.split_clients(seed=plan.seed)
    model = model_fn()
    gview = _View(train, val, test, model, *loss_builder(model))

    meta_model_fn = plan.meta_model_fn or su.get("meta_model")
    meta_data_fn = plan.meta_data_fn or su.get("meta_data")
    if meta_model_fn is None and meta_data_fn is None:
        return gview, gview
    mmodel = meta_model_fn(plan) if meta_model_fn else model
    if meta_data_fn:
        # eager scenarios return lists; lazy ones a RegistryView — both
        # satisfy the Sequence contract, so neither is re-materialized
        mtrain, mval, mtest = (meta_data_fn(c, plan)
                               for c in (train, val, test))
    else:
        mtrain, mval, mtest = train, val, test
    return gview, _View(mtrain, mval, mtest, mmodel,
                        *loss_builder(mmodel))


def _cap_clients(clients, cap: Optional[int]):
    """Bound an eval cohort (population scale): both lists and
    `RegistryView`s slice to a prefix view without materializing."""
    return clients[:cap] if cap and len(clients) > cap else clients


def _eval_records(history: list) -> list:
    return [rec for rec in history if rec.get("eval_acc") is not None]


def comm_to_target(history: list, target_acc: float,
                   sustain: int = 1) -> Optional[dict]:
    """The paper's Fig.-3 metric: cumulative communication (and client
    compute) to reach ``target_acc`` on held-out clients.

    With ``sustain=k`` the target must hold on k consecutive evals and
    the cost is charged at the LAST round of the first such window — a
    single noisy eval spike cannot set the table. History records carry
    cumulative comm fields, so the result is monotone in the target: a
    higher target can only cost more bytes. Returns None when the
    target is never (sustainably) reached.

    >>> hist = [{"round": r, "eval_acc": 0.1 * r, "comm_MB": 2.0 * r,
    ...          "upload_MB": r, "download_MB": r, "client_GFLOPs": 0.0}
    ...         for r in (1, 2, 3)]
    >>> comm_to_target(hist, 0.2)["rounds"]
    2
    """
    evals = _eval_records(history)
    k = max(1, min(sustain, len(evals)))
    for i in range(len(evals) - k + 1):
        window = evals[i:i + k]
        if all(rec["eval_acc"] >= target_acc for rec in window):
            rec = window[-1]
            return {"rounds": rec["round"], "comm_MB": rec["comm_MB"],
                    "upload_MB": rec["upload_MB"],
                    "download_MB": rec["download_MB"],
                    "client_GFLOPs": rec["client_GFLOPs"],
                    "eval_acc": rec["eval_acc"]}
    return None


def fairness_stats(per_client) -> dict:
    """Accuracy-distribution (fairness) summary across clients, after
    Li et al.'s federated-learning survey: deciles, variance, and the
    mean over the worst-off 10% of clients. A method can buy mean
    accuracy by abandoning its tail; these fields make that visible in
    every comparison artifact.

    Pure function of the per-client accuracies, so committed artifacts
    can be re-derived exactly (test_scenario_plane pins this).

    >>> fairness_stats([1.0, 0.0])["worst10_mean"]
    0.0
    """
    import numpy as np
    a = np.sort(np.asarray(per_client, np.float64))
    k = max(1, int(np.ceil(0.1 * len(a))))
    return {
        "mean": float(a.mean()),
        "variance": float(a.var()),
        "deciles": [float(np.percentile(a, p)) for p in range(10, 100, 10)],
        "worst10_mean": float(a[:k].mean()),
        "num_clients": int(len(a)),
    }


def _sustained_best(history: list, sustain: int) -> Optional[float]:
    """Best accuracy the method HELD for ``sustain`` consecutive evals
    (the max over windows of the window min)."""
    evals = [rec["eval_acc"] for rec in _eval_records(history)]
    if not evals:
        return None
    k = max(1, min(sustain, len(evals)))
    return max(min(evals[i:i + k]) for i in range(len(evals) - k + 1))


def _shared_target(results: dict, sustain: int) -> Optional[float]:
    """Highest accuracy every method sustainably reached — the natural
    shared target when the plan does not pin one. Derived under the
    same sustain rule as `comm_to_target`, so every row of the table is
    finite and comparable by construction."""
    best = []
    for r in results.values():
        b = _sustained_best(r["history"], sustain)
        if b is None:
            return None
        best.append(b)
    return min(best) if best else None


def run_comparison(plan: ExperimentPlan, out_dir: str = "results/experiments",
                   log: Callable = None, save: bool = True) -> dict:
    """Run every plan method on the shared split/stream; return (and
    optionally write) the full comparison record.

    The record's schema is documented field-by-field in DESIGN.md §13;
    the JSON artifact lands at ``{out_dir}/{name or dataset}_compare.json``.
    Example::

        out = run_comparison(default_plan("sent140", rounds=60), log=print)
        print(format_table(out))              # comm-to-target table
        out["methods"]["maml"]["fairness"]    # per-client acc distribution
    """
    say = log or (lambda *a, **k: None)
    su = DATASETS.get(plan.dataset, {})
    gview, mview = _build_views(plan, su)

    results = {}
    for method in plan.methods:
        view = gview if method in FEDAVG_METHODS else mview
        val = _cap_clients(view.val, plan.eval_clients_cap)
        test = _cap_clients(view.test, plan.eval_clients_cap)
        tr = make_trainer(plan, method, view.loss_fn, view.eval_fn,
                          view.train)
        state = tr.init(jax.random.PRNGKey(plan.seed), view.model.init)
        tr.measure_flops(state)
        # perf_counter, not time.time: interval timing is the only
        # wall-clock this module is allowed (det-wallclock invariant)
        t0 = time.perf_counter()
        state = tr.run(state, plan.rounds, eval_every=plan.eval_every,
                       eval_clients=val)
        seconds = time.perf_counter() - t0
        # reuse the trainer's jitted evaluator — a fresh one would
        # recompile the whole adapt+eval graph for the test pass
        if method in FEDAVG_METHODS:
            test_acc, per_client, test_loss = evaluate_global(
                view.eval_fn, state["theta"], test,
                support_frac=plan.support_frac,
                support_size=plan.support_size, query_size=plan.query_size,
                seed=plan.seed, evaluator=tr.evaluator())
        else:
            test_acc, per_client, test_loss = evaluate_meta(
                tr.algo, tr.phi_tree(state), test,
                support_frac=plan.support_frac,
                support_size=plan.support_size, query_size=plan.query_size,
                seed=plan.seed, evaluator=tr.evaluator())
        results[method] = {
            "history": tr.history,
            "test_acc": test_acc, "test_loss": test_loss,
            "per_client": [float(a) for a in per_client],
            "fairness": fairness_stats(per_client),
            "comm": tr.comm.summary(), "seconds": seconds,
        }
        if sanitizers_enabled():
            # invariant plane (DESIGN.md §16): everything entering the
            # artifact must be host data — a tracer here means a jitted
            # step leaked an abstract value into history
            assert_no_tracers(results[method],
                              where=f"{plan.dataset}/{method} record")
        say(f"[{plan.dataset}] {method}: test_acc={test_acc:.4f} "
            f"comm_MB={tr.comm.summary()['comm_MB']:.2f} "
            f"phi_MB={tr.comm.summary()['phi_MB']:.4f} ({seconds:.0f}s)")

    target = plan.target_acc if plan.target_acc is not None \
        else _shared_target(results, plan.sustain_evals)
    table = {}
    if target is not None:
        table = {m: comm_to_target(r["history"], target,
                                   sustain=plan.sustain_evals)
                 for m, r in results.items()}
        base = table.get("fedavg")
        # FedAvg never (sustainably) reaching the target is itself the
        # paper's claim — reductions then use its FULL-RUN spend and are
        # lower bounds (it would need at least that much)
        if base is not None:
            base_mb, bound = base["comm_MB"], False
        elif "fedavg" in results:
            base_mb, bound = results["fedavg"]["comm"]["comm_MB"], True
        else:
            base_mb, bound = None, False
        for m, row in table.items():
            if row and base_mb and row["comm_MB"]:
                row["comm_reduction_vs_fedavg"] = round(
                    base_mb / row["comm_MB"], 2)
                if bound:
                    row["comm_reduction_is_lower_bound"] = True

    out = {"plan": plan.to_json(), "target_acc": target,
           "comm_to_target": table,
           "methods": {m: {k: v for k, v in r.items() if k != "per_client"}
                       for m, r in results.items()},
           "per_client": {m: r["per_client"] for m, r in results.items()}}
    if save:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{plan.name or plan.dataset}_compare.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        out["path"] = path
        say(f"[{plan.dataset}] wrote {path} (target_acc={target})")
    return out


def format_table(out: dict) -> str:
    """Human-readable comm-to-target table for one comparison record.

    >>> print(format_table(run_comparison(plan, save=False)))  # doctest: +SKIP
    target accuracy: 0.7
    method         rounds   comm_MB    up_MB  down_MB   GFLOPs test_acc vs_fedavg
    ...
    """
    lines = [f"target accuracy: {out['target_acc']}",
             f"{'method':<14} {'rounds':>6} {'comm_MB':>9} {'up_MB':>8} "
             f"{'down_MB':>8} {'GFLOPs':>8} {'test_acc':>8} {'vs_fedavg':>9}"]
    for m, res in out["methods"].items():
        row = (out.get("comm_to_target") or {}).get(m)
        if row:
            red = row.get("comm_reduction_vs_fedavg", "")
            if red and row.get("comm_reduction_is_lower_bound"):
                red = f">={red}"
            lines.append(
                f"{m:<14} {row['rounds']:>6} {row['comm_MB']:>9.2f} "
                f"{row['upload_MB']:>8.2f} {row['download_MB']:>8.2f} "
                f"{row['client_GFLOPs']:>8.2f} {res['test_acc']:>8.4f} "
                f"{red:>9}")
        else:
            lines.append(f"{m:<14} {'—':>6} {'—':>9} {'—':>8} {'—':>8} "
                         f"{'—':>8} {res['test_acc']:>8.4f} {'—':>9}")
    return "\n".join(lines)
