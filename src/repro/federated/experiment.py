"""Unified experiment plane: FedMeta vs FedAvg under identical conditions.

The paper's headline claim (Fig. 3 / §4) is a *comparison*: FedMeta
reaches a target accuracy with 2.82–4.33× less communication than FedAvg
and higher final accuracy. A comparison is only meaningful when every
method runs under the same client split, the same per-round client
sampling stream, and the same communication accounting — the evaluation
discipline urged by Li et al. (2019). This module is the one place that
enforces those invariants:

  * one `FederatedDataset`, one `split_clients(seed)` call, shared by
    every method;
  * every trainer consumes an identical task-sampling stream: one
    `sample_task_batch` per round from a `RandomState(seed)` that both
    `FederatedTrainer` and `FedAvgTrainer` advance with the exact same
    call pattern (FedAvg's local minibatch indices come from a separate
    stream), so round r samples the same clients for every method;
  * per-round history (train loss, eval accuracy, cumulative
    upload/download bytes, client GFLOPs) recorded by the trainers
    themselves at full round resolution;
  * the paper's comm-to-target-accuracy metric (`comm_to_target`)
    computed from those histories against one shared target.

`run_comparison(plan)` is the entry point; it emits a JSON artifact
under ``results/experiments/`` with the full curves and the
comm-to-target table (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional, Sequence

import jax

from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import (FederatedTrainer, evaluate_global,
                                    evaluate_meta)

FEDMETA_METHODS = ("maml", "fomaml", "meta-sgd", "reptile")
FEDAVG_METHODS = ("fedavg", "fedavg(meta)")
DEFAULT_METHODS = FEDAVG_METHODS + ("maml", "fomaml", "meta-sgd")


def _femnist_data(num_clients, seed):
    from repro.data import make_femnist
    return make_femnist(num_clients=num_clients, mean_samples=60, seed=seed)


def _femnist_model():
    from repro.models.paper import femnist_cnn
    return femnist_cnn(num_classes=62, hidden=128)


def _sent140_data(num_clients, seed):
    from repro.data import make_sent140
    return make_sent140(num_clients=num_clients, seed=seed)


def _sent140_model():
    from repro.models.paper import sent_lstm
    return sent_lstm(vocab=2000, hidden=32, embed_dim=16)


def _shakespeare_data(num_clients, seed):
    from repro.data import make_shakespeare
    return make_shakespeare(num_clients=num_clients, mean_samples=150,
                            seed=seed)


def _shakespeare_model():
    from repro.models.paper import char_lstm
    return char_lstm(vocab=70, hidden=64, embed_dim=8)


# dataset name -> builders + paper-Table-4-shaped hyperparameters
# (CPU-scaled, same values as benchmarks/table2_leaf.py). Like the
# paper's Table 4, learning rates may be tuned per algorithm
# (method_overrides) — the sharing discipline is about data splits,
# sampling streams, and comm accounting, not about forcing one lr onto
# algorithms with different update geometries.
DATASETS = {
    "femnist": dict(data=_femnist_data, model=_femnist_model,
                    inner_lr=0.01, outer_lr=1e-3, local_lr=1e-3,
                    clients_per_round=4, support_size=16, query_size=16,
                    num_clients=100,
                    # first-order MAML stagnates at inner_lr=0.01 on
                    # synthetic femnist; 0.05 converges (probed in PR 3)
                    method_overrides={"fomaml": {"inner_lr": 0.05}}),
    "sent140": dict(data=_sent140_data, model=_sent140_model,
                    inner_lr=0.01, outer_lr=1e-3, local_lr=1e-3,
                    clients_per_round=8, support_size=16, query_size=16,
                    num_clients=100),
    "shakespeare": dict(data=_shakespeare_data, model=_shakespeare_model,
                        inner_lr=0.1, outer_lr=1e-2, local_lr=1e-3,
                        clients_per_round=8, support_size=24, query_size=24,
                        num_clients=48),
}


@dataclasses.dataclass
class ExperimentPlan:
    """Everything needed to reproduce one FedMeta-vs-FedAvg comparison.

    ``pipeline`` selects the FedMeta execution substrate: "tree" (pytree
    φ), "packed" (flat parameter plane, PR 1) or "client_plane" (flat
    inner loop too, PR 2) — the baselines are substrate-independent.
    ``data_fn(num_clients, seed)`` / ``model_fn()`` override the named
    registry for custom scenarios (they are not serialized)."""
    dataset: str
    methods: Sequence[str] = DEFAULT_METHODS
    rounds: int = 100
    eval_every: int = 10
    num_clients: int = 100
    clients_per_round: int = 4
    support_frac: float = 0.2
    support_size: int = 16
    query_size: int = 16
    inner_lr: float = 0.01
    outer_lr: float = 1e-3
    local_lr: float = 1e-3
    local_steps: int = 3
    target_acc: Optional[float] = None   # None = shared reachable target
    # a target counts as reached only when held for this many
    # consecutive evals — single-eval noise spikes must not set the
    # comm-to-target table (charged at the window's last round)
    sustain_evals: int = 2
    pipeline: str = "tree"               # tree | packed | client_plane
    client_chunk: Optional[int] = None
    # async round engine (DESIGN.md §12): staged round blocks ahead of
    # the device (0 = the synchronous loop) and the deferred-metrics
    # flush cadence. Bit-identity of the pipelined loop means the
    # comparison artifacts regenerate unchanged at any depth — the
    # depth-0 invariant is pinned by test_experiment_plane.
    prefetch_depth: int = 0
    flush_every: int = 1
    fuse_rounds: int = 1                 # lax.scan round blocks (packed)
    # per-method lr/step overrides, paper-Table-4 style:
    # {"fomaml": {"inner_lr": 0.05}}
    method_overrides: dict = dataclasses.field(default_factory=dict)
    seed: int = 0
    name: str = ""
    data_fn: Optional[Callable] = None
    model_fn: Optional[Callable] = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("data_fn"), d.pop("model_fn")
        d["methods"] = list(self.methods)
        return d


def default_plan(dataset: str, **overrides) -> ExperimentPlan:
    """Plan with the registry hyperparameters for a named dataset."""
    su = DATASETS[dataset]
    base = dict(clients_per_round=su["clients_per_round"],
                support_size=su["support_size"],
                query_size=su["query_size"], inner_lr=su["inner_lr"],
                outer_lr=su["outer_lr"], local_lr=su["local_lr"],
                num_clients=su["num_clients"],
                # copy: plans must not alias (and mutate) the registry
                method_overrides={k: dict(v) for k, v in
                                  su.get("method_overrides", {}).items()})
    base.update(overrides)
    return ExperimentPlan(dataset=dataset, **base)


def make_trainer(plan: ExperimentPlan, method: str, loss_fn, eval_fn,
                 train_clients):
    """One trainer per method, all sharing plan-level sampling config."""
    common = dict(clients_per_round=plan.clients_per_round,
                  support_frac=plan.support_frac,
                  support_size=plan.support_size,
                  query_size=plan.query_size, seed=plan.seed,
                  prefetch_depth=plan.prefetch_depth,
                  flush_every=plan.flush_every)
    over = plan.method_overrides.get(method, {})
    if method in FEDAVG_METHODS:
        return FedAvgTrainer(
            loss_fn, eval_fn,
            local_lr=over.get("local_lr", plan.local_lr),
            local_steps=over.get("local_steps", plan.local_steps),
            train_clients=train_clients, client_chunk=plan.client_chunk,
            meta_eval=(method == "fedavg(meta)"), **common)
    from repro.core import make_algorithm
    from repro.optim import adam
    algo = make_algorithm(method, loss_fn, eval_fn,
                          inner_lr=over.get("inner_lr", plan.inner_lr),
                          inner_steps=over.get("inner_steps", 1))
    packed = plan.pipeline in ("packed", "client_plane")
    return FederatedTrainer(
        algo, adam(over.get("outer_lr", plan.outer_lr)), train_clients,
        client_axis="chunked" if plan.client_chunk else "vmap",
        client_chunk=plan.client_chunk, packed=packed,
        client_plane=(plan.pipeline == "client_plane"),
        fuse_rounds=plan.fuse_rounds if packed else 1, **common)


def _eval_records(history: list) -> list:
    return [rec for rec in history if rec.get("eval_acc") is not None]


def comm_to_target(history: list, target_acc: float,
                   sustain: int = 1) -> Optional[dict]:
    """The paper's Fig.-3 metric: cumulative communication (and client
    compute) to reach ``target_acc`` on held-out clients.

    With ``sustain=k`` the target must hold on k consecutive evals and
    the cost is charged at the LAST round of the first such window — a
    single noisy eval spike cannot set the table. History records carry
    cumulative comm fields, so the result is monotone in the target: a
    higher target can only cost more bytes. Returns None when the
    target is never (sustainably) reached."""
    evals = _eval_records(history)
    k = max(1, min(sustain, len(evals)))
    for i in range(len(evals) - k + 1):
        window = evals[i:i + k]
        if all(rec["eval_acc"] >= target_acc for rec in window):
            rec = window[-1]
            return {"rounds": rec["round"], "comm_MB": rec["comm_MB"],
                    "upload_MB": rec["upload_MB"],
                    "download_MB": rec["download_MB"],
                    "client_GFLOPs": rec["client_GFLOPs"],
                    "eval_acc": rec["eval_acc"]}
    return None


def _sustained_best(history: list, sustain: int) -> Optional[float]:
    """Best accuracy the method HELD for ``sustain`` consecutive evals
    (the max over windows of the window min)."""
    evals = [rec["eval_acc"] for rec in _eval_records(history)]
    if not evals:
        return None
    k = max(1, min(sustain, len(evals)))
    return max(min(evals[i:i + k]) for i in range(len(evals) - k + 1))


def _shared_target(results: dict, sustain: int) -> Optional[float]:
    """Highest accuracy every method sustainably reached — the natural
    shared target when the plan does not pin one. Derived under the
    same sustain rule as `comm_to_target`, so every row of the table is
    finite and comparable by construction."""
    best = []
    for r in results.values():
        b = _sustained_best(r["history"], sustain)
        if b is None:
            return None
        best.append(b)
    return min(best) if best else None


def run_comparison(plan: ExperimentPlan, out_dir: str = "results/experiments",
                   log: Callable = None, save: bool = True) -> dict:
    """Run every plan method on the shared split/stream; return (and
    optionally write) the full comparison record."""
    say = log or (lambda *a, **k: None)
    su = DATASETS.get(plan.dataset, {})
    data_fn = plan.data_fn or su["data"]
    model_fn = plan.model_fn or su["model"]
    ds = data_fn(plan.num_clients, plan.seed)
    train, val, test = ds.split_clients(seed=plan.seed)
    model = model_fn()
    from repro.core import classification_loss
    loss_fn, eval_fn = classification_loss(model.apply)

    results = {}
    for method in plan.methods:
        tr = make_trainer(plan, method, loss_fn, eval_fn, train)
        state = tr.init(jax.random.PRNGKey(plan.seed), model.init)
        tr.measure_flops(state)
        t0 = time.time()
        state = tr.run(state, plan.rounds, eval_every=plan.eval_every,
                       eval_clients=val)
        seconds = time.time() - t0
        # reuse the trainer's jitted evaluator — a fresh one would
        # recompile the whole adapt+eval graph for the test pass
        if method in FEDAVG_METHODS:
            test_acc, per_client, test_loss = evaluate_global(
                eval_fn, state["theta"], test, support_frac=plan.support_frac,
                support_size=plan.support_size, query_size=plan.query_size,
                seed=plan.seed, evaluator=tr.evaluator())
        else:
            test_acc, per_client, test_loss = evaluate_meta(
                tr.algo, tr.phi_tree(state), test,
                support_frac=plan.support_frac,
                support_size=plan.support_size, query_size=plan.query_size,
                seed=plan.seed, evaluator=tr.evaluator())
        results[method] = {
            "history": tr.history,
            "test_acc": test_acc, "test_loss": test_loss,
            "per_client": [float(a) for a in per_client],
            "comm": tr.comm.summary(), "seconds": seconds,
        }
        say(f"[{plan.dataset}] {method}: test_acc={test_acc:.4f} "
            f"comm_MB={tr.comm.summary()['comm_MB']:.2f} ({seconds:.0f}s)")

    target = plan.target_acc if plan.target_acc is not None \
        else _shared_target(results, plan.sustain_evals)
    table = {}
    if target is not None:
        table = {m: comm_to_target(r["history"], target,
                                   sustain=plan.sustain_evals)
                 for m, r in results.items()}
        base = table.get("fedavg")
        # FedAvg never (sustainably) reaching the target is itself the
        # paper's claim — reductions then use its FULL-RUN spend and are
        # lower bounds (it would need at least that much)
        if base is not None:
            base_mb, bound = base["comm_MB"], False
        elif "fedavg" in results:
            base_mb, bound = results["fedavg"]["comm"]["comm_MB"], True
        else:
            base_mb, bound = None, False
        for m, row in table.items():
            if row and base_mb and row["comm_MB"]:
                row["comm_reduction_vs_fedavg"] = round(
                    base_mb / row["comm_MB"], 2)
                if bound:
                    row["comm_reduction_is_lower_bound"] = True

    out = {"plan": plan.to_json(), "target_acc": target,
           "comm_to_target": table,
           "methods": {m: {k: v for k, v in r.items() if k != "per_client"}
                       for m, r in results.items()},
           "per_client": {m: r["per_client"] for m, r in results.items()}}
    if save:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{plan.name or plan.dataset}_compare.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        out["path"] = path
        say(f"[{plan.dataset}] wrote {path} (target_acc={target})")
    return out


def format_table(out: dict) -> str:
    """Human-readable comm-to-target table for one comparison record."""
    lines = [f"target accuracy: {out['target_acc']}",
             f"{'method':<14} {'rounds':>6} {'comm_MB':>9} {'up_MB':>8} "
             f"{'down_MB':>8} {'GFLOPs':>8} {'test_acc':>8} {'vs_fedavg':>9}"]
    for m, res in out["methods"].items():
        row = (out.get("comm_to_target") or {}).get(m)
        if row:
            red = row.get("comm_reduction_vs_fedavg", "")
            if red and row.get("comm_reduction_is_lower_bound"):
                red = f">={red}"
            lines.append(
                f"{m:<14} {row['rounds']:>6} {row['comm_MB']:>9.2f} "
                f"{row['upload_MB']:>8.2f} {row['download_MB']:>8.2f} "
                f"{row['client_GFLOPs']:>8.2f} {res['test_acc']:>8.4f} "
                f"{red:>9}")
        else:
            lines.append(f"{m:<14} {'—':>6} {'—':>9} {'—':>8} {'—':>8} "
                         f"{'—':>8} {res['test_acc']:>8.4f} {'—':>9}")
    return "\n".join(lines)
