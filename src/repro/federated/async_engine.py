"""Async round engine: the pipelined host↔device round loop.

The synchronous driver (PR 3) pays three host↔device stalls per round:
host-side numpy task sampling, blocking array transfers, and a
``float(v)`` metrics readback that forces the device to drain before
the next round can even be sampled. This engine removes all three while
keeping the *math* of the round loop untouched (DESIGN.md §12):

  * **prefetch** — a background thread owns the trainer's
    ``TaskStream`` (data/federated.py) and stages the next
    ``prefetch_depth`` rounds' batches onto the device with
    ``jax.device_put`` while the current round computes. The stream is
    advanced sequentially on that one thread, so the batch sequence —
    and therefore the whole run — is identical to the synchronous
    loop's under a fixed seed. ``prefetch_depth=0`` is the synchronous
    degenerate case: no thread, batches staged inline.
  * **deferred metrics** — per-round metrics stay unread ``jax.Array``s
    (comm counters stay host-side round indices) in a pending list and
    are drained to ``history`` every ``flush_every`` rounds and at
    ``run()`` exit. No per-round ``float()`` sync; the records that
    come out are bit-identical, just materialized later.
  * **fused-K** — with ``fuse_rounds=K > 1`` the driver hands the step
    K rounds' batches as one stacked ``(K, ...)`` buffer and the
    trainer runs them in a single ``lax.scan`` over rounds (packed
    pipeline only). Blocks are split so every eval round lands on a
    block boundary — evaluation needs φ on the host mid-stream.

Staleness-aware aggregation (``StalenessConfig``) is the engine-level
answer to straggler clients: a configured fraction of each round's
clients return their meta-gradient ``delay`` rounds late — computed
against the φ they were dispatched with — and the server aggregates
the arrived gradients with their weight discounted by ``discount**s``
(s = rounds of staleness). The discounted weighting runs through the
same fused packed aggregation kernel as the fresh path (DESIGN.md §3),
so the hot path stays flat. The actual step-level wiring lives in
``core/fedmeta.make_packed_meta_train_step``; this module owns the
config and the per-round straggler pick.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

PREFETCH_THREAD_NAME = "repro-round-prefetch"
WORKER_THREAD_NAME = "repro-pool-worker"


class PrefetchError(RuntimeError):
    """The prefetch producer failed permanently. Raised at the
    consumer's ``get()`` with ``__cause__`` chained to the producer's
    original exception, so the failing frame's traceback survives the
    thread hop."""


class WorkerPoolError(PrefetchError):
    """A worker-pool task failed permanently (retries exhausted, task
    timeout, or dead pool). Same semantics as `PrefetchError`: the
    message names the failing work, and for task failures ``__cause__``
    chains the worker-frame exception across the thread hop."""


def call_with_retry(fn, *, max_retries: int, backoff: float,
                    stop: Optional[threading.Event] = None):
    """Run ``fn()`` with bounded exponential-backoff retries — the one
    retry loop the prefetcher and the worker pool share (PR 6's
    retry-with-backoff semantics: ``backoff · 2^attempt`` seconds
    between attempts; ``fn`` must be retry-safe).

    Returns ``(None, result, attempts)`` on success,
    ``(exc, None, attempts)`` after exhaustion, or ``None`` if ``stop``
    was set before an attempt started."""
    for attempt in range(max(0, max_retries) + 1):
        if stop is not None and stop.is_set():
            return None
        try:
            return (None, fn(), attempt + 1)
        except BaseException as exc:
            if attempt >= max_retries:
                return (exc, None, attempt + 1)
            time.sleep(backoff * (2 ** attempt))
    return None  # unreachable


@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """Simulated straggler clients with discount-weighted aggregation.

    Each round, ``fraction`` of the sampled clients are stragglers: the
    meta-gradient they computed against the *current* φ arrives only
    ``delay`` rounds later, by which point φ has moved on — exactly the
    asynchronous-FL staleness semantics. On arrival a stale gradient's
    aggregation weight is its original data-count weight times
    ``discount ** s`` (weight × γ^s, s = its actual rounds of
    staleness), and the round's effective weights are renormalized over
    the rows actually aggregated. Fresh rows have s = 0 and keep their
    full weight. The straggler pick per round is seeded (``seed``) and
    independent of the task stream, so enabling staleness never perturbs
    task sampling.

    ``jitter=True`` models heterogeneous stragglers: instead of every
    straggler arriving exactly ``delay`` rounds late, each straggler
    independently draws a per-round seeded delay s ∈ [0, delay] (0 =
    arrives within the round, i.e. effectively fresh) and rejoins after
    s rounds at weight w·γ^s. ``jitter=False`` is bitwise-identical to
    the fixed-delay behavior — the fixed path's code is untouched and
    the rng draws the same values (tests pin this).

    >>> cfg = StalenessConfig(delay=2, fraction=0.25, jitter=True)
    >>> strag, fresh, delays = cfg.pick(4, np.random.RandomState(0))
    >>> delays.shape == strag.shape and (delays <= 2).all()
    True
    """
    delay: int = 1          # s_max: rounds between ModelTraining and arrival
    fraction: float = 0.25  # fraction of each round's clients that straggle
    discount: float = 0.5   # γ: an arrived gradient weighs w * γ^s
    jitter: bool = False    # per-straggler random delay in [0, delay]
    seed: int = 0

    def __post_init__(self):
        if self.delay < 1:
            raise ValueError("staleness delay must be >= 1")
        if not 0.0 <= self.fraction < 1.0:
            raise ValueError("straggler fraction must be in [0, 1)")

    def num_stragglers(self, m: int) -> int:
        """Static per-round straggler count (static shapes keep the step
        jitted once); at least one client always stays fresh."""
        return max(0, min(m - 1, int(round(self.fraction * m))))

    def pick(self, m: int, rng: np.random.RandomState):
        """One round's straggler pick — sorted int32 index arrays.

        Returns ``(straggler_idx, fresh_idx)``, plus a per-straggler
        ``delays`` array when ``jitter`` is on. With jitter off the rng
        consumes exactly the draws it always did (the off-path stays
        bitwise-identical)."""
        k = self.num_stragglers(m)
        perm = rng.permutation(m)
        sel = (np.sort(perm[:k]).astype(np.int32),
               np.sort(perm[k:]).astype(np.int32))
        if not self.jitter:
            return sel
        return sel + (rng.randint(0, self.delay + 1,
                                  size=k).astype(np.int32),)


class Prefetcher:
    """Bounded background producer of staged round inputs.

    ``produce(k)`` performs the host half of a round block — sampling
    from the task stream and ``jax.device_put``-staging the arrays —
    and is only ever called from this one thread, in block order, so
    seeded streams advance exactly as they would synchronously. The
    queue holds at most ``depth`` staged blocks (double-buffered device
    slots at depth 1). Example::

        pf = Prefetcher(stage, sizes=[1, 1, 1], depth=2)
        try:
            for _ in range(3):
                staged = pf.get()       # blocks until produced
        finally:
            pf.close()                  # joins the thread, always

    Failure on either side releases the other:

      * a producer exception is re-raised in the consumer at the
        ``get()`` for the failed block, with the producer-frame
        traceback intact (``max_retries > 0`` wraps it in a
        ``PrefetchError`` naming the failed rounds, chained via
        ``__cause__``);
      * ``close()`` (consumer exception or normal exit) sets the stop
        flag, drains the queue so a blocked ``put`` can observe it, and
        joins the thread — no leaked threads when a step raises;
      * a ``get()`` that would otherwise block forever on a dead
        producer (thread exited without staging the requested block)
        raises instead of deadlocking — the stored producer error if
        there is one, a ``PrefetchError`` otherwise.

    ``max_retries`` bounds transient-failure retries per block: the
    producer re-calls ``produce(k)`` up to that many extra times with
    exponential backoff (``retry_backoff · 2^attempt`` seconds) before
    giving up. ``produce`` must therefore be retry-safe: a failed call
    must leave its seeded streams where they started (the trainers
    snapshot/restore their RNGs around staging). ``first_round`` only
    labels error messages — the round numbering a resumed run is at.

    Lock-order contract (see `WorkerPool` for the full ordering): the
    producer↔consumer handoff itself rides the queue and the stop
    Event; ``self._lock`` guards exactly one plain attribute — the
    stored producer error — and is a *leaf* lock: both sides take it
    only around the ``_error`` read/write, never around ``put``/``get``
    or any other blocking call.
    """

    def __init__(self, produce: Callable, sizes, depth: int, *,
                 max_retries: int = 0, retry_backoff: float = 0.05,
                 first_round: int = 1):
        self._produce = produce
        self._sizes = list(sizes)
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._max_retries = max(0, max_retries)
        self._retry_backoff = retry_backoff
        self._first_round = first_round
        # leaf lock for _error: written on the producer thread, read on
        # the consumer thread after observing producer death — the
        # handoff is otherwise unsynchronized (thread-unguarded-write)
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, name=PREFETCH_THREAD_NAME, daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _wrap(self, exc, k, r, attempts):
        if self._max_retries == 0:
            return exc      # no retry facility: surface verbatim
        rounds = (f"round {r}" if k == 1 else
                  f"rounds {r}..{r + k - 1}")
        err = PrefetchError(
            f"prefetch producer failed staging {rounds} after "
            f"{attempts} attempt(s) (max_retries={self._max_retries} "
            f"exhausted): {type(exc).__name__}: {exc}")
        err.__cause__ = exc  # original traceback survives the hop
        return err

    def _produce_with_retry(self, k, r):
        out = call_with_retry(lambda: self._produce(k),
                              max_retries=self._max_retries,
                              backoff=self._retry_backoff,
                              stop=self._stop)
        if out is None:
            return None
        exc, item, attempts = out
        if exc is not None:
            return (self._wrap(exc, k, r, attempts), None)
        return (None, item)

    def _run(self):
        r = self._first_round
        try:
            for k in self._sizes:
                if self._stop.is_set():
                    return
                item = self._produce_with_retry(k, r)
                if item is None:
                    return
                if item[0] is not None:
                    with self._lock:
                        self._error = item[0]
                    self._put(item)
                    return
                if not self._put(item):
                    return
                r += k
        except BaseException as exc:  # pragma: no cover - safety net
            with self._lock:
                self._error = exc
            self._put((exc, None))

    def get(self):
        while True:
            try:
                exc, item = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if not self._thread.is_alive():
                    # the producer died without staging this block; the
                    # stored error (if any) beats a blind deadlock
                    with self._lock:
                        err = self._error
                    if err is not None:
                        raise err
                    raise PrefetchError(
                        "prefetch producer thread exited without "
                        "staging the requested block")
        if exc is not None:
            raise exc
        return item

    def close(self):
        self._stop.set()
        while True:  # unblock a producer waiting on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10.0)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()


class _PoolTask:
    """One queued unit of pool work.

    Publication protocol (audited, DESIGN.md §16): ``result`` and
    ``error`` are written by exactly one worker *before* ``done.set()``
    and read by the gather side only *after* ``done`` is observed set —
    the Event is the happens-before edge, so neither field needs a
    lock. ``started_at`` is the one deliberately racy field: the worker
    publishes it unsynchronized and the gather side polls it purely to
    arm the task-timeout clock; a stale read can only delay timeout
    detection by one 50 ms poll tick, never corrupt a result."""
    __slots__ = ("item", "result", "error", "started_at", "done")

    def __init__(self, item):
        self.item = item
        self.result = None
        self.error: Optional[BaseException] = None
        self.started_at: Optional[float] = None
        self.done = threading.Event()


class WorkerPool:
    """K persistent worker threads materializing client shards.

    The fault-tolerant generalization of the single prefetch producer
    (DESIGN.md §15): ``map(items)`` fans the items out to ``workers``
    threads running ``fn(item)`` — concurrent registry
    materialization — and blocks until every task completes, returning
    results in submission order. Each task gets the shared
    retry-with-backoff loop (`call_with_retry`, PR 6 semantics — ``fn``
    must be retry-safe), and the gather side enforces a per-task
    ``task_timeout`` measured from the moment a worker *starts* the
    task (queue wait does not count against it).

    Failure semantics mirror `PrefetchError`:

      * a task that exhausts its retries raises `WorkerPoolError` at
        ``map()`` naming the item and the caller's ``label`` (e.g. the
        round being staged), with the worker-frame exception chained
        via ``__cause__``;
      * a task exceeding ``task_timeout`` raises `WorkerPoolError`
        without waiting for the stuck worker;
      * ``map()`` on a pool whose workers have all died raises instead
        of deadlocking;
      * ``close()`` stops the workers, drains queued tasks (their
        waiters are released), and joins every thread — no leaked
        threads, whatever the consumer did.

    Example::

        pool = WorkerPool(lambda i: registry[i], workers=4,
                          max_retries=2)
        try:
            shards = pool.map([3, 17, 42], label="round 7")
        finally:
            pool.close()

    **Acquired-order contract** (the lock-ordering audit the
    ``thread-lock-order`` lint rule stubs; DESIGN.md §16). Three
    blocking primitives meet when the pool materializes registry
    shards: the gather side's per-task ``done`` Events, the registry's
    per-client in-flight Events, and ``ClientRegistry._lock``. The
    deadlock-free order is::

        gather (map): wait on task.done        — holding NO locks
        worker (fn):  registry.__getitem__
                        acquire _lock          — leaf: hash/cache ops
                                                 only, released before
                                                 ANY blocking call
                        wait on in-flight Event — lock NOT held
                        source.get(i)           — lock NOT held

    i.e. every Event wait is lock-free and the registry lock is a leaf
    acquired strictly *after* all Event-level blocking. The forbidden
    inversion — holding ``_lock`` while waiting on an in-flight Event
    or a pool gather — parks the only thread that could ``set()`` the
    Event behind the lock it needs, which is exactly the shape the
    lint rule flags.
    """

    def __init__(self, fn: Callable, workers: int = 2, *,
                 max_retries: int = 0, retry_backoff: float = 0.05,
                 task_timeout: Optional[float] = None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self._fn = fn
        self._max_retries = max(0, max_retries)
        self._retry_backoff = retry_backoff
        self._task_timeout = task_timeout
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._threads = [
            threading.Thread(target=self._work, daemon=True,
                             name=f"{WORKER_THREAD_NAME}-{i}")
            for i in range(workers)]
        for t in self._threads:
            t.start()

    def _work(self):
        while not self._stop.is_set():
            try:
                task = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            task.started_at = time.monotonic()
            out = call_with_retry(lambda: self._fn(task.item),
                                  max_retries=self._max_retries,
                                  backoff=self._retry_backoff,
                                  stop=self._stop)
            if out is None:      # stopped mid-retry
                task.error = WorkerPoolError("worker pool closed")
            elif out[0] is not None:
                task.error = out[0]
            else:
                task.result = out[1]
            task.done.set()

    def _fail(self, msg, cause=None) -> WorkerPoolError:
        err = WorkerPoolError(msg)
        if cause is not None:
            err.__cause__ = cause
        return err

    def map(self, items, label: str = "") -> list:
        """Materialize ``items`` concurrently; results in order."""
        tasks = [_PoolTask(it) for it in items]
        for t in tasks:
            self._q.put(t)
        where = f" for {label}" if label else ""
        out = []
        for t in tasks:
            while not t.done.wait(timeout=0.05):
                if self._task_timeout is not None and \
                        t.started_at is not None and \
                        time.monotonic() - t.started_at > \
                        self._task_timeout:
                    raise self._fail(
                        f"worker task {t.item!r}{where} exceeded the "
                        f"{self._task_timeout}s task timeout")
                if not any(th.is_alive() for th in self._threads):
                    raise self._fail(
                        f"worker pool died before task {t.item!r}"
                        f"{where} completed")
            if t.error is not None:
                raise self._fail(
                    f"worker pool failed materializing {t.item!r}"
                    f"{where} after {self._max_retries + 1} attempt(s) "
                    f"(max_retries={self._max_retries} exhausted): "
                    f"{type(t.error).__name__}: {t.error}", t.error)
            out.append(t.result)
        return out

    def close(self):
        self._stop.set()
        while True:              # release waiters of never-run tasks
            try:
                task = self._q.get_nowait()
            except queue.Empty:
                break
            task.error = WorkerPoolError("worker pool closed")
            task.done.set()
        for t in self._threads:
            t.join(timeout=10.0)

    @property
    def alive(self) -> bool:
        return any(t.is_alive() for t in self._threads)


def plan_blocks(rounds: int, eval_every: int, fuse: int,
                start: int = 0) -> list:
    """Round-block sizes covering rounds ``start + 1``..``rounds``: at
    most ``fuse`` rounds per block, and a block boundary at every eval
    round (and the final round) so evaluation always sees post-step φ
    on the host. ``start > 0`` is the resumed-run case: the plan picks
    up mid-schedule with the same absolute eval boundaries, so a
    resumed run's blocks are the uninterrupted plan's tail.

    >>> plan_blocks(10, 4, 3)   # eval rounds 4 and 8 end their blocks
    [3, 1, 3, 1, 2]
    >>> plan_blocks(10, 4, 3, start=4)
    [3, 1, 2]
    """
    fuse = max(1, fuse)
    if rounds <= start:
        return []
    bounds = {rounds}
    if eval_every:
        bounds.update(b for b in range(eval_every, rounds + 1, eval_every)
                      if b > start)
    blocks, r = [], start
    for b in sorted(bounds):
        seg = b - r
        while seg > 0:
            k = min(fuse, seg)
            blocks.append(k)
            seg -= k
        r = b
    return blocks


@dataclasses.dataclass
class AsyncRoundEngine:
    """The round driver shared by ``FederatedTrainer`` and
    ``FedAvgTrainer``. The trainer supplies the task-specific pieces;
    the engine owns pipelining, metric deferral and record cadence:

      stage(k)            host+device staging of the next k rounds'
                          inputs (called in stream order — on the
                          prefetch thread when ``prefetch_depth > 0``)
      step(state, staged) one jitted round; -> (state, metrics)
      fused_step          optional: (state, stacked-(k,...) staged) ->
                          (state, metrics with leading (k,) axis)
      comm                CommTracker (ticked per round by the engine)
      history             trainer's record list, appended at flush time
      checkpoint          optional (state, round) -> None hook, called
                          every ``checkpoint_every`` rounds at block
                          boundaries (after the pending metrics flush,
                          so a checkpointed history is never partial)
      prefetch_retries    bounded retry-with-backoff for transient
                          staging failures (Prefetcher max_retries)

    Example — a minimal pipelined driver (what both trainers' ``run``
    methods build)::

        engine = AsyncRoundEngine(stage=stage, step=step, comm=comm,
                                  history=history, prefetch_depth=2,
                                  flush_every=4)
        state = engine.run(state, rounds=100, eval_every=10,
                           evaluate=lambda st: {"eval_acc": ...})
    """
    stage: Callable
    step: Callable
    comm: object
    history: list
    fused_step: Optional[Callable] = None
    prefetch_depth: int = 0
    flush_every: int = 1
    fuse_rounds: int = 1
    checkpoint: Optional[Callable] = None
    checkpoint_every: int = 0
    prefetch_retries: int = 0

    def run(self, state, rounds: int, *, eval_every: int = 0,
            evaluate: Optional[Callable] = None, log: Callable = None,
            start_round: int = 0):
        fuse = self.fuse_rounds if self.fused_step is not None else 1
        blocks = plan_blocks(rounds, eval_every if evaluate else 0, fuse,
                             start=start_round)
        pending: list = []

        def flush():
            # the only host-device sync in the loop: float() on the
            # pending rounds' still-on-device metric arrays
            for n, metrics, comm_rounds, eval_fields in pending:
                rec = {"round": n,
                       **{k: float(v) for k, v in metrics.items()},
                       **self.comm.summary_at(comm_rounds)}
                if eval_fields:
                    rec.update(eval_fields)
                self.history.append(rec)
                if log:
                    log(rec)
            pending.clear()

        prefetch = None
        if self.prefetch_depth > 0:
            prefetch = Prefetcher(self.stage, blocks, self.prefetch_depth,
                                  max_retries=self.prefetch_retries,
                                  first_round=start_round + 1)
        r = start_round
        last_ckpt = start_round
        try:
            for bk in blocks:
                staged = prefetch.get() if prefetch else self.stage(bk)
                if bk == 1:
                    state, metrics = self.step(state, staged)
                    per_round = [metrics]
                else:
                    state, stacked = self.fused_step(state, staged)
                    per_round = [
                        jax.tree.map(lambda x, i=i: x[i], stacked)
                        for i in range(bk)]
                for metrics in per_round:
                    r += 1
                    self.comm.tick()
                    eval_fields = None
                    if evaluate and eval_every and \
                            (r % eval_every == 0 or r == rounds):
                        eval_fields = evaluate(state)
                    pending.append((r, metrics, self.comm.rounds,
                                    eval_fields))
                    # eval rounds already synced the device to read φ,
                    # so draining there is free
                    if eval_fields is not None or (
                            self.flush_every and
                            r % self.flush_every == 0):
                        flush()
                if (self.checkpoint is not None and self.checkpoint_every
                        and r - last_ckpt >= self.checkpoint_every):
                    # flush first: the payload captures history up to
                    # and including round r, never a pending tail
                    flush()
                    self.checkpoint(state, r)
                    last_ckpt = r
            return state
        finally:
            if prefetch is not None:
                prefetch.close()
            flush()
