"""Privacy mechanisms for the FedMeta upload path (beyond-paper; the
paper's §5 names privacy-preserving aggregation as its first future
direction).

Two composable mechanisms applied to the per-client meta-gradient g_u
before upload:

- **Clipped Gaussian DP** (DP-FedAvg style, adapted to meta-gradients):
  g_u <- g_u * min(1, S / ||g_u||) + N(0, σ²S²) applied at the server
  after aggregation-weighted mean (central DP; per-round ε via the
  standard Gaussian-mechanism accounting surface exposed here as
  noise_multiplier σ).

- **Secure-aggregation simulation** (Bonawitz et al. protocol shape):
  each pair of clients (u, v) in the round shares an antisymmetric mask
  M_uv = -M_vu derived from a pairwise seed; every client uploads
  g_u + Σ_v M_uv. Pairwise masks cancel in the sum, so the server
  recovers Σ_u g_u exactly while individual uploads are
  indistinguishable from noise. The simulation verifies the cancellation
  invariant (tests/test_privacy.py) — the paper's privacy argument
  ("only the algorithm is transmitted") strengthened to "only *masked*
  algorithm updates are transmitted".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_norm


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Central-DP knob for the packed pipeline (DESIGN.md §17).

    The fused aggregation applies the clip as a weight scale (clipping
    row u by c is identical to scaling its aggregation weight by c —
    the same identity the norm-screening aggregator uses), then adds
    N(0, σ²) to the aggregated meta-gradient with
    σ = noise_multiplier · clip_norm / m — exactly `dp_aggregate`'s
    accounting, pinned against it in tests. Noise keys derive from
    ``fold_in(PRNGKey(seed), round)`` — a pure function of the round
    index, so prefetched, fused and resumed runs replay identically
    with nothing extra in the checkpoint.

    Note on weighting: σ = z·S/m is the uniform-mean (weights = 1/m)
    Gaussian-mechanism accounting; with data-count weights the
    worst-case per-client sensitivity is max_u w_u·S. Runs targeting a
    formal ε should set ``weighted=False`` on the trainer.
    """
    clip_norm: float = 1.0
    noise_multiplier: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.clip_norm <= 0:
            raise ValueError(f"clip_norm must be > 0, got {self.clip_norm}")
        if self.noise_multiplier < 0:
            raise ValueError("noise_multiplier must be >= 0, got "
                             f"{self.noise_multiplier}")

    def sigma(self, num_clients: int) -> float:
        """σ_effective of the noise added to the aggregated mean."""
        return self.noise_multiplier * self.clip_norm / num_clients

    def round_key(self, round_: int):
        """The round's noise key (pure function of the round index)."""
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), round_)


def dp_clip_factors(row_norms, clip_norm: float):
    """(m,) per-row L2 norms -> (m,) clip factors min(1, S/‖g_u‖).

    Scaling aggregation weights by these factors IS the per-client clip
    (`clip_gradient`'s epsilon guard kept identical), so the clipped
    aggregate runs through the unmodified fused weighted kernel."""
    return jnp.minimum(1.0, clip_norm / (row_norms + 1e-12))


def clip_gradient(g, clip_norm: float):
    """Per-client L2 clip: g * min(1, S/||g||)."""
    norm = tree_norm(g)
    scale = jnp.minimum(1.0, clip_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale, g), norm


def add_gaussian_noise(g, key, noise_multiplier: float, clip_norm: float,
                       num_clients: int):
    """Central-DP Gaussian mechanism on the aggregated mean of clipped
    per-client gradients: σ_effective = noise_multiplier * S / m."""
    sigma = noise_multiplier * clip_norm / num_clients
    leaves, treedef = jax.tree.flatten(g)
    keys = jax.random.split(key, len(leaves))
    noised = [x + sigma * jax.random.normal(k, x.shape, jnp.float32)
              for x, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noised)


def dp_aggregate(client_grads, weights, key, *, clip_norm: float,
                 noise_multiplier: float):
    """client_grads: pytree with leading client axis m. Returns the
    DP-protected weighted mean."""
    m = jax.tree.leaves(client_grads)[0].shape[0]
    w = weights / jnp.sum(weights)

    def clip_one(i):
        g_i = jax.tree.map(lambda x: x[i], client_grads)
        return clip_gradient(g_i, clip_norm)[0]

    clipped = [clip_one(i) for i in range(m)]
    mean = jax.tree.map(
        lambda *xs: sum(w[i] * xs[i].astype(jnp.float32)
                        for i in range(m)), *clipped)
    return add_gaussian_noise(mean, key, noise_multiplier, clip_norm, m)


# ------------------------------------------------------- secure aggregation

def _pair_mask(key_uv, leaf):
    return jax.random.normal(key_uv, leaf.shape, jnp.float32)


def masked_uploads(client_grads, round_key):
    """Simulate the pairwise-mask protocol: returns per-client uploads
    g_u + Σ_{v>u} M_uv − Σ_{v<u} M_vu (masks cancel in the sum)."""
    m = jax.tree.leaves(client_grads)[0].shape[0]
    uploads = []
    for u in range(m):
        g_u = jax.tree.map(lambda x: x[u].astype(jnp.float32), client_grads)
        masked = g_u
        for v in range(m):
            if v == u:
                continue
            lo, hi = min(u, v), max(u, v)
            pk = jax.random.fold_in(jax.random.fold_in(round_key, lo), hi)
            sign = 1.0 if u < v else -1.0
            masked = jax.tree.map(
                lambda x, k=pk, s=sign: x + s * _pair_mask(k, x), masked)
        uploads.append(masked)
    return uploads


def secure_sum(uploads):
    """Server-side sum of masked uploads; equals Σ_u g_u exactly."""
    return jax.tree.map(lambda *xs: sum(xs), *uploads)
