"""Federated Averaging baseline (McMahan et al. 2017) + FedAvg(Meta).

FedAvg: each sampled client runs local optimization (Adam, per paper A.2)
for `local_steps` minibatch steps starting from the global model; the
server replaces the global model with the example-count-weighted average
of the returned client models.

FedAvg(Meta) is an *evaluation-time* variant (paper §4.1): the same
trained global model is fine-tuned on a test client's support set before
testing on its query set — handled in server.evaluate_global.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import adam, sgd


@dataclasses.dataclass
class FedAvgTrainer:
    loss_fn: Callable
    eval_fn: Callable
    local_lr: float
    local_steps: int = 5
    local_optimizer: str = "adam"          # paper A.2 uses Adam locally
    name: str = "fedavg"

    def _opt(self):
        return (adam(self.local_lr) if self.local_optimizer == "adam"
                else sgd(self.local_lr))

    def init_state(self, key, model_init):
        return {"theta": model_init(key)}

    def local_train(self, theta, batches):
        """batches: pytree with leading (steps,) axis of minibatches."""
        opt = self._opt()

        def body(carry, batch):
            p, st = carry
            g = jax.grad(self.loss_fn)(p, batch)
            p, st = opt.update(p, g, st)
            return (p, st), None

        (theta, _), _ = jax.lax.scan(body, (theta, opt.init(theta)), batches)
        return theta

    def round_step(self, state, client_batches, weights=None):
        """client_batches: leading axes (m, steps, ...) on every leaf."""
        m = jax.tree.leaves(client_batches)[0].shape[0]
        w = (jnp.full((m,), 1.0 / m, jnp.float32) if weights is None
             else weights / jnp.sum(weights))
        thetas = jax.vmap(lambda b: self.local_train(state["theta"], b))(
            client_batches)
        theta = jax.tree.map(
            lambda t: jnp.tensordot(w, t.astype(jnp.float32),
                                    axes=1).astype(t.dtype), thetas)
        return {"theta": theta}

    def finetune(self, theta, support, steps: int | None = None):
        """FedAvg(Meta): fine-tune on a test client's support set."""
        reps = steps or self.local_steps
        batches = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), support)
        return self.local_train(theta, batches)
