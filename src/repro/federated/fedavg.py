"""Federated Averaging baseline (McMahan et al. 2017) + FedAvg(Meta).

FedAvg: each sampled client runs local optimization (Adam, per paper A.2)
for `local_steps` minibatch steps starting from the global model; the
server replaces the global model with the example-count-weighted average
of the returned client models.

FedAvg(Meta) is an *evaluation-time* variant (paper §4.1): the same
trained global model is fine-tuned on a test client's support set before
testing on its query set (``meta_eval=True`` / `finetune`).

This trainer is at *parity* with `server.FederatedTrainer`: the same
`run(state, rounds, eval_every, eval_clients)` driver loop, a
`CommTracker` (download = full model θ, upload = full model θ — FedAvg
ships the whole model both ways every round, the asymmetry the paper's
communication claim exploits), weighted aggregation from
`TaskBatch.weight`, per-round history records, and a chunked client
axis that reuses `core/fedmeta._scan_chunks`. This is what lets the
experiment plane (`federated/experiment.py`) run FedAvg and FedMeta on
the identical client split and sampling stream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedmeta import (_maybe_jit, _normalize_weights, _scan_chunks,
                                _weighted_metrics)
from repro.data.federated import TaskStream, sample_task_batch
from repro.federated.async_engine import AsyncRoundEngine
from repro.federated.comm import CommTracker, measure_client_flops
from repro.optim import adam, sgd
from repro.utils.pytree import tree_add, tree_zeros_like


@dataclasses.dataclass
class FedAvgTrainer:
    loss_fn: Callable
    eval_fn: Callable
    local_lr: float
    local_steps: int = 5
    local_optimizer: str = "adam"          # paper A.2 uses Adam locally
    name: str = "fedavg"
    # ---- driver-loop parity with FederatedTrainer --------------------
    train_clients: Optional[list] = None
    clients_per_round: int = 4
    support_frac: float = 0.5       # split recorded per batch; FedAvg
    support_size: int = 16          # trains on support+query combined
    query_size: int = 16
    weighted: bool = True           # paper A.2: weight by local data count
    client_chunk: Optional[int] = None   # scan-of-chunks over clients
    local_batch_size: Optional[int] = None     # None = support_size
    finetune_batch_size: Optional[int] = None  # None = full support size
    meta_eval: bool = False         # FedAvg(Meta) scoring at eval time
    seed: int = 0
    # ---- async round engine (DESIGN.md §12) -------------------------
    prefetch_depth: int = 0         # staged rounds ahead; 0 = sync loop
    flush_every: int = 1            # drain deferred metrics every k rounds

    def __post_init__(self):
        if self.meta_eval and self.name == "fedavg":
            self.name = "fedavg(meta)"
        # task-sampling stream: consumes exactly one `sample_task_batch`
        # per round — the SAME RandomState call pattern as
        # FederatedTrainer, so both trainers see identical client picks
        # and support/query splits under a shared seed. Local minibatch
        # indices come from a separate stream so they cannot desync it.
        self._rng = np.random.RandomState(self.seed)
        self._local_rng = np.random.RandomState(self.seed + 0x5EED)
        self._step = None
        self._evaluator = None
        self.comm: Optional[CommTracker] = None
        self.history: list = []

    def _opt(self):
        return (adam(self.local_lr) if self.local_optimizer == "adam"
                else sgd(self.local_lr))

    # ---- state ------------------------------------------------------
    def init(self, key, model_init):
        state = {"theta": model_init(key)}
        self.comm = CommTracker.for_state(state["theta"],
                                          self.clients_per_round)
        return state

    def init_state(self, key, model_init):
        return self.init(key, model_init)

    def phi_tree(self, state):
        """The global model as a pytree (parity with FederatedTrainer)."""
        return state["theta"]

    def evaluator(self):
        """The trainer's jitted global evaluator (finetuning when
        ``meta_eval``) — pass to `evaluate_global` to amortize
        compilation across eval calls. Built lazily."""
        if self._evaluator is None:
            from repro.federated.server import make_global_evaluator
            self._evaluator = make_global_evaluator(
                self.eval_fn, self.finetune if self.meta_eval else None)
        return self._evaluator

    # ---- client procedure -------------------------------------------
    def local_train(self, theta, batches):
        """batches: pytree with leading (steps,) axis of minibatches."""
        opt = self._opt()

        def body(carry, batch):
            p, st = carry
            g = jax.grad(self.loss_fn)(p, batch)
            p, st = opt.update(p, g, st)
            return (p, st), None

        (theta, _), _ = jax.lax.scan(body, (theta, opt.init(theta)), batches)
        return theta

    def finetune(self, theta, support, steps: int | None = None, key=None):
        """FedAvg(Meta): fine-tune on a test client's support set with
        *per-step seeded minibatches* (paper A.2 local training), not one
        identical full-support batch repeated every step."""
        reps = steps or self.local_steps
        n = jax.tree.leaves(support)[0].shape[0]
        bs = min(self.finetune_batch_size or n, n)
        key = jax.random.PRNGKey(self.seed) if key is None else key
        # with-replacement draws: stochastic per step even at bs == n,
        # and jit-friendly inside the vmapped global evaluator
        idx = jax.random.randint(key, (reps, bs), 0, n)
        batches = jax.tree.map(lambda x: x[idx], support)
        return self.local_train(theta, batches)

    # ---- server round -----------------------------------------------
    def _round(self, theta, batches, eval_batch, w):
        """Weighted model average over the client axis.

        batches: leading (m, steps, B, ...) local minibatches;
        eval_batch: optional (m, P, ...) per-client data the locally
        trained model is scored on (train-loss/accuracy metrics);
        w: normalized (m,) aggregation weights."""

        def chunk_fn(b, e, wc):
            def one(bi, ei):
                th = self.local_train(theta, bi)
                if ei is None:
                    return th, {}
                loss, met = self.eval_fn(th, ei)
                return th, {"train_loss": loss, **met}

            thetas, mets = jax.vmap(one)(b, e)
            partial = jax.tree.map(
                lambda t: jnp.tensordot(wc, t.astype(jnp.float32), axes=1),
                thetas)
            return partial, _weighted_metrics(wc, mets)

        m = jax.tree.leaves(batches)[0].shape[0]
        if self.client_chunk and self.client_chunk < m:
            acc0 = tree_zeros_like(
                jax.tree.map(lambda x: x.astype(jnp.float32), theta))
            avg, metrics = _scan_chunks(chunk_fn, acc0, tree_add, batches,
                                        eval_batch, w, m, self.client_chunk)
        else:
            avg, metrics = chunk_fn(batches, eval_batch, w)
        new_theta = jax.tree.map(lambda a, t: a.astype(t.dtype), avg, theta)
        return new_theta, metrics

    def round_step(self, state, client_batches, weights=None):
        """client_batches: leading axes (m, steps, ...) on every leaf."""
        m = jax.tree.leaves(client_batches)[0].shape[0]
        w = _normalize_weights(
            None if weights is None else jnp.asarray(weights), m)
        theta, _ = self._round(state["theta"], client_batches, None, w)
        return {"theta": theta}

    def _make_step(self):
        def step(state, batches, eval_batch, w):
            theta, metrics = self._round(state["theta"], batches, eval_batch,
                                         w)
            return {"theta": theta}, metrics

        # donate θ across rounds (no-op on CPU, where XLA lacks donation)
        return _maybe_jit(step, True, True)

    def _local_batches(self, tb):
        """Per-round local training minibatches from the sampled clients'
        FULL local data (support+query — FedAvg has no query split, paper
        §4.1): (m, steps, B, ...) with per-step indices drawn from the
        dedicated local stream."""
        px = np.concatenate([tb.support_x, tb.query_x], axis=1)
        py = np.concatenate([tb.support_y, tb.query_y], axis=1)
        m, pool = py.shape[:2]
        bs = min(self.local_batch_size or self.support_size, pool)
        idx = self._local_rng.randint(0, pool,
                                      size=(m, self.local_steps, bs))
        rows = np.arange(m)[:, None, None]
        return (px[rows, idx], py[rows, idx]), (px, py)

    def measure_flops(self, state):
        """One-off XLA cost analysis of one client's local training."""
        tb = sample_task_batch(self.train_clients, 1, self.support_frac,
                               self.support_size, self.query_size, self._rng)
        (bx, by), _ = self._local_batches(tb)
        batch = (jnp.asarray(bx[0]), jnp.asarray(by[0]))
        fl = measure_client_flops(
            lambda b: self.local_train(state["theta"], b), batch)
        if self.comm:
            self.comm.flops_per_client = fl
        return fl

    def run(self, state, rounds: int, eval_every: int = 0,
            eval_clients=None, log: Callable = None):
        """Driver loop at parity with FederatedTrainer.run, on the same
        async round engine (DESIGN.md §12): task sampling AND the local
        minibatch build run on the prefetch thread (both seeded streams
        advance sequentially there, preserving the synchronous order),
        arrays are staged with device_put instead of per-round
        jnp.asarray re-transfers, and the per-round float() metrics
        readback is deferred to the flush points. prefetch_depth=0 /
        flush_every=1 is exactly the synchronous loop; periodic
        evaluation on held-out clients (FedAvg(Meta) fine-tunes when
        ``meta_eval=True``) is unchanged."""
        from repro.federated.server import evaluate_global
        if self._step is None:
            self._step = self._make_step()
        evaluator = self.evaluator()
        stream = TaskStream(self.train_clients, self.clients_per_round,
                            self.support_frac, self.support_size,
                            self.query_size, self._rng)
        dp = jax.device_put

        def stage(k):
            assert k == 1, "FedAvg has no fused-K mode"
            tb = stream.next()
            (bx, by), (px, py) = self._local_batches(tb)
            w = _normalize_weights(
                jnp.asarray(tb.weight) if self.weighted else None,
                len(tb.weight))
            return ((dp(bx), dp(by)), (dp(px), dp(py)), w)

        evaluate = None
        if eval_every and eval_clients is not None:
            def evaluate(st):
                acc, _, loss = evaluate_global(
                    self.eval_fn, st["theta"], eval_clients,
                    support_frac=self.support_frac,
                    support_size=self.support_size,
                    query_size=self.query_size, seed=self.seed,
                    evaluator=evaluator)
                return {"eval_acc": acc, "eval_loss": loss}

        engine = AsyncRoundEngine(
            stage=stage, step=lambda st, a: self._step(st, *a),
            comm=self.comm, history=self.history,
            prefetch_depth=self.prefetch_depth,
            flush_every=self.flush_every)
        return engine.run(state, rounds, eval_every=eval_every,
                          evaluate=evaluate, log=log)
