"""Seeded per-round client-failure injection (DESIGN.md §14).

Federated populations are unreliable (Li et al., 1908.07873): clients
drop out mid-round, diverge locally and upload non-finite gradients, or
are outright Byzantine. ``FaultConfig`` describes such a population with
three independent failure fractions; each round a seeded pick assigns
*disjoint* failure roles to the sampled clients:

  * **dropout** — the client's update never arrives: its aggregation
    weight is zeroed (the gradient row is computed but contributes
    nothing; renormalization is the aggregator's job).
  * **non-finite** — local divergence: the client's gradient row is
    replaced by NaN. Plain mean aggregation is poisoned and relies on
    the engine's non-finite guard to skip the round; screening/trimmed
    aggregators reject the row and keep training.
  * **Byzantine** — an adversarial upload: the row is replaced by
    ``-scale·g`` (``"sign_flip"``) or by ``scale·N(0, 1)`` noise
    (``"scaled_noise"``).

Like ``StalenessConfig``, per-round counts are *static* functions of m
(fractions rounded, total clamped to m−1 so at least one honest client
always arrives) — the jitted step compiles once and zero-count failure
modes are statically absent, keeping a disabled ``FaultConfig`` bitwise
identical to no config at all. The per-round pick consumes its own
``np.random.RandomState`` (seeded independently of task sampling and
straggler picks), so enabling faults never perturbs the task stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

BYZANTINE_MODES = ("sign_flip", "scaled_noise")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-round client failure model; all fractions of clients-per-round.

    >>> cfg = FaultConfig(dropout=0.25, byzantine=0.25)
    >>> cfg.counts(8)
    (2, 0, 2)
    >>> keep, nan_m, byz_m, seed = cfg.pick(8, np.random.RandomState(0))
    >>> int(keep.sum()), int(nan_m.sum()), int(byz_m.sum())
    (6, 0, 2)
    """
    dropout: float = 0.0      # fraction whose update never arrives
    nonfinite: float = 0.0    # fraction uploading NaN gradients
    byzantine: float = 0.0    # fraction uploading adversarial gradients
    byzantine_mode: str = "sign_flip"   # or "scaled_noise"
    byzantine_scale: float = 10.0       # magnitude of the adversarial row
    seed: int = 0

    def __post_init__(self):
        for name in ("dropout", "nonfinite", "byzantine"):
            f = getattr(self, name)
            if not 0.0 <= f < 1.0:
                raise ValueError(f"{name} fraction must be in [0, 1)")
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(f"byzantine_mode must be one of "
                             f"{BYZANTINE_MODES}, got "
                             f"{self.byzantine_mode!r}")

    @property
    def enabled(self) -> bool:
        return (self.dropout > 0 or self.nonfinite > 0 or
                self.byzantine > 0)

    def counts(self, m: int) -> tuple:
        """Static per-round (dropped, nonfinite, byzantine) counts.

        Static shapes keep the step jitted once; the total is capped at
        m − 1 (at least one honest arriving client), shaving overflow
        off byzantine, then nonfinite, then dropout."""
        ks = [int(round(f * m))
              for f in (self.dropout, self.nonfinite, self.byzantine)]
        over = max(0, sum(ks) - (m - 1))
        for i in (2, 1, 0):
            take = min(over, ks[i])
            ks[i] -= take
            over -= take
        return tuple(ks)

    def pick(self, m: int, rng: np.random.RandomState):
        """One round's failure assignment — host-side mask arrays.

        Returns ``(keep, nan_mask, byz_mask, noise_seed)``: a (m,) f32
        arrival mask (0 = dropped), two (m,) bool failure masks, and a
        uint32 seed for the scaled-noise draw. Roles are disjoint slices
        of one permutation; the rng consumes the same draws regardless
        of which modes are enabled, so fraction sweeps share the same
        underlying assignment."""
        kd, kn, kb = self.counts(m)
        perm = rng.permutation(m)
        keep = np.ones((m,), np.float32)
        keep[perm[:kd]] = 0.0
        nan_mask = np.zeros((m,), bool)
        nan_mask[perm[kd:kd + kn]] = True
        byz_mask = np.zeros((m,), bool)
        byz_mask[perm[kd + kn:kd + kn + kb]] = True
        seed = np.uint32(rng.randint(0, 2**31 - 1))
        return keep, nan_mask, byz_mask, seed


def apply_faults(cfg: FaultConfig, G, w, fault):
    """Apply one round's failure assignment to the (m, N) gradient block.

    ``fault`` is a (device-put) ``cfg.pick`` tuple. Returns
    ``(G, w_agg, w_rep)``: the corrupted block, the aggregation weights
    (dropped rows zeroed — renormalization is the aggregator's concern),
    and the metric-reporting weights (renormalized over arrived clients,
    since the server only sees metrics from clients that report back).
    Every transform is gated on the *static* per-round count, so a
    zero-fraction config leaves the jitted graph — and the numerics —
    bitwise untouched."""
    keep, nan_mask, byz_mask, noise_seed = fault
    kd, kn, kb = cfg.counts(G.shape[0])
    if kb:
        if cfg.byzantine_mode == "sign_flip":
            bad = (-jnp.float32(cfg.byzantine_scale)).astype(G.dtype) * G
        else:
            bad = (jnp.float32(cfg.byzantine_scale) * jax.random.normal(
                jax.random.PRNGKey(noise_seed), G.shape,
                jnp.float32)).astype(G.dtype)
        G = jnp.where(byz_mask[:, None], bad, G)
    if kn:
        G = jnp.where(nan_mask[:, None], jnp.asarray(jnp.nan, G.dtype), G)
    if kd:
        w_agg = w * keep
        w_rep = w_agg / jnp.sum(w_agg)
    else:
        w_agg = w_rep = w
    return G, w_agg, w_rep
