"""Byte- and FLOP-accounting for the federated protocol.

The paper's Figure 3 measures, per method, the total bytes transferred
between server and clients and total client FLOPs needed to hit a target
accuracy. This tracker reproduces that accounting exactly:

  per round: download = m * bytes(φ), upload = m * bytes(g_u)
  (g_u matches φ structurally for every algorithm in Alg. 1; when the
  packed pipeline transmits a reduced-precision gradient block —
  ``block_dtype=bf16`` — the upload leg counts the block's actual dtype,
  so the reported communication reduction matches what is transmitted)
  client compute = m * flops_per_client (measured once from the compiled
  client function via XLA cost analysis).

Crucially, ``phi_bytes`` is *per tracker*: each trainer builds its own
tracker from its own θ, so methods that ship different-sized models pay
different per-round bytes. That is what makes the paper's §4.3 model-size
argument measurable — FedMeta's small local-head recommender vs FedAvg's
global-service head (DESIGN.md §13) — and the summaries expose the size
itself as ``phi_MB`` so comparison artifacts record the asymmetry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.utils.pytree import tree_bytes, tree_size


@dataclasses.dataclass
class CommTracker:
    phi_bytes: int
    clients_per_round: int
    flops_per_client: float = 0.0
    rounds: int = 0
    # bytes of one client's uploaded gradient; None = same as φ (f32
    # tree upload). Set by for_state(block_dtype=...) for the packed
    # reduced-precision block, and overridden by the trainer with the
    # codec-true bytes (payload + scales/indices, DESIGN.md §17) when
    # upload compression is on.
    grad_bytes: Optional[int] = None
    # codec tag ("int8+ef", "topk0.05+ef", ...) surfaced in summaries
    # so artifacts record WHAT the upload bytes are bytes of; None =
    # dense upload (key omitted — pre-compression artifacts unchanged)
    codec: Optional[str] = None
    # population plane (DESIGN.md §15): one (selected, arrived,
    # quarantined) entry per round, appended by the trainer's staging
    # under over-selection. Download bytes charge ALL selected
    # candidates (φ was pushed to each of them), upload bytes and
    # client FLOPs only the ARRIVED clients (failed/late/surplus
    # clients never deliver a gradient). Empty = the classical
    # fixed-cohort accounting (rounds · m) — existing artifacts are
    # untouched.
    participation: list = dataclasses.field(default_factory=list)

    @classmethod
    def for_state(cls, phi, clients_per_round: int,
                  flops_per_client: float = 0.0, block_dtype=None):
        grad_bytes = None
        if block_dtype is not None:
            import jax.numpy as jnp
            grad_bytes = tree_size(phi) * jnp.dtype(block_dtype).itemsize
        return cls(tree_bytes(phi), clients_per_round, flops_per_client,
                   grad_bytes=grad_bytes)

    def tick(self, rounds: int = 1):
        self.rounds += rounds

    def record_round(self, selected: int, arrived: int,
                     quarantined: int = 0):
        """Record one round's participation (population plane). Called
        at staging time — possibly rounds ahead of ``tick()`` under
        prefetching; `summary_at` only ever reads the first ``rounds``
        entries, so the accounting stays a pure function of the round
        index."""
        self.participation.append((int(selected), int(arrived),
                                   int(quarantined)))

    def _counts_at(self, rounds: int):
        """(selected, arrived) client-round totals as of ``rounds``."""
        if not self.participation:
            n = rounds * self.clients_per_round
            return n, n
        k = min(rounds, len(self.participation))
        sel = sum(p[0] for p in self.participation[:k])
        arr = sum(p[1] for p in self.participation[:k])
        extra = max(0, rounds - k) * self.clients_per_round
        return sel + extra, arr + extra

    @property
    def download_bytes(self) -> int:
        return self._counts_at(self.rounds)[0] * self.phi_bytes

    @property
    def upload_bytes(self) -> int:
        per_client = (self.grad_bytes if self.grad_bytes is not None
                      else self.phi_bytes)
        return self._counts_at(self.rounds)[1] * per_client

    @property
    def total_bytes(self) -> int:
        return self.download_bytes + self.upload_bytes

    @property
    def total_flops(self) -> float:
        return self._counts_at(self.rounds)[1] * self.flops_per_client

    def summary_at(self, rounds: int) -> dict:
        """The cumulative summary as of round ``rounds`` — a pure
        function of the round index, which is what lets the async
        engine defer history materialization: a pending record only has
        to remember its round count, not a snapshot of this tracker."""
        snap = self if rounds == self.rounds else dataclasses.replace(
            self, rounds=rounds)
        out = {
            "rounds": snap.rounds,
            "comm_MB": snap.total_bytes / 1e6,
            "upload_MB": snap.upload_bytes / 1e6,
            "download_MB": snap.download_bytes / 1e6,
            "client_GFLOPs": snap.total_flops / 1e9,
            # the per-method model size the bytes above are multiples of —
            # constant across rounds, recorded so artifacts carry the
            # local-head vs global-head θ asymmetry explicitly
            "phi_MB": self.phi_bytes / 1e6,
        }
        if self.codec is not None:
            out["codec"] = self.codec
        if self.participation and rounds >= 1:
            r = min(rounds, len(self.participation)) - 1
            sel_r, arr_r, quar_r = self.participation[r]
            cum_sel, cum_arr = self._counts_at(rounds)
            # per-round participation + cumulative totals — the ints a
            # population-plane history record carries (DESIGN.md §15)
            out.update(selected=sel_r, arrived=arr_r,
                       quarantined=quar_r, selected_total=cum_sel,
                       arrived_total=cum_arr)
        return out

    def summary(self) -> dict:
        return self.summary_at(self.rounds)


def measure_client_flops(fn, *args) -> float:
    """FLOPs of one client call via XLA cost analysis (CPU backend).

    Returns 0.0 when cost analysis is unavailable — with a warning, so a
    Fig-3 reproduction cannot silently report zero client compute."""
    import jax
    try:
        compiled = jax.jit(fn).lower(*args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        return float(cost.get("flops", 0.0))
    except Exception as e:
        import logging
        logging.getLogger(__name__).warning(
            "measure_client_flops: XLA cost analysis failed (%s: %s); "
            "reporting 0.0 client FLOPs — Fig-3 compute numbers will be "
            "wrong", type(e).__name__, e)
        return 0.0
