"""Federated training drivers: server round loop, client sampling,
communication accounting, and the paper's evaluation schemes.

Evaluation (paper §4.1 + A.2): accuracy w.r.t. all data points on held-out
*test clients*; each test client adapts on its support set (FedMeta /
FedAvg(Meta)) or not (FedAvg) and is scored on its query set.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedmeta import (_maybe_jit, init_packed_state,
                                make_meta_train_step,
                                make_packed_meta_train_step)
from repro.data.federated import (TaskStream, assemble_task_batch,
                                  sample_task_batch, stack_task_batches)
from repro.federated.async_engine import (AsyncRoundEngine, StalenessConfig,
                                          WorkerPool)
from repro.federated.comm import CommTracker, measure_client_flops
from repro.federated.faults import FaultConfig
from repro.federated.privacy import DPConfig
from repro.kernels.meta_update.compress import CompressionConfig
from repro.federated.population import (CircuitBreaker, UnreliabilityConfig,
                                        plan_round)
from repro.kernels.meta_update import ops as mu_ops
from repro.optim import Optimizer
from repro.utils.flat import plane_for


def _rng_state_payload(state):
    """np.random.RandomState.get_state() tuple -> checkpointable dict
    (the 624-word key vector as an array, scalars as python types)."""
    alg, keys, pos, has_gauss, cached = state
    return {"alg": alg, "keys": np.asarray(keys, np.uint32),
            "pos": int(pos), "has_gauss": int(has_gauss),
            "cached": float(cached)}


def _rng_state_from_payload(p):
    return (str(p["alg"]), np.asarray(p["keys"], np.uint32),
            int(p["pos"]), int(p["has_gauss"]), float(p["cached"]))


def _batch_eval(eval_one, clients, m, support_frac, support_size, query_size,
                rng):
    tb = sample_task_batch(clients, m, support_frac, support_size, query_size,
                           rng)
    accs, losses = eval_one((tb.support_x, tb.support_y),
                            (tb.query_x, tb.query_y))
    counts = (np.ones((m,), np.float64) if tb.query_count is None
              else np.asarray(tb.query_count, np.float64))
    return np.asarray(accs), np.asarray(losses), counts


def _count_weighted(accs, losses, counts):
    """§4.1 evaluation: accuracy w.r.t. *all data points*, i.e. each
    client's (fixed-shape resampled) query accuracy weighted by the
    number of query examples that client actually holds — not an
    unweighted mean over clients. Same reduction for the loss."""
    w = counts / counts.sum()
    return float(np.sum(w * accs)), float(np.sum(w * losses))


def make_meta_evaluator(algo, adapt_steps=None):
    """Jitted once; φ passed as an argument (avoids per-eval recompiles)."""

    @jax.jit
    def eval_batch(phi, support, query):
        def one(s, q):
            theta_u = algo.adapt(phi, s, steps=adapt_steps)
            loss, met = algo.eval_fn(theta_u, q)
            return met["accuracy"], loss
        return jax.vmap(one)(support, query)

    return eval_batch


def make_global_evaluator(eval_fn, finetune: Optional[Callable] = None):
    @jax.jit
    def eval_batch(theta, support, query):
        def one(s, q):
            th = theta if finetune is None else finetune(theta, s)
            loss, met = eval_fn(th, q)
            return met["accuracy"], loss
        return jax.vmap(one)(support, query)

    return eval_batch


def evaluate_meta(algo, phi, clients, *, support_frac, support_size,
                  query_size, seed=0, adapt_steps=None, evaluator=None):
    """Per-client adapted accuracy over all test clients; returns
    (acc, per_client_accs, mean_loss) with acc and mean_loss weighted by
    each client's true query count (§4.1). Pass a `make_meta_evaluator`
    result to amortize compilation across calls."""
    rng = np.random.RandomState(seed)
    ev = evaluator or make_meta_evaluator(algo, adapt_steps)
    accs, losses, counts = _batch_eval(
        lambda s, q: ev(phi, s, q), clients, len(clients), support_frac,
        support_size, query_size, rng)
    acc, loss = _count_weighted(accs, losses, counts)
    return acc, accs, loss


def evaluate_global(eval_fn, theta, clients, *, support_frac, support_size,
                    query_size, seed=0, finetune: Optional[Callable] = None,
                    evaluator=None):
    """FedAvg (finetune=None) / FedAvg(Meta) (finetune=trainer.finetune).
    Returns (acc, per_client_accs, mean_loss), query-count-weighted like
    `evaluate_meta`."""
    rng = np.random.RandomState(seed)
    ev = evaluator or make_global_evaluator(eval_fn, finetune)
    accs, losses, counts = _batch_eval(
        lambda s, q: ev(theta, s, q), clients, len(clients), support_frac,
        support_size, query_size, rng)
    acc, loss = _count_weighted(accs, losses, counts)
    return acc, accs, loss


@dataclasses.dataclass
class FederatedTrainer:
    """FedMeta meta-training loop (Algorithm 1 AlgorithmUpdate)."""
    algo: object
    optimizer: Optimizer
    train_clients: list
    clients_per_round: int
    support_frac: float
    support_size: int
    query_size: int
    weighted: bool = True          # paper A.2: weight by local data count
    client_axis: str = "vmap"
    seed: int = 0
    client_chunk: Optional[int] = None   # for client_axis="chunked"
    packed: bool = False                 # packed parameter plane pipeline
    impl: Optional[str] = None           # fused-kernel impl for packed
    block_dtype: Optional[object] = None  # client-grad block dtype (packed)
    client_plane: bool = False  # fused flat inner loop (packed only)
    mesh: Optional[object] = None  # for client_axis="sharded" (None =
    mesh_axis: Optional[str] = None  # ambient mesh, first axis)
    # ---- async round engine (DESIGN.md §12) -------------------------
    prefetch_depth: int = 0     # staged round blocks ahead; 0 = sync loop
    flush_every: int = 1        # drain deferred metrics every k rounds
                                # (0 = only at eval rounds / run() exit)
    fuse_rounds: int = 1        # lax.scan-over-rounds block size (packed)
    staleness: Optional[StalenessConfig] = None  # packed + vmap axis only
    # ---- failure plane (DESIGN.md §14) ------------------------------
    aggregator: str = "mean"    # mean | masked_mean | screen | trimmed
    screen_factor: float = 3.0  # screen: clip rows > factor × median ‖g‖
    trim: int = 1               # trimmed: per-coordinate trim count
    faults: Optional[FaultConfig] = None  # packed + vmap axis only
    guard: Optional[bool] = None  # non-finite skip-round guard; None =
                                  # auto (on iff faults or robust agg)
    # ---- bytes-on-the-wire plane (DESIGN.md §17) --------------------
    compression: Optional[CompressionConfig] = None  # packed + vmap only
    dp: Optional[DPConfig] = None  # central-DP clip+noise (packed + vmap)
    prefetch_retries: int = 0   # transient staging failures retried
    checkpoint_every: int = 0   # rounds between checkpoints (0 = off)
    checkpoint_dir: Optional[str] = None
    checkpoint_keep: int = 3    # keep-last-k retention
    # ---- population plane (DESIGN.md §15) ---------------------------
    unreliability: Optional[UnreliabilityConfig] = None  # arrival model
    over_select: float = 0.0    # sample m·(1+over_select) candidates
    round_deadline: Optional[float] = None  # latency cutoff (unrel units)
    pool_workers: int = 0       # shard-materializing workers (0 = inline)
    pool_retries: int = 2       # per-shard retry-with-backoff budget
    task_timeout: Optional[float] = None    # per-shard pool timeout (s)
    breaker_threshold: int = 3  # consecutive failures before quarantine
    breaker_cooldown: int = 10  # quarantine length in rounds

    def __post_init__(self):
        if self.client_plane and not self.packed:
            raise ValueError("client_plane=True requires packed=True")
        if self.fuse_rounds > 1 and not self.packed:
            raise ValueError("fuse_rounds>1 (fused-K round blocks) is a "
                             "packed-pipeline mode")
        if self.staleness is not None:
            if not self.packed or self.client_axis != "vmap":
                raise ValueError("staleness-aware aggregation requires "
                                 "packed=True and client_axis='vmap'")
            if self.fuse_rounds > 1:
                raise ValueError("staleness and fuse_rounds>1 are mutually "
                                 "exclusive (stragglers need per-round "
                                 "straggler picks)")
        if self.over_select < 0:
            raise ValueError("over_select must be >= 0")
        if self._population_active:
            if not self.packed or self.client_axis != "vmap":
                raise ValueError("the population plane (unreliability / "
                                 "over_select / round_deadline) needs "
                                 "the full (m, N) client block — "
                                 "packed=True and client_axis='vmap'")
            if self.fuse_rounds > 1:
                raise ValueError("the population plane and fuse_rounds>1 "
                                 "are mutually exclusive (arrival plans "
                                 "are per-round)")
            if self.staleness is not None:
                raise ValueError("staleness simulation and the population "
                                 "plane are mutually exclusive — the "
                                 "deadline model already decides who "
                                 "arrives late")
            if self.aggregator == "mean":
                # partial rounds need the renormalizing aggregator:
                # zero-weight pad rows must be exact no-ops
                self.aggregator = "masked_mean"
        if self.aggregator not in mu_ops.AGGREGATORS:
            raise ValueError(f"unknown aggregator {self.aggregator!r}; "
                             f"expected one of {mu_ops.AGGREGATORS}")
        if self.faults is not None or self.aggregator != "mean":
            if not self.packed or self.client_axis != "vmap":
                raise ValueError("fault injection / robust aggregation "
                                 "need the full (m, N) client block — "
                                 "packed=True and client_axis='vmap'")
        if self.faults is not None and self.fuse_rounds > 1:
            raise ValueError("faults and fuse_rounds>1 are mutually "
                             "exclusive (failures need per-round picks)")
        if self.aggregator == "trimmed" and \
                2 * self.trim >= self.clients_per_round:
            raise ValueError(f"trimmed mean needs 2·trim < clients_per_"
                             f"round ({self.trim} vs "
                             f"{self.clients_per_round})")
        if self.compression is not None or self.dp is not None:
            if not self.packed or self.client_axis != "vmap":
                raise ValueError("compression / DP need the full (m, N) "
                                 "client block — packed=True and "
                                 "client_axis='vmap'")
            if (self.staleness is not None or self.faults is not None
                    or self.aggregator != "mean"
                    or self._population_active):
                raise ValueError("compression / DP compose with each "
                                 "other but not with staleness, faults, "
                                 "robust aggregators, or the population "
                                 "plane")
            if self.fuse_rounds > 1:
                raise ValueError("compression / DP and fuse_rounds>1 are "
                                 "mutually exclusive (EF indices and "
                                 "noise keys are per-round inputs)")
        if self.guard is None:
            # auto: any failure-plane knob needs skip-round semantics
            self.guard = (self.faults is not None or
                          self.aggregator != "mean")
        if self.guard and not self.packed:
            raise ValueError("the non-finite guard is a flat-plane check "
                             "— packed=True only")
        # the packed step needs φ's FlatPlane, built in init(); the tree
        # step has no such dependency and is built eagerly
        self._step = None if self.packed else make_meta_train_step(
            self.algo, self.optimizer, client_axis=self.client_axis,
            client_chunk=self.client_chunk, mesh=self.mesh,
            mesh_axis=self.mesh_axis)
        self._fused = None
        self._plane = None
        self._rng = np.random.RandomState(self.seed)
        self._stale_rng = (np.random.RandomState(self.staleness.seed)
                           if self.staleness is not None else None)
        self._fault_rng = (np.random.RandomState(self.faults.seed)
                           if self.faults is not None else None)
        self._rng_snaps: dict = {}   # round -> rng states (prefetch-safe)
        self._breaker = (CircuitBreaker(self.breaker_threshold,
                                        self.breaker_cooldown)
                         if self._population_active else None)
        self._pool: Optional[WorkerPool] = None
        self._evaluator = make_meta_evaluator(self.algo)
        self.comm: Optional[CommTracker] = None
        self.history: list = []

    @property
    def _population_active(self) -> bool:
        """Deadline/over-selection staging replaces the plain task
        stream. A bare pool (pool_workers>0, everything else off) is
        NOT population mode — it only pre-warms the registry cache, so
        staging stays bit-identical to the eager path."""
        return (self.unreliability is not None or self.over_select > 0
                or self.round_deadline is not None)

    def init(self, key, model_init):
        phi = self.algo.init_state(key, model_init)
        if self.packed:
            self._plane = plane_for(phi)
            kw = dict(client_axis=self.client_axis,
                      client_chunk=self.client_chunk, impl=self.impl,
                      block_dtype=self.block_dtype,
                      client_plane=self.client_plane,
                      staleness=self.staleness,
                      aggregator=self.aggregator,
                      screen_factor=self.screen_factor, trim=self.trim,
                      faults=self.faults, guard=bool(self.guard),
                      compression=self.compression, dp=self.dp,
                      mesh=self.mesh, mesh_axis=self.mesh_axis)
            self._step = make_packed_meta_train_step(
                self.algo, self.optimizer, self._plane, **kw)
            if self.fuse_rounds > 1:
                # scan-over-rounds on the SAME (unjitted) step body the
                # per-round path compiles — fused-K blocks must be
                # bit-identical to K per-round steps
                body = make_packed_meta_train_step(
                    self.algo, self.optimizer, self._plane, jit=False,
                    donate=False, **kw)

                def fused(state, staged):
                    def one(st, inp):
                        sup, qry, w = inp
                        return body(st, sup, qry, w)
                    return jax.lax.scan(one, state, staged)

                self._fused = _maybe_jit(fused, True, True)
            state = init_packed_state(
                self.optimizer, self._plane, phi, staleness=self.staleness,
                clients_per_round=self.clients_per_round,
                block_dtype=self.block_dtype,
                compression=self.compression,
                num_clients=len(self.train_clients))
        else:
            state = {"phi": phi, "opt": self.optimizer.init(phi)}
        self.comm = CommTracker.for_state(
            phi, self.clients_per_round,
            block_dtype=self.block_dtype if self.packed else None)
        if self.packed and self.compression is not None:
            # codec-true upload bytes (§17): payload + side information
            # over the REAL parameter count; top-k values ride at the
            # block dtype's width. Download stays dense φ.
            from repro.utils.pytree import tree_size
            val_itemsize = jnp.dtype(
                self.block_dtype or jnp.float32).itemsize
            self.comm.grad_bytes = self.compression.upload_bytes(
                tree_size(phi), val_itemsize)
            self.comm.codec = self.compression.label()
        return state

    def phi_tree(self, state):
        """φ as a pytree regardless of parameter representation."""
        if self.packed:
            return self._plane.unpack(state["phi"])
        return state["phi"]

    def evaluator(self):
        """The trainer's jitted meta-evaluator — pass to `evaluate_meta`
        to amortize compilation across eval calls."""
        return self._evaluator

    def measure_flops(self, state):
        """One-off XLA cost analysis of the client procedure."""
        tb = sample_task_batch(self.train_clients, 1, self.support_frac,
                               self.support_size, self.query_size, self._rng)
        sup = jax.tree.map(lambda x: jnp.asarray(x[0]),
                           (tb.support_x, tb.support_y))
        qry = jax.tree.map(lambda x: jnp.asarray(x[0]),
                           (tb.query_x, tb.query_y))
        fl = measure_client_flops(
            lambda s, q: self.algo.client_grad(self.phi_tree(state), s, q)[0],
            sup, qry)
        if self.comm:
            self.comm.flops_per_client = fl
        return fl

    def _stage_block(self, stream, dp, k, round_):
        """Host half of one round block: sample + device_put staging.
        Runs on the prefetch thread (in block order) when pipelined.

        The step's optional inputs are positional —
        ``(stale_sel, fault, ef_idx, dp_key)`` — staged as a tail with
        trailing ``None``s trimmed, so every off-knob configuration
        stages byte-for-byte the argument tuple it staged before the
        knob existed (the PR 4–7 shipping invariant)."""
        if k > 1:   # fused-K: one stacked (k, m, ...) staged buffer
            tb = stack_task_batches(stream.take(k))
            return ((dp(tb.support_x), dp(tb.support_y)),
                    (dp(tb.query_x), dp(tb.query_y)),
                    dp(tb.weight) if self.weighted else None)
        tb = stream.next()
        args = ((dp(tb.support_x), dp(tb.support_y)),
                (dp(tb.query_x), dp(tb.query_y)),
                dp(tb.weight) if self.weighted else None)
        sel = None
        if self.staleness is not None:
            # (straggler_idx, fresh_idx[, delays]) — delays only
            # with jitter on, so the off-path stays bit-identical
            sel = tuple(dp(s) for s in self.staleness.pick(
                self.clients_per_round, self._stale_rng))
        fault = None
        if self.faults is not None:
            fault = tuple(dp(f) for f in self.faults.pick(
                self.clients_per_round, self._fault_rng))
        ef_idx = None
        if self.compression is not None and \
                self.compression.error_feedback:
            # this round's picks = the residual-plane rows the step
            # gathers/scatters (recorded by the sampler; no extra draw)
            ef_idx = dp(np.asarray(tb.client_idx, np.int32))
        dp_key = None
        if self.dp is not None and self.dp.noise_multiplier > 0:
            # pure function of the round index: prefetch/resume-safe
            # with nothing checkpointed
            dp_key = self.dp.round_key(round_)
        tail = [sel, fault, ef_idx, dp_key]
        while tail and tail[-1] is None:
            tail.pop()
        return args + tuple(tail)

    # ---- population plane (DESIGN.md §15) ---------------------------
    def _peek_picks(self):
        """The upcoming task batch's client picks without consuming the
        stream — the rng state is saved and restored, so the subsequent
        real draw replays identically (pool cache pre-warming)."""
        st = self._rng.get_state()
        n = len(self.train_clients)
        picks = self._rng.choice(n, size=self.clients_per_round,
                                 replace=n < self.clients_per_round)
        self._rng.set_state(st)
        return picks

    def _stage_population(self, dp, round_):
        """Host half of one population-plane round: sample
        ``m·(1+over_select)`` non-quarantined candidates, compute the
        deterministic arrival plan, materialize the arrived shards
        (through the worker pool when configured), and build the
        zero-weight-padded batch the `masked_mean` step renormalizes.
        Runs on the prefetch thread (in round order) when pipelined."""
        clients = self.train_clients
        m = self.clients_per_round
        rng = self._rng
        n_cand = m + int(round(self.over_select * m))
        quar = self._breaker.blocked(round_)
        n_total = len(clients)
        if quar and len(quar) < n_total:
            avail = np.setdiff1d(np.arange(n_total, dtype=np.int64),
                                 np.fromiter(quar, np.int64, len(quar)))
            cand = avail[rng.choice(len(avail), size=n_cand,
                                    replace=len(avail) < n_cand)]
        else:
            cand = rng.choice(n_total, size=n_cand,
                              replace=n_total < n_cand).astype(np.int64)
        plan = plan_round(cand, round_, self.unreliability,
                          self.round_deadline, m)
        for c in plan.failed:
            self._breaker.record_failure(int(c), round_)
        for c in plan.arrived:
            self._breaker.record_success(int(c))
        idxs = [int(c) for c in plan.arrived]
        label = f"round {round_}"
        if self._pool is not None:
            shards = self._pool.map(idxs, label=label)
            probe = (None if idxs else
                     self._pool.map([int(cand[0])], label=label)[0])
        else:
            shards = [clients[i] for i in idxs]
            probe = None if idxs else clients[int(cand[0])]
        tb = assemble_task_batch(shards, m, self.support_frac,
                                 self.support_size, self.query_size, rng,
                                 weighted=self.weighted, probe=probe)
        # download: φ went to every candidate; upload: only arrivals
        self.comm.record_round(len(cand), len(idxs), len(quar))
        # weights always staged: the zero rows ARE the arrival mask
        args = ((dp(tb.support_x), dp(tb.support_y)),
                (dp(tb.query_x), dp(tb.query_y)), dp(tb.weight))
        if self.faults is not None:
            args += (None,)   # stale_sel placeholder (positional call)
            fault = self.faults.pick(m, self._fault_rng)
            args += (tuple(dp(f) for f in fault),)
        return args

    # ---- crash-safe checkpointing (DESIGN.md §14) -------------------
    def _capture_rngs(self):
        """Snapshot every host-side seeded/stateful stream the run
        consumes (the breaker and participation log ride along — they
        mutate at staging time, so retry/resume must roll them back
        with the rngs)."""
        snap = {"task": self._rng.get_state()}
        if self._stale_rng is not None:
            snap["stale"] = self._stale_rng.get_state()
        if self._fault_rng is not None:
            snap["fault"] = self._fault_rng.get_state()
        if self._breaker is not None:
            snap["breaker"] = self._breaker.state_dict()
            snap["participation"] = (list(self.comm.participation)
                                     if self.comm is not None else [])
        return snap

    def _restore_rngs(self, snap):
        self._rng.set_state(snap["task"])
        if self._stale_rng is not None:
            self._stale_rng.set_state(snap["stale"])
        if self._fault_rng is not None:
            self._fault_rng.set_state(snap["fault"])
        if self._breaker is not None and "breaker" in snap:
            self._breaker.load_state(snap["breaker"])
            if self.comm is not None:
                self.comm.participation[:] = snap.get("participation", [])

    def save_checkpoint(self, state, round_: int, ckpt_dir=None) -> str:
        """Write one atomic checkpoint capturing everything a resumed
        run needs for bit-identical history: train state (φ, optimizer,
        staleness ring), the RNG states *as of round ``round_``* (under
        prefetching the live streams have already advanced past the
        checkpointed round — the engine hook uses the snapshot staged
        at that round's block boundary), CommTracker counters, and the
        flushed history."""
        from repro.checkpoint.io import save_server_state
        snap = self._rng_snaps.pop(round_, None) or self._capture_rngs()
        self._rng_snaps = {r: s for r, s in self._rng_snaps.items()
                           if r > round_}
        payload = {
            "round": int(round_),
            "state": state,
            "rng": {k: _rng_state_payload(snap[k])
                    for k in ("task", "stale", "fault") if k in snap},
            "comm_rounds": int(self.comm.rounds),
            "flops_per_client": float(self.comm.flops_per_client or 0.0),
            "history": list(self.history),
        }
        if "breaker" in snap:      # population plane host state
            payload["breaker"] = snap["breaker"]
            payload["participation"] = [list(p) for p in
                                        snap.get("participation", [])]
        return save_server_state(ckpt_dir or self.checkpoint_dir,
                                 round_, payload,
                                 keep_last=self.checkpoint_keep)

    def resume(self, ckpt_dir=None, step: int | None = None):
        """Restore a killed run from its latest (or ``step``-numbered)
        checkpoint. Call after ``init()``; returns ``(state,
        start_round)`` for ``run(state, rounds,
        start_round=start_round)`` — the resumed tail reproduces the
        uninterrupted run's history record-for-record."""
        from repro.checkpoint.io import load_server_state
        payload = load_server_state(ckpt_dir or self.checkpoint_dir, step)
        for name, rng in (("task", self._rng), ("stale", self._stale_rng),
                          ("fault", self._fault_rng)):
            if name in payload["rng"] and rng is not None:
                rng.set_state(_rng_state_from_payload(
                    payload["rng"][name]))
        self.comm.rounds = int(payload["comm_rounds"])
        if payload["flops_per_client"]:
            self.comm.flops_per_client = payload["flops_per_client"]
        if self._breaker is not None and payload.get("breaker") is not None:
            self._breaker.load_state(payload["breaker"])
        self.comm.participation[:] = [
            tuple(int(x) for x in p)
            for p in payload.get("participation", [])]
        self.history[:] = payload["history"]
        state = payload["state"]
        return state, int(payload["round"])

    def run(self, state, rounds: int, eval_every: int = 0,
            eval_clients=None, log: Callable = None,
            start_round: int = 0):
        """Drive ``rounds`` rounds through the async round engine
        (DESIGN.md §12). The default knobs (prefetch_depth=0,
        flush_every=1, fuse_rounds=1) reproduce the synchronous loop
        exactly; with staleness off, every pipelined configuration
        yields bit-identical history under the same seed. A record is
        appended EVERY round — convergence curves at full resolution,
        not subsampled to eval_every; eval fields only when evaluated.
        ``start_round`` continues a resumed run (see ``resume``)."""
        stream = TaskStream(self.train_clients, self.clients_per_round,
                            self.support_frac, self.support_size,
                            self.query_size, self._rng)
        dp = jax.device_put
        produced = {"r": start_round}   # prefetch-thread round cursor
        if self.pool_workers > 0:
            clients = self.train_clients
            self._pool = WorkerPool(lambda i: clients[i],
                                    workers=self.pool_workers,
                                    max_retries=self.pool_retries,
                                    task_timeout=self.task_timeout)

        def stage(k):
            # retry safety: a transiently failing stage() must not leak
            # partial stream draws (or breaker/participation state), or
            # the retry would see different tasks than the sync run
            entry = self._capture_rngs()
            try:
                if self._population_active:
                    args = self._stage_population(dp, produced["r"] + 1)
                else:
                    if self._pool is not None and k == 1:
                        # pre-warm the registry cache for the upcoming
                        # picks — peeked without consuming the stream,
                        # so staging stays bit-identical to the
                        # pool-less path
                        self._pool.map(
                            sorted({int(p) for p in self._peek_picks()}),
                            label=f"round {produced['r'] + 1} warm")
                    args = self._stage_block(stream, dp, k,
                                             produced["r"] + 1)
            except BaseException:
                self._restore_rngs(entry)
                raise
            produced["r"] += k
            if self.checkpoint_every:
                # rng states *after* this block = the states a resume
                # from its boundary round must start from
                self._rng_snaps[produced["r"]] = self._capture_rngs()
            return args

        evaluate = None
        if eval_every and eval_clients is not None:
            def evaluate(st):
                acc, _, loss = evaluate_meta(
                    self.algo, self.phi_tree(st), eval_clients,
                    support_frac=self.support_frac,
                    support_size=self.support_size,
                    query_size=self.query_size, seed=self.seed,
                    evaluator=self._evaluator)
                return {"eval_acc": acc, "eval_loss": loss}

        checkpoint = None
        if self.checkpoint_every and self.checkpoint_dir:
            checkpoint = lambda st, r: self.save_checkpoint(st, r)  # noqa: E731
        engine = AsyncRoundEngine(
            stage=stage, step=lambda st, a: self._step(st, *a),
            comm=self.comm, history=self.history, fused_step=self._fused,
            prefetch_depth=self.prefetch_depth,
            flush_every=self.flush_every, fuse_rounds=self.fuse_rounds,
            checkpoint=checkpoint,
            checkpoint_every=self.checkpoint_every,
            prefetch_retries=self.prefetch_retries)
        try:
            return engine.run(state, rounds, eval_every=eval_every,
                              evaluate=evaluate, log=log,
                              start_round=start_round)
        finally:
            if self._pool is not None:
                self._pool.close()   # no leaked worker threads, ever
                self._pool = None
