"""Federated training drivers: server round loop, client sampling,
communication accounting, and the paper's evaluation schemes.

Evaluation (paper §4.1 + A.2): accuracy w.r.t. all data points on held-out
*test clients*; each test client adapts on its support set (FedMeta /
FedAvg(Meta)) or not (FedAvg) and is scored on its query set.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedmeta import (_maybe_jit, init_packed_state,
                                make_meta_train_step,
                                make_packed_meta_train_step)
from repro.data.federated import (TaskStream, sample_task_batch,
                                  stack_task_batches)
from repro.federated.async_engine import AsyncRoundEngine, StalenessConfig
from repro.federated.comm import CommTracker, measure_client_flops
from repro.federated.faults import FaultConfig
from repro.kernels.meta_update import ops as mu_ops
from repro.optim import Optimizer
from repro.utils.flat import plane_for


def _rng_state_payload(state):
    """np.random.RandomState.get_state() tuple -> checkpointable dict
    (the 624-word key vector as an array, scalars as python types)."""
    alg, keys, pos, has_gauss, cached = state
    return {"alg": alg, "keys": np.asarray(keys, np.uint32),
            "pos": int(pos), "has_gauss": int(has_gauss),
            "cached": float(cached)}


def _rng_state_from_payload(p):
    return (str(p["alg"]), np.asarray(p["keys"], np.uint32),
            int(p["pos"]), int(p["has_gauss"]), float(p["cached"]))


def _batch_eval(eval_one, clients, m, support_frac, support_size, query_size,
                rng):
    tb = sample_task_batch(clients, m, support_frac, support_size, query_size,
                           rng)
    accs, losses = eval_one((tb.support_x, tb.support_y),
                            (tb.query_x, tb.query_y))
    counts = (np.ones((m,), np.float64) if tb.query_count is None
              else np.asarray(tb.query_count, np.float64))
    return np.asarray(accs), np.asarray(losses), counts


def _count_weighted(accs, losses, counts):
    """§4.1 evaluation: accuracy w.r.t. *all data points*, i.e. each
    client's (fixed-shape resampled) query accuracy weighted by the
    number of query examples that client actually holds — not an
    unweighted mean over clients. Same reduction for the loss."""
    w = counts / counts.sum()
    return float(np.sum(w * accs)), float(np.sum(w * losses))


def make_meta_evaluator(algo, adapt_steps=None):
    """Jitted once; φ passed as an argument (avoids per-eval recompiles)."""

    @jax.jit
    def eval_batch(phi, support, query):
        def one(s, q):
            theta_u = algo.adapt(phi, s, steps=adapt_steps)
            loss, met = algo.eval_fn(theta_u, q)
            return met["accuracy"], loss
        return jax.vmap(one)(support, query)

    return eval_batch


def make_global_evaluator(eval_fn, finetune: Optional[Callable] = None):
    @jax.jit
    def eval_batch(theta, support, query):
        def one(s, q):
            th = theta if finetune is None else finetune(theta, s)
            loss, met = eval_fn(th, q)
            return met["accuracy"], loss
        return jax.vmap(one)(support, query)

    return eval_batch


def evaluate_meta(algo, phi, clients, *, support_frac, support_size,
                  query_size, seed=0, adapt_steps=None, evaluator=None):
    """Per-client adapted accuracy over all test clients; returns
    (acc, per_client_accs, mean_loss) with acc and mean_loss weighted by
    each client's true query count (§4.1). Pass a `make_meta_evaluator`
    result to amortize compilation across calls."""
    rng = np.random.RandomState(seed)
    ev = evaluator or make_meta_evaluator(algo, adapt_steps)
    accs, losses, counts = _batch_eval(
        lambda s, q: ev(phi, s, q), clients, len(clients), support_frac,
        support_size, query_size, rng)
    acc, loss = _count_weighted(accs, losses, counts)
    return acc, accs, loss


def evaluate_global(eval_fn, theta, clients, *, support_frac, support_size,
                    query_size, seed=0, finetune: Optional[Callable] = None,
                    evaluator=None):
    """FedAvg (finetune=None) / FedAvg(Meta) (finetune=trainer.finetune).
    Returns (acc, per_client_accs, mean_loss), query-count-weighted like
    `evaluate_meta`."""
    rng = np.random.RandomState(seed)
    ev = evaluator or make_global_evaluator(eval_fn, finetune)
    accs, losses, counts = _batch_eval(
        lambda s, q: ev(theta, s, q), clients, len(clients), support_frac,
        support_size, query_size, rng)
    acc, loss = _count_weighted(accs, losses, counts)
    return acc, accs, loss


@dataclasses.dataclass
class FederatedTrainer:
    """FedMeta meta-training loop (Algorithm 1 AlgorithmUpdate)."""
    algo: object
    optimizer: Optimizer
    train_clients: list
    clients_per_round: int
    support_frac: float
    support_size: int
    query_size: int
    weighted: bool = True          # paper A.2: weight by local data count
    client_axis: str = "vmap"
    seed: int = 0
    client_chunk: Optional[int] = None   # for client_axis="chunked"
    packed: bool = False                 # packed parameter plane pipeline
    impl: Optional[str] = None           # fused-kernel impl for packed
    block_dtype: Optional[object] = None  # client-grad block dtype (packed)
    client_plane: bool = False  # fused flat inner loop (packed only)
    mesh: Optional[object] = None  # for client_axis="sharded" (None =
    mesh_axis: Optional[str] = None  # ambient mesh, first axis)
    # ---- async round engine (DESIGN.md §12) -------------------------
    prefetch_depth: int = 0     # staged round blocks ahead; 0 = sync loop
    flush_every: int = 1        # drain deferred metrics every k rounds
                                # (0 = only at eval rounds / run() exit)
    fuse_rounds: int = 1        # lax.scan-over-rounds block size (packed)
    staleness: Optional[StalenessConfig] = None  # packed + vmap axis only
    # ---- failure plane (DESIGN.md §14) ------------------------------
    aggregator: str = "mean"    # mean | masked_mean | screen | trimmed
    screen_factor: float = 3.0  # screen: clip rows > factor × median ‖g‖
    trim: int = 1               # trimmed: per-coordinate trim count
    faults: Optional[FaultConfig] = None  # packed + vmap axis only
    guard: Optional[bool] = None  # non-finite skip-round guard; None =
                                  # auto (on iff faults or robust agg)
    prefetch_retries: int = 0   # transient staging failures retried
    checkpoint_every: int = 0   # rounds between checkpoints (0 = off)
    checkpoint_dir: Optional[str] = None
    checkpoint_keep: int = 3    # keep-last-k retention

    def __post_init__(self):
        if self.client_plane and not self.packed:
            raise ValueError("client_plane=True requires packed=True")
        if self.fuse_rounds > 1 and not self.packed:
            raise ValueError("fuse_rounds>1 (fused-K round blocks) is a "
                             "packed-pipeline mode")
        if self.staleness is not None:
            if not self.packed or self.client_axis != "vmap":
                raise ValueError("staleness-aware aggregation requires "
                                 "packed=True and client_axis='vmap'")
            if self.fuse_rounds > 1:
                raise ValueError("staleness and fuse_rounds>1 are mutually "
                                 "exclusive (stragglers need per-round "
                                 "straggler picks)")
        if self.aggregator not in mu_ops.AGGREGATORS:
            raise ValueError(f"unknown aggregator {self.aggregator!r}; "
                             f"expected one of {mu_ops.AGGREGATORS}")
        if self.faults is not None or self.aggregator != "mean":
            if not self.packed or self.client_axis != "vmap":
                raise ValueError("fault injection / robust aggregation "
                                 "need the full (m, N) client block — "
                                 "packed=True and client_axis='vmap'")
        if self.faults is not None and self.fuse_rounds > 1:
            raise ValueError("faults and fuse_rounds>1 are mutually "
                             "exclusive (failures need per-round picks)")
        if self.aggregator == "trimmed" and \
                2 * self.trim >= self.clients_per_round:
            raise ValueError(f"trimmed mean needs 2·trim < clients_per_"
                             f"round ({self.trim} vs "
                             f"{self.clients_per_round})")
        if self.guard is None:
            # auto: any failure-plane knob needs skip-round semantics
            self.guard = (self.faults is not None or
                          self.aggregator != "mean")
        if self.guard and not self.packed:
            raise ValueError("the non-finite guard is a flat-plane check "
                             "— packed=True only")
        # the packed step needs φ's FlatPlane, built in init(); the tree
        # step has no such dependency and is built eagerly
        self._step = None if self.packed else make_meta_train_step(
            self.algo, self.optimizer, client_axis=self.client_axis,
            client_chunk=self.client_chunk, mesh=self.mesh,
            mesh_axis=self.mesh_axis)
        self._fused = None
        self._plane = None
        self._rng = np.random.RandomState(self.seed)
        self._stale_rng = (np.random.RandomState(self.staleness.seed)
                           if self.staleness is not None else None)
        self._fault_rng = (np.random.RandomState(self.faults.seed)
                           if self.faults is not None else None)
        self._rng_snaps: dict = {}   # round -> rng states (prefetch-safe)
        self._evaluator = make_meta_evaluator(self.algo)
        self.comm: Optional[CommTracker] = None
        self.history: list = []

    def init(self, key, model_init):
        phi = self.algo.init_state(key, model_init)
        if self.packed:
            self._plane = plane_for(phi)
            kw = dict(client_axis=self.client_axis,
                      client_chunk=self.client_chunk, impl=self.impl,
                      block_dtype=self.block_dtype,
                      client_plane=self.client_plane,
                      staleness=self.staleness,
                      aggregator=self.aggregator,
                      screen_factor=self.screen_factor, trim=self.trim,
                      faults=self.faults, guard=bool(self.guard),
                      mesh=self.mesh, mesh_axis=self.mesh_axis)
            self._step = make_packed_meta_train_step(
                self.algo, self.optimizer, self._plane, **kw)
            if self.fuse_rounds > 1:
                # scan-over-rounds on the SAME (unjitted) step body the
                # per-round path compiles — fused-K blocks must be
                # bit-identical to K per-round steps
                body = make_packed_meta_train_step(
                    self.algo, self.optimizer, self._plane, jit=False,
                    donate=False, **kw)

                def fused(state, staged):
                    def one(st, inp):
                        sup, qry, w = inp
                        return body(st, sup, qry, w)
                    return jax.lax.scan(one, state, staged)

                self._fused = _maybe_jit(fused, True, True)
            state = init_packed_state(
                self.optimizer, self._plane, phi, staleness=self.staleness,
                clients_per_round=self.clients_per_round,
                block_dtype=self.block_dtype)
        else:
            state = {"phi": phi, "opt": self.optimizer.init(phi)}
        self.comm = CommTracker.for_state(
            phi, self.clients_per_round,
            block_dtype=self.block_dtype if self.packed else None)
        return state

    def phi_tree(self, state):
        """φ as a pytree regardless of parameter representation."""
        if self.packed:
            return self._plane.unpack(state["phi"])
        return state["phi"]

    def evaluator(self):
        """The trainer's jitted meta-evaluator — pass to `evaluate_meta`
        to amortize compilation across eval calls."""
        return self._evaluator

    def measure_flops(self, state):
        """One-off XLA cost analysis of the client procedure."""
        tb = sample_task_batch(self.train_clients, 1, self.support_frac,
                               self.support_size, self.query_size, self._rng)
        sup = jax.tree.map(lambda x: jnp.asarray(x[0]),
                           (tb.support_x, tb.support_y))
        qry = jax.tree.map(lambda x: jnp.asarray(x[0]),
                           (tb.query_x, tb.query_y))
        fl = measure_client_flops(
            lambda s, q: self.algo.client_grad(self.phi_tree(state), s, q)[0],
            sup, qry)
        if self.comm:
            self.comm.flops_per_client = fl
        return fl

    def _stage_block(self, stream, dp, k):
        """Host half of one round block: sample + device_put staging.
        Runs on the prefetch thread (in block order) when pipelined."""
        if k > 1:   # fused-K: one stacked (k, m, ...) staged buffer
            tb = stack_task_batches(stream.take(k))
            return ((dp(tb.support_x), dp(tb.support_y)),
                    (dp(tb.query_x), dp(tb.query_y)),
                    dp(tb.weight) if self.weighted else None)
        tb = stream.next()
        args = ((dp(tb.support_x), dp(tb.support_y)),
                (dp(tb.query_x), dp(tb.query_y)),
                dp(tb.weight) if self.weighted else None)
        if self.staleness is not None:
            # (straggler_idx, fresh_idx[, delays]) — delays only
            # with jitter on, so the off-path stays bit-identical
            sel = self.staleness.pick(
                self.clients_per_round, self._stale_rng)
            args += (tuple(dp(s) for s in sel),)
        elif self.faults is not None:
            args += (None,)   # stale_sel placeholder (positional call)
        if self.faults is not None:
            fault = self.faults.pick(
                self.clients_per_round, self._fault_rng)
            args += (tuple(dp(f) for f in fault),)
        return args

    # ---- crash-safe checkpointing (DESIGN.md §14) -------------------
    def _capture_rngs(self):
        """Snapshot every host-side seeded stream the run consumes."""
        snap = {"task": self._rng.get_state()}
        if self._stale_rng is not None:
            snap["stale"] = self._stale_rng.get_state()
        if self._fault_rng is not None:
            snap["fault"] = self._fault_rng.get_state()
        return snap

    def _restore_rngs(self, snap):
        self._rng.set_state(snap["task"])
        if self._stale_rng is not None:
            self._stale_rng.set_state(snap["stale"])
        if self._fault_rng is not None:
            self._fault_rng.set_state(snap["fault"])

    def save_checkpoint(self, state, round_: int, ckpt_dir=None) -> str:
        """Write one atomic checkpoint capturing everything a resumed
        run needs for bit-identical history: train state (φ, optimizer,
        staleness ring), the RNG states *as of round ``round_``* (under
        prefetching the live streams have already advanced past the
        checkpointed round — the engine hook uses the snapshot staged
        at that round's block boundary), CommTracker counters, and the
        flushed history."""
        from repro.checkpoint.io import save_server_state
        snap = self._rng_snaps.pop(round_, None) or self._capture_rngs()
        self._rng_snaps = {r: s for r, s in self._rng_snaps.items()
                           if r > round_}
        payload = {
            "round": int(round_),
            "state": state,
            "rng": {k: _rng_state_payload(s) for k, s in snap.items()},
            "comm_rounds": int(self.comm.rounds),
            "flops_per_client": float(self.comm.flops_per_client or 0.0),
            "history": list(self.history),
        }
        return save_server_state(ckpt_dir or self.checkpoint_dir,
                                 round_, payload,
                                 keep_last=self.checkpoint_keep)

    def resume(self, ckpt_dir=None, step: int | None = None):
        """Restore a killed run from its latest (or ``step``-numbered)
        checkpoint. Call after ``init()``; returns ``(state,
        start_round)`` for ``run(state, rounds,
        start_round=start_round)`` — the resumed tail reproduces the
        uninterrupted run's history record-for-record."""
        from repro.checkpoint.io import load_server_state
        payload = load_server_state(ckpt_dir or self.checkpoint_dir, step)
        for name, rng in (("task", self._rng), ("stale", self._stale_rng),
                          ("fault", self._fault_rng)):
            if name in payload["rng"] and rng is not None:
                rng.set_state(_rng_state_from_payload(
                    payload["rng"][name]))
        self.comm.rounds = int(payload["comm_rounds"])
        if payload["flops_per_client"]:
            self.comm.flops_per_client = payload["flops_per_client"]
        self.history[:] = payload["history"]
        state = payload["state"]
        return state, int(payload["round"])

    def run(self, state, rounds: int, eval_every: int = 0,
            eval_clients=None, log: Callable = None,
            start_round: int = 0):
        """Drive ``rounds`` rounds through the async round engine
        (DESIGN.md §12). The default knobs (prefetch_depth=0,
        flush_every=1, fuse_rounds=1) reproduce the synchronous loop
        exactly; with staleness off, every pipelined configuration
        yields bit-identical history under the same seed. A record is
        appended EVERY round — convergence curves at full resolution,
        not subsampled to eval_every; eval fields only when evaluated.
        ``start_round`` continues a resumed run (see ``resume``)."""
        stream = TaskStream(self.train_clients, self.clients_per_round,
                            self.support_frac, self.support_size,
                            self.query_size, self._rng)
        dp = jax.device_put
        produced = {"r": start_round}   # prefetch-thread round cursor

        def stage(k):
            # retry safety: a transiently failing stage() must not leak
            # partial stream draws, or the retry would see different
            # tasks than the synchronous run
            entry = self._capture_rngs()
            try:
                args = self._stage_block(stream, dp, k)
            except BaseException:
                self._restore_rngs(entry)
                raise
            produced["r"] += k
            if self.checkpoint_every:
                # rng states *after* this block = the states a resume
                # from its boundary round must start from
                self._rng_snaps[produced["r"]] = self._capture_rngs()
            return args

        evaluate = None
        if eval_every and eval_clients is not None:
            def evaluate(st):
                acc, _, loss = evaluate_meta(
                    self.algo, self.phi_tree(st), eval_clients,
                    support_frac=self.support_frac,
                    support_size=self.support_size,
                    query_size=self.query_size, seed=self.seed,
                    evaluator=self._evaluator)
                return {"eval_acc": acc, "eval_loss": loss}

        checkpoint = None
        if self.checkpoint_every and self.checkpoint_dir:
            checkpoint = lambda st, r: self.save_checkpoint(st, r)  # noqa: E731
        engine = AsyncRoundEngine(
            stage=stage, step=lambda st, a: self._step(st, *a),
            comm=self.comm, history=self.history, fused_step=self._fused,
            prefetch_depth=self.prefetch_depth,
            flush_every=self.flush_every, fuse_rounds=self.fuse_rounds,
            checkpoint=checkpoint,
            checkpoint_every=self.checkpoint_every,
            prefetch_retries=self.prefetch_retries)
        return engine.run(state, rounds, eval_every=eval_every,
                          evaluate=evaluate, log=log,
                          start_round=start_round)
