"""Population plane: deterministic client unreliability, round
deadlines with over-selection, and the per-client circuit breaker.

The paper's deployment setting is massive fleets of *unreliable*
mobile devices (§1; Li et al. 1908.07873 name availability as the
central federated-systems challenge). This module is the host-side
model of that unreliability and the server's standard production
countermeasures (deadline/partial-participation schemes à la the
Liu et al. 2210.13111 survey):

  * `UnreliabilityConfig` — a *stateless* seeded per-(client, round)
    latency/failure model. Every draw is a pure function of
    ``(seed, client, round)``, so arrival outcomes are deterministic
    regardless of worker-thread scheduling, prefetch depth, or resume
    point — nothing to checkpoint, nothing to race on. Disjoint by
    construction from PR 6's in-graph `FaultConfig` rng (which corrupts
    gradients of clients that DID arrive; this plane decides who
    arrives at all).
  * `plan_round` — the deadline + over-selection arithmetic: which of
    a round's ``m·(1+over_select)`` candidates fail, which miss the
    deadline, and the first ``m`` arrivals in latency order.
  * `CircuitBreaker` — quarantines clients whose shards repeatedly
    fail: ``threshold`` consecutive failures open the breaker for
    ``cooldown`` rounds (the client is excluded from selection), after
    which it half-opens — one trial pick; a success closes it, another
    failure re-opens it immediately.

The trainer composes these with the worker pool
(`async_engine.WorkerPool`) and routes the arrived-set shortfall
through the `masked_mean` renormalizing aggregator (DESIGN.md §15).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def _draw_rng(*entropy) -> np.random.RandomState:
    return np.random.RandomState(
        np.random.MT19937(np.random.SeedSequence(entropy)))


@dataclasses.dataclass(frozen=True)
class UnreliabilityConfig:
    """Seeded per-client latency/failure model (stateless draws).

    ``fail_rate`` is the per-(client, round) transient failure
    probability; ``chronic_frac`` marks a per-client fraction of the
    population that *always* fails (the dead-device tail the circuit
    breaker exists for). Latency is lognormal per client (median
    ``latency_mean``, spread ``latency_sigma`` across clients) times a
    per-round lognormal jitter (``jitter_sigma``) — slow clients are
    persistently slow, with round-to-round variation. Units are
    arbitrary but shared with ``round_deadline``.

    >>> u = UnreliabilityConfig(fail_rate=0.5, seed=1)
    >>> u.draw(3, 7) == u.draw(3, 7)   # pure function of (client, round)
    True
    """
    fail_rate: float = 0.1
    chronic_frac: float = 0.0
    latency_mean: float = 1.0
    latency_sigma: float = 0.5
    jitter_sigma: float = 0.25
    seed: int = 0

    def __post_init__(self):
        for f in ("fail_rate", "chronic_frac"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")

    def client_profile(self, client: int):
        """(chronic, base_latency) — the fixed per-client draws."""
        rng = _draw_rng(self.seed, int(client))
        chronic = rng.random_sample() < self.chronic_frac
        base = float(np.exp(rng.normal(np.log(self.latency_mean),
                                       self.latency_sigma)))
        return chronic, base

    def draw(self, client: int, round_: int):
        """(failed, latency) for one (client, round) pair."""
        chronic, base = self.client_profile(client)
        rng = _draw_rng(self.seed, int(client), int(round_))
        failed = chronic or rng.random_sample() < self.fail_rate
        latency = base * float(np.exp(rng.normal(0.0, self.jitter_sigma)))
        return bool(failed), latency


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One round's deterministic arrival outcome (all int64 arrays of
    client ids). ``arrived`` is in arrival (latency, then candidate)
    order, at most ``m`` long; ``failed`` never responded; ``late``
    responded after the deadline (alive, just slow); ``surplus`` beat
    the deadline but lost the first-m race (their upload is discarded
    — over-selection's deliberate waste)."""
    candidates: np.ndarray
    arrived: np.ndarray
    failed: np.ndarray
    late: np.ndarray
    surplus: np.ndarray
    latencies: np.ndarray    # per-candidate, NaN for failed


def plan_round(candidates, round_: int,
               unreliability: Optional[UnreliabilityConfig],
               deadline: Optional[float], m: int) -> RoundPlan:
    """Deadline + over-selection arithmetic for one round.

    With no unreliability model every candidate "arrives" instantly in
    candidate order (latency 0) — the first ``m`` are taken, the rest
    are surplus. Determinism: outcomes depend only on the candidate
    ids, the round index, and the config — never on wall-clock or
    thread scheduling (the worker pool does the *work*; this plan
    decides the *outcome*).
    """
    cand = np.asarray(candidates, np.int64)
    n = len(cand)
    if unreliability is None:
        failed = np.zeros(n, bool)
        lat = np.zeros(n, np.float64)
    else:
        drawn = [unreliability.draw(int(c), int(round_)) for c in cand]
        failed = np.array([d[0] for d in drawn], bool)
        lat = np.array([d[1] for d in drawn], np.float64)
    on_time = ~failed if deadline is None else (~failed) & (lat <= deadline)
    # arrival order: latency, candidate position as the tie-breaker
    order = np.lexsort((np.arange(n), np.where(on_time, lat, np.inf)))
    ok = order[on_time[order]]
    arrived = cand[ok[:m]]
    surplus = cand[np.sort(ok[m:])]
    late = cand[(~failed) & ~on_time]
    return RoundPlan(candidates=cand, arrived=arrived,
                     failed=cand[failed], late=late, surplus=surplus,
                     latencies=np.where(failed, np.nan, lat))


@dataclasses.dataclass
class CircuitBreaker:
    """Per-client quarantine of repeatedly failing shards.

    closed --threshold consecutive failures--> open (excluded from
    selection for ``cooldown`` rounds) --cooldown elapses--> half-open
    (selectable again; one trial) --success--> closed / --failure-->
    open again immediately.

    >>> b = CircuitBreaker(threshold=2, cooldown=3)
    >>> b.record_failure(5, 1); b.record_failure(5, 2)
    >>> b.state(5, 3), b.state(5, 2 + 1 + 3)
    ('open', 'half_open')
    """
    threshold: int = 3
    cooldown: int = 10

    def __post_init__(self):
        if self.threshold < 1 or self.cooldown < 1:
            raise ValueError("threshold and cooldown must be >= 1")
        self._fails: dict = {}        # client -> consecutive failures
        self._open_until: dict = {}   # client -> first half-open round

    def record_failure(self, client: int, round_: int):
        n = self._fails.get(client, 0) + 1
        if n >= self.threshold:
            # trip: quarantined for `cooldown` rounds after this one;
            # count held at threshold-1 so the half-open trial's single
            # failure re-trips immediately
            self._open_until[client] = round_ + 1 + self.cooldown
            self._fails[client] = self.threshold - 1
        else:
            self._fails[client] = n

    def record_success(self, client: int):
        self._fails.pop(client, None)
        self._open_until.pop(client, None)   # half-open trial succeeded

    def state(self, client: int, round_: int) -> str:
        if client in self._open_until:
            return ("open" if round_ < self._open_until[client]
                    else "half_open")
        return "closed"

    def blocked(self, round_: int) -> set:
        """Clients excluded from round ``round_``'s selection."""
        return {c for c, r in self._open_until.items() if round_ < r}

    def state_dict(self) -> dict:
        return {"fails": [[int(c), int(n)] for c, n in
                          sorted(self._fails.items())],
                "open": [[int(c), int(r)] for c, r in
                         sorted(self._open_until.items())]}

    def load_state(self, d: dict):
        self._fails = {int(c): int(n) for c, n in d.get("fails", [])}
        self._open_until = {int(c): int(r) for c, r in d.get("open", [])}
