"""Personalized serving plane: adaptation-on-demand (paper §3.2).

The deployment half of FedMeta: an incoming client request carries a
support set D_S^u; the server (or the device runtime) adapts the
meta-learned initialization θ to that client and answers queries with
θ_u. This module turns that story into an engine:

  TrafficModel      seeded synthetic open-loop traffic — Poisson
                    arrivals, Zipf-skewed client popularity,
                    heterogeneous support-set sizes, per-client think
                    time. Every draw is a pure function of
                    (seed, request id) via the same stateless
                    `SeedSequence` pattern as `population._draw_rng`,
                    so the request stream is identical under any batch
                    schedule.
  AdaptationCache   bounded thread-safe LRU of adapted flat rows φ_u,
                    keyed (client, φ-version, support digest) — the
                    `ClientRegistry` cache discipline (leaf lock,
                    hit/miss/eviction/peak counters).
  ServingEngine     batches concurrent cache-miss adaptations through
                    `MetaAlgorithm.adapt_packed_batch` — the SAME fused
                    `inner_update` (chunk, N) plane kernel that powers
                    training — then serves queries through the
                    prefill + flash-decode path, vmapped across
                    requests with per-request adapted parameters.

Bit-identity contract: plane rows are independent (row c only enters
client c's loss), so every served φ_u is bit-identical to that
client's solo `jax.jit(adapt)` / `jax.jit(adapt_packed)` — at any
batch size, under any batch composition — pinned by
tests/test_serving.py. Padding rows (partial batches are padded to the
compiled batch size by replicating the last request) therefore never
perturb real rows. The identity holds between *jitted* paths (training
is always jitted); eager op-by-op dispatch fuses differently and can
drift by 1 ulp.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.population import _draw_rng
from repro.utils.flat import plane_for

__all__ = ["TrafficModel", "AdaptationCache", "ServeRequest",
           "ServingEngine", "ServeReport", "support_digest"]


# ----------------------------------------------------------- traffic model

@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One serving request: client u asks for `prompt` to be continued
    under its personalized model, supplying the support set to adapt
    with. `arrival` is the (simulated) arrival time in seconds."""
    rid: int
    client: int
    arrival: float
    support: Any
    prompt: Any = None


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Seeded synthetic serving traffic.

    Arrivals are Poisson with mean `rate` requests/s; the requesting
    client is Zipf-skewed (popularity ∝ rank^-hot_skew, so a small hot
    set dominates — what makes the adaptation cache earn its keep);
    each *client* owns a support-set size drawn uniformly from
    `support_sizes` (its on-device data is stable across requests, so
    repeat requests from a client hit the adaptation cache); and a
    client never issues two requests closer than `think_time` seconds
    (its later arrival is floored to previous + think_time, then the
    table is re-sorted by time).

    Everything is a pure function of `seed`: the arrival table is drawn
    from one `_draw_rng(seed, _TABLE_SALT)` stream, each client's
    support set from `_draw_rng(seed, salt, client)`, and each
    request's prompt from `_draw_rng(seed, salt, rid)` — so the stream
    an engine sees is identical no matter how requests are batched or
    replayed (pinned by tests/test_serving.py).
    """
    num_clients: int = 32
    rate: float = 8.0
    support_sizes: tuple = (2, 4)
    hot_skew: float = 1.0
    think_time: float = 0.0
    seed: int = 0

    _TABLE_SALT = 0x5EF1
    _SUPPORT_SALT = 0x5EF2
    _PROMPT_SALT = 0x5EF3

    def arrival_table(self, n: int) -> tuple:
        """First `n` arrivals as ((rid, client, time, support_size), ...),
        sorted by (time, rid). Pure function of (seed, n), and
        content-stable under extension: every rid < m row of
        `arrival_table(n)` equals its `arrival_table(m)` row for m <= n
        (each field draws from its own salted stream, and think-time
        flooring is causal in rid order) — only sort *positions* can
        shift when later arrivals interleave."""
        gaps = _draw_rng(self.seed, self._TABLE_SALT, 0).exponential(
            1.0 / self.rate, size=n)
        times = np.cumsum(gaps)
        ranks = np.arange(self.num_clients, dtype=np.float64)
        w = (ranks + 1.0) ** -self.hot_skew
        clients = _draw_rng(self.seed, self._TABLE_SALT, 1).choice(
            self.num_clients, size=n, p=w / w.sum())
        by_client = _draw_rng(self.seed, self._TABLE_SALT, 2).choice(
            np.asarray(self.support_sizes), size=self.num_clients)
        sizes = by_client[clients]
        if self.think_time > 0.0:
            last: dict = {}
            for i in range(n):          # rid order == raw arrival order
                c = int(clients[i])
                floor = last.get(c)
                if floor is not None and times[i] < floor + self.think_time:
                    times[i] = floor + self.think_time
                last[c] = times[i]
        order = sorted(range(n), key=lambda i: (times[i], i))
        return tuple((i, int(clients[i]), float(times[i]), int(sizes[i]))
                     for i in order)

    def requests(self, n: int, make_support: Callable,
                 make_prompt: Optional[Callable] = None) -> tuple:
        """Materialize the first `n` requests. `make_support(rng, size)`
        (and optionally `make_prompt(rng)`) build the task-specific
        payloads from a stateless keyed RandomState — supports per
        *client* (stable on-device data), prompts per *request* — so
        content never depends on processing order."""
        out = []
        for rid, client, t, size in self.arrival_table(n):
            sup = make_support(
                _draw_rng(self.seed, self._SUPPORT_SALT, client), size)
            prm = (make_prompt(_draw_rng(self.seed, self._PROMPT_SALT, rid))
                   if make_prompt is not None else None)
            out.append(ServeRequest(rid=rid, client=client, arrival=t,
                                    support=sup, prompt=prm))
        return tuple(out)


# -------------------------------------------------------- adaptation cache

def support_digest(support) -> str:
    """Content digest of a support pytree (shape/dtype/bytes of every
    leaf, in canonical traversal order) — the cache-key component that
    invalidates a client's cached φ_u when its on-device data changes."""
    h = hashlib.sha1()
    for leaf in jax.tree.leaves(support):
        a = np.asarray(leaf)
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class AdaptationCache:
    """Bounded thread-safe LRU of adapted flat rows, keyed
    (client, φ-version, support digest).

    Same cache discipline as `data.registry.ClientRegistry`:
    ``self._lock`` is a **leaf** lock guarding only the store and the
    counters, never held across a blocking call, and
    ``stats()["peak_resident"]`` proves the bound. ``capacity=None``
    means unbounded."""

    def __init__(self, capacity: Optional[int] = 64):
        if capacity is not None and capacity < 1:
            raise ValueError("capacity must be >= 1 (or None)")
        self.capacity = capacity
        self._store: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self._hits = self._misses = self._evictions = 0
        self._peak = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def get(self, key):
        with self._lock:
            if key in self._store:
                self._hits += 1
                self._store.move_to_end(key)
                return self._store[key]
            self._misses += 1
            return None

    def put(self, key, row) -> None:
        with self._lock:
            self._store[key] = row
            self._store.move_to_end(key)
            cap = self.capacity
            while cap is not None and len(self._store) > cap:
                self._store.popitem(last=False)
                self._evictions += 1
            self._peak = max(self._peak, len(self._store))

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "resident": len(self._store),
                    "peak_resident": self._peak,
                    "capacity": self.capacity}

    def clear(self) -> None:
        """Drop entries and counters (bench warmup→measure reset)."""
        with self._lock:
            self._store.clear()
            self._hits = self._misses = self._evictions = 0
            self._peak = 0


# ----------------------------------------------------------- serve report

@dataclasses.dataclass
class ServeReport:
    """Per-request records + wall time for one `ServingEngine.serve`."""
    records: list
    wall_s: float
    cache_stats: dict

    def summary(self) -> dict:
        n = len(self.records)
        hits = sum(1 for r in self.records if r["hit"])
        adapt = np.asarray([r["adapt_ms"] for r in self.records], np.float64)
        out = {"requests": n, "hits": hits, "misses": n - hits,
               "wall_s": self.wall_s,
               "requests_per_s": (n / self.wall_s if self.wall_s > 0
                                  else float("inf")),
               "adapt_p50_ms": float(np.percentile(adapt, 50)) if n else 0.0,
               "adapt_p99_ms": float(np.percentile(adapt, 99)) if n else 0.0,
               "cache": self.cache_stats}
        dec = np.asarray([r["decode_ms"] for r in self.records
                          if r.get("decode_ms") is not None], np.float64)
        if dec.size:
            out["decode_p50_ms"] = float(np.percentile(dec, 50))
            out["decode_p99_ms"] = float(np.percentile(dec, 99))
        return out


# ----------------------------------------------------------- serving engine

def _shape_sig(tree) -> tuple:
    return tuple((np.shape(x), str(np.asarray(x).dtype))
                 for x in jax.tree.leaves(tree))


class ServingEngine:
    """Adaptation-on-demand: batch concurrent support-set adaptations
    on the training kernel's (chunk, N) plane, cache φ_u rows, serve
    decode.

    `serve(requests)` processes requests in arrival order:

      1. cache lookup under (client, φ-version, support digest) — a hit
         skips adaptation entirely (adapt_ms = 0);
      2. misses are bucketed by support *shape signature* (heterogeneous
         sizes never share a compiled executable), and each bucket is
         flushed through the jitted `adapt_packed_batch` when it holds
         `adapt_batch` requests — partial buckets at end-of-stream are
         padded to `adapt_batch` by replicating the last request, which
         is sound because plane rows are independent;
      3. with `max_new_tokens > 0` and prefill/decode fns wired in,
         adapted requests are grouped by prompt shape and decoded
         vmapped-across-requests, each request under its own φ_u.

    Duplicate keys inside one un-flushed bucket are not coalesced: they
    occupy separate rows, which is wasteful but bit-identical (the
    second write wins with an equal row). The engine is a
    single-threaded orchestrator; only `AdaptationCache` is shared.
    """

    def __init__(self, algo, phi, *, adapt_batch: int = 4,
                 adapt_steps: Optional[int] = None,
                 cache: Optional[AdaptationCache] = None,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 impl: Optional[str] = None, phi_version: int = 0):
        if adapt_batch < 1:
            raise ValueError("adapt_batch must be >= 1")
        self.algo = algo
        self.adapt_batch = int(adapt_batch)
        self.adapt_steps = adapt_steps
        self.cache = cache if cache is not None else AdaptationCache()
        self.phi_version = int(phi_version)
        self._phi = phi
        self.plane = plane_for(phi["theta"])
        self._prefill_fn = prefill_fn
        self._decode_fn = decode_fn
        self._gen_fns: dict = {}

        plane = self.plane

        def _adapt(phi_, supports):
            return algo.adapt_packed_batch(phi_, supports, adapt_steps,
                                           impl=impl, plane=plane)

        self._adapt = jax.jit(_adapt)

    # -- φ lifecycle ------------------------------------------------------

    def publish_phi(self, phi) -> None:
        """Install a fresh meta-initialization. Bumps the φ-version so
        every cached row goes stale by keying (no eager invalidation —
        stale entries age out of the LRU)."""
        self._phi = phi
        self.phi_version += 1

    def unpack_row(self, row):
        """Adapted flat row -> parameter pytree (serving-side θ_u)."""
        return self.plane.unpack(row)

    # -- adaptation -------------------------------------------------------

    def _flush(self, items: list, records: dict) -> None:
        t0 = time.perf_counter()
        reqs = [r for r, _ in items]
        padded = reqs + [reqs[-1]] * (self.adapt_batch - len(reqs))
        supports = jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[r.support for r in padded])
        rows = jax.block_until_ready(self._adapt(self._phi, supports))
        wall_ms = (time.perf_counter() - t0) * 1e3
        for i, (req, key) in enumerate(items):
            row = rows[i]
            self.cache.put(key, row)
            records[req.rid] = {"rid": req.rid, "client": req.client,
                                "arrival": req.arrival, "hit": False,
                                "adapt_ms": wall_ms, "batch_fill": len(items),
                                "row": row}

    # -- decode -----------------------------------------------------------

    def _generate_fn(self, max_new_tokens: int):
        fn = self._gen_fns.get(max_new_tokens)
        if fn is not None:
            return fn
        plane, prefill, decode = self.plane, self._prefill_fn, self._decode_fn

        def gen_one(row, prompt):
            params = plane.unpack(row)
            logits, cache = prefill(params, prompt[None])
            tok = jnp.argmax(logits, -1).astype(jnp.int32)   # (1,)

            def step(carry, _):
                t, c = carry
                lg, c = decode(params, c, t[:, None])
                nt = jnp.argmax(lg, -1).astype(jnp.int32)
                return (nt, c), nt

            if max_new_tokens == 1:
                return tok
            (_, _), rest = jax.lax.scan(step, (tok, cache), None,
                                        length=max_new_tokens - 1)
            return jnp.concatenate([tok[None], rest], axis=0)[:, 0]

        fn = jax.jit(jax.vmap(gen_one))
        self._gen_fns[max_new_tokens] = fn
        return fn

    # -- the serve loop ---------------------------------------------------

    def serve(self, requests, *, max_new_tokens: int = 0) -> ServeReport:
        """Serve a request stream (processed in (arrival, rid) order).
        Returns a `ServeReport`; each record carries the adapted flat
        row under "row" (unpack with `unpack_row`) and, when decoding,
        the generated tokens under "tokens"."""
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        t_start = time.perf_counter()
        records: dict = {}
        buckets: OrderedDict = OrderedDict()
        for req in reqs:
            key = (req.client, self.phi_version, support_digest(req.support))
            row = self.cache.get(key)
            if row is not None:
                records[req.rid] = {"rid": req.rid, "client": req.client,
                                    "arrival": req.arrival, "hit": True,
                                    "adapt_ms": 0.0, "batch_fill": 0,
                                    "row": row}
                continue
            sig = _shape_sig(req.support)
            buckets.setdefault(sig, []).append((req, key))
            if len(buckets[sig]) == self.adapt_batch:
                self._flush(buckets.pop(sig), records)
        for sig in list(buckets):       # insertion order — deterministic
            self._flush(buckets.pop(sig), records)

        if max_new_tokens > 0:
            if self._prefill_fn is None or self._decode_fn is None:
                raise ValueError("decode requested but the engine has no "
                                 "prefill_fn/decode_fn wired in")
            gen = self._generate_fn(max_new_tokens)
            groups: OrderedDict = OrderedDict()
            for req in reqs:
                if req.prompt is not None:
                    groups.setdefault(np.shape(req.prompt), []).append(req)
            for shape in list(groups):
                greqs = groups.pop(shape)
                rows = jnp.stack([records[r.rid]["row"] for r in greqs])
                prompts = jnp.stack([jnp.asarray(r.prompt, jnp.int32)
                                     for r in greqs])
                t0 = time.perf_counter()
                toks = jax.block_until_ready(gen(rows, prompts))
                wall_ms = (time.perf_counter() - t0) * 1e3
                for i, r in enumerate(greqs):
                    records[r.rid]["tokens"] = np.asarray(toks[i])
                    records[r.rid]["decode_ms"] = wall_ms

        wall_s = time.perf_counter() - t_start
        return ServeReport(records=[records[r.rid] for r in reqs],
                           wall_s=wall_s, cache_stats=self.cache.stats())
