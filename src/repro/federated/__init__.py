from repro.federated.async_engine import (AsyncRoundEngine, PrefetchError,
                                          Prefetcher, StalenessConfig,
                                          WorkerPool, WorkerPoolError,
                                          call_with_retry)
from repro.federated.comm import CommTracker
from repro.federated.faults import FaultConfig
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.privacy import (DPConfig, add_gaussian_noise,
                                     clip_gradient, dp_aggregate,
                                     dp_clip_factors, masked_uploads,
                                     secure_sum)
from repro.kernels.meta_update.compress import CompressionConfig
from repro.federated.population import (CircuitBreaker, RoundPlan,
                                        UnreliabilityConfig, plan_round)
from repro.federated.serving import (AdaptationCache, ServeReport,
                                     ServeRequest, ServingEngine,
                                     TrafficModel, support_digest)
from repro.federated.server import FederatedTrainer, evaluate_meta, evaluate_global
from repro.federated.experiment import (ExperimentPlan, comm_to_target,
                                        default_plan, run_comparison)
