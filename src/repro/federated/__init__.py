from repro.federated.async_engine import (AsyncRoundEngine, Prefetcher,
                                          StalenessConfig)
from repro.federated.comm import CommTracker
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import FederatedTrainer, evaluate_meta, evaluate_global
from repro.federated.experiment import (ExperimentPlan, comm_to_target,
                                        default_plan, run_comparison)
