from repro.federated.comm import CommTracker
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import FederatedTrainer, evaluate_meta, evaluate_global
