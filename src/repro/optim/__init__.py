from repro.optim.optimizers import Optimizer, sgd, adam, adamw, clip_by_global_norm
