from repro.optim.optimizers import (Optimizer, adam, adamw,
                                    clip_by_global_norm, make_flat_optimizer,
                                    sgd)
