"""Fused outer-Adam Pallas kernel over the packed parameter plane.

Per-leaf XLA Adam is ~10 ops per tensor (two moment EMAs with upcasts,
bias corrections, rsqrt, the φ update) — each materialized separately.
On the flat plane the whole step is one pass: every grid step reads one
(block_rows, 128) tile of (φ, g, m, v), updates the moments, applies
bias correction and the parameter update, and writes (φ', m', v') back.
Bias-correction scales depend on the step count, so they are computed
outside and handed to the kernel as SMEM scalars.

``input_output_aliases`` aliases φ/m/v to the three outputs so the
update is in-place on TPU (the buffers are donated by the jitted
meta-step; see DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update.fused import LANE, SUBLANE, choose_block_rows


def _adam_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref,
                 po_ref, mo_ref, vo_ref, *, b1, b2, eps, lr, wd):
    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1.0 - b2) * g * g
    u = (m * sc_ref[0]) / (jnp.sqrt(v * sc_ref[1]) + eps)
    if wd > 0.0:
        u = u + wd * p
    po_ref[...] = (p - lr * u).astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)
    vo_ref[...] = v.astype(vo_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("lr", "b1", "b2", "eps", "wd", "interpret"))
def adam_flat_pallas(phi, g, m, v, scales, *, lr, b1, b2, eps, wd,
                     interpret: bool = False):
    """One fused Adam step on flat (N,) buffers; scales = (2,) f32 holding
    the bias-correction factors [1/(1−b1^t), 1/(1−b2^t)]."""
    (N,) = phi.shape
    assert N % (SUBLANE * LANE) == 0, N
    total_rows = N // LANE
    rows = choose_block_rows(total_rows)
    n_tiles = total_rows // rows

    spec = pl.BlockSpec((rows, LANE), lambda i: (i, 0))
    shape2d = (total_rows, LANE)
    kernel = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, lr=lr,
                               wd=wd)
    new_p, new_m, new_v = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct(shape2d, phi.dtype),
                   jax.ShapeDtypeStruct(shape2d, m.dtype),
                   jax.ShapeDtypeStruct(shape2d, v.dtype)],
        # φ, m, v update in place (inputs 1/3/4 -> outputs 0/1/2)
        input_output_aliases={1: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(scales.astype(jnp.float32), phi.reshape(shape2d), g.reshape(shape2d),
      m.reshape(shape2d), v.reshape(shape2d))
    return new_p.reshape(N), new_m.reshape(N), new_v.reshape(N)


def adam_flat_ref(phi, g, m, v, scales, *, lr, b1, b2, eps, wd):
    """Pure-jnp oracle for the fused kernel (single fused elementwise
    chain over the flat plane — still far fewer HLO ops than per-leaf)."""
    g = g.astype(jnp.float32)
    m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
    v = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
    u = (m * scales[0]) / (jnp.sqrt(v * scales[1]) + eps)
    if wd > 0.0:
        u = u + wd * phi.astype(jnp.float32)
    return (phi.astype(jnp.float32) - lr * u).astype(phi.dtype), m, v


def adam_flat_update(phi, g, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                     wd=0.0, state_dtype=jnp.float32, impl: str = "xla"):
    """One outer-Adam step on the packed plane.

    step: previous step count (int32 scalar); returns
    (phi', m', v', step+1) with moments in ``state_dtype``.
    """
    step = step + 1
    t = step.astype(jnp.float32)
    scales = jnp.stack([1.0 / (1.0 - b1 ** t), 1.0 / (1.0 - b2 ** t)])
    if impl == "xla":
        phi, m, v = adam_flat_ref(phi, g, m, v, scales, lr=lr, b1=b1, b2=b2,
                                  eps=eps, wd=wd)
    else:
        phi, m, v = adam_flat_pallas(
            phi, g, m, v, scales, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
            interpret=(impl == "pallas_interpret"))
    return phi, m.astype(state_dtype), v.astype(state_dtype), step
