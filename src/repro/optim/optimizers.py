"""Pure-JAX optimizers (no optax in this environment).

An Optimizer is an (init, update) pair over parameter pytrees:

    opt = adam(1e-3)
    state = opt.init(params)
    new_params, new_state = opt.update(params, grads, state)

States are pytrees so they shard/checkpoint like parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]
    name: str = "optimizer"
    # hyperparameter record ({"kind": ..., ...}) so wrappers like the
    # packed-plane fused Adam can rebuild the update without re-deriving
    # closure state; None for custom optimizers.
    hyper: Any = None


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params),
        }

    def update(params, grads, state):
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
            return new_params, {"step": state["step"] + 1}
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, mu)
        return new_params, {"step": state["step"] + 1, "mu": mu}

    return Optimizer(init, update, name=f"sgd(lr={lr},mom={momentum})",
                     hyper={"kind": "sgd", "lr": lr, "momentum": momentum})


def adam(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when weight_decay > 0).

    state_dtype: dtype of the m/v moments — bfloat16 halves optimizer
    memory (perf lever for the 340B config; see EXPERIMENTS.md §Perf)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=state_dtype), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=state_dtype), params),
        }

    def update(params, grads, state):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: (b1 * m_.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)
                           ).astype(state_dtype), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: (b2 * v_.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(state_dtype), state["v"], grads)
        mhat_scale = 1.0 / (1 - b1 ** t)
        vhat_scale = 1.0 / (1 - b2 ** t)

        def upd(p, m_, v_):
            u = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
            if weight_decay > 0.0:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, name=f"adam(lr={lr})",
                     hyper={"kind": "adam", "lr": lr, "b1": b1, "b2": b2,
                            "eps": eps, "weight_decay": weight_decay,
                            "state_dtype": state_dtype})


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def make_flat_optimizer(opt: Optimizer, *, impl: str = "xla") -> Optimizer:
    """Lift ``opt`` onto the packed parameter plane (flat (N,) params).

    Adam gets the single-pass fused update (``optim/fused_adam.py``) —
    one kernel / one fused elementwise chain instead of ~10 XLA ops per
    leaf. Any other optimizer falls back to itself: a flat buffer is a
    valid single-leaf pytree, so tree_map-based updates already work.
    """
    hyp = opt.hyper
    if not (isinstance(hyp, dict) and hyp.get("kind") == "adam"):
        return opt

    from repro.optim.fused_adam import adam_flat_update

    state_dtype = hyp["state_dtype"]

    def init(flat_phi):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jnp.zeros_like(flat_phi, dtype=state_dtype),
                "v": jnp.zeros_like(flat_phi, dtype=state_dtype)}

    def update(flat_phi, flat_g, state):
        phi, m, v, step = adam_flat_update(
            flat_phi, flat_g, state["m"], state["v"], state["step"],
            lr=hyp["lr"], b1=hyp["b1"], b2=hyp["b2"], eps=hyp["eps"],
            wd=hyp["weight_decay"], state_dtype=state_dtype, impl=impl)
        return phi, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, name=f"flat_{opt.name}[{impl}]",
                     hyper=hyp)


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm
