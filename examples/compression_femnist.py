"""Bytes-on-the-wire comparison (DESIGN.md §17): one FedMeta method on
femnist under four upload codecs, same split, same sampling stream,
same pinned target accuracy.

Variants (upload leg only; the download leg is always dense f32 φ):

  f32    dense float32 gradient block      4   B/param
  bf16   reduced-precision block           2   B/param
  int8   per-row-scaled int8 + EF          ~1  B/param (+4 B scale)
  topk   top-5% bf16 values + EF           0.3 B/param (k·(4+2) B)

The committed artifact (``results/experiments/compression_femnist.json``)
is the acceptance evidence for the compression plane: int8/topk reach the
pinned target at a fraction of the bf16 baseline's true transmitted
upload bytes, with accuracy inside the clean noise band
(tests/test_experiment_plane.py pins the claim from the JSON).

  # committed artifact:
  PYTHONPATH=src python examples/compression_femnist.py

  # CI smoke (few rounds, tiny pool, gitignored outdir):
  PYTHONPATH=src python examples/compression_femnist.py --dry-run
"""
import argparse
import json
import os

from repro.federated.experiment import default_plan, run_comparison
from repro.kernels.meta_update.compress import CompressionConfig

# femnist fomaml reaches 0.12 sustained within a few rounds (see the
# committed femnist_compare.json: the shared target there is 0.121)
TARGET_ACC = 0.12
METHOD = "fomaml"

VARIANTS = {
    "f32": {},
    "bf16": dict(block_dtype="bfloat16"),
    "int8+ef": dict(compression=CompressionConfig("int8")),
    # top-k values ride the bf16 wire dtype: 0.05·(4+2) = 0.3 B/param
    "topk0.05+ef": dict(compression=CompressionConfig("topk",
                                                      topk_frac=0.05),
                        block_dtype="bfloat16"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--outdir", default="results/experiments")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny rounds/pool for CI smoke")
    args = ap.parse_args()
    rounds, num_clients, target = args.rounds, 100, TARGET_ACC
    if args.dry_run:
        rounds, num_clients, target = 4, 24, None
        if args.outdir == "results/experiments":
            args.outdir = "results/experiments-smoke"

    variants = {}
    for label, knobs in VARIANTS.items():
        plan = default_plan(
            "femnist", methods=(METHOD,), rounds=rounds,
            eval_every=args.eval_every, num_clients=num_clients,
            target_acc=target, pipeline="packed", seed=args.seed,
            name=f"compression_{label}", **knobs)
        out = run_comparison(plan, save=False, log=print)
        rec = out["methods"][METHOD]
        row = (out["comm_to_target"] or {}).get(METHOD)
        cfg = knobs.get("compression")
        variants[label] = {
            "plan_overrides": {
                k: (v if not isinstance(v, CompressionConfig)
                    else v.__dict__) for k, v in knobs.items()},
            "history": rec["history"],
            "test_acc": rec["test_acc"],
            "comm": rec["comm"],
            "comm_to_target": row,
        }
        print(f"[{label}] test_acc={rec['test_acc']:.4f} "
              f"upload_MB={rec['comm']['upload_MB']:.2f}"
              + (f" to-target upload_MB={row['upload_MB']:.2f} "
                 f"@round {row['rounds']}" if row else " (target missed)"))

    # the headline: true transmitted upload bytes to the pinned target,
    # each codec vs the bf16 baseline path
    ratios = {}
    base = variants["bf16"]["comm_to_target"]
    for label, v in variants.items():
        row = v["comm_to_target"]
        if base and row and row["upload_MB"] > 0:
            ratios[label] = round(base["upload_MB"] / row["upload_MB"], 2)

    out = {
        "dataset": "femnist", "method": METHOD,
        "target_acc": target, "rounds": rounds,
        "seed": args.seed, "sustain_evals": 2,
        "baseline": "bf16",
        "variants": variants,
        "upload_to_target_ratio_vs_bf16": ratios,
    }
    os.makedirs(args.outdir, exist_ok=True)
    path = os.path.join(args.outdir, "compression_femnist.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    print("upload-bytes-to-target vs bf16:", ratios)


if __name__ == "__main__":
    main()
