"""The paper's headline experiment (Fig. 3 / §4): FedMeta vs FedAvg on a
shared client split, sampling stream, and communication budget.

Runs FedMeta (MAML / FOMAML / Meta-SGD, optionally Reptile) against
FedAvg and FedAvg(Meta) through the experiment plane
(`repro.federated.experiment`), records per-round comm/accuracy curves,
and prints the comm-to-target-accuracy table. JSON artifacts land under
``results/experiments/``.

  PYTHONPATH=src python examples/compare_fedmeta_fedavg.py \
      --datasets femnist,sent140 --rounds 60 --eval-every 5

  # CI smoke (few rounds, tiny client pools, both datasets):
  PYTHONPATH=src python examples/compare_fedmeta_fedavg.py --dry-run
"""
import argparse

from repro.federated.experiment import (DEFAULT_METHODS, default_plan,
                                        format_table, run_comparison)
from repro.federated.faults import FaultConfig
from repro.federated.privacy import DPConfig
from repro.kernels.meta_update.compress import CompressionConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="femnist,sent140")
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS))
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--clients", type=int, default=0,
                    help="override registry client-pool size")
    ap.add_argument("--support-frac", type=float, default=None,
                    help="override the per-dataset registry default")
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--target-acc", type=float, default=None,
                    help="fixed target accuracy (default: highest "
                         "accuracy every method reaches)")
    ap.add_argument("--pipeline", default="tree",
                    choices=["tree", "packed", "client_plane"])
    ap.add_argument("--client-chunk", type=int, default=0)
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="async round engine: staged round blocks ahead "
                         "of the device (0 = synchronous loop; history "
                         "is bit-identical either way)")
    ap.add_argument("--flush-every", type=int, default=1,
                    help="deferred-metrics drain cadence (0 = at exit)")
    ap.add_argument("--fuse-rounds", type=int, default=1,
                    help="lax.scan round-block size (packed pipelines)")
    ap.add_argument("--aggregator", default="mean",
                    choices=["mean", "masked_mean", "screen", "trimmed"],
                    help="FedMeta (m, N) aggregation mode (DESIGN.md "
                         "§14; non-mean needs a packed pipeline)")
    ap.add_argument("--fault-dropout", type=float, default=0.0,
                    help="fraction of each round's clients whose update "
                         "never arrives (fault injection)")
    ap.add_argument("--fault-byzantine", type=float, default=0.0,
                    help="fraction of Byzantine (sign-flip) clients")
    ap.add_argument("--fault-nonfinite", type=float, default=0.0,
                    help="fraction of clients uploading NaN gradients")
    ap.add_argument("--lazy-population", action="store_true",
                    help="serve clients from the lazy ClientRegistry "
                         "(sequential mode: bit-identical to eager)")
    ap.add_argument("--cache-clients", type=int, default=0,
                    help="LRU cap on resident lazy clients (0 = "
                         "unbounded)")
    ap.add_argument("--over-select", type=float, default=0.0,
                    help="sample m·(1+x) candidates per round, "
                         "aggregate the first m arrivals (FedMeta "
                         "methods; needs a packed pipeline)")
    ap.add_argument("--round-deadline", type=float, default=0.0,
                    help="arrival latency cutoff, in unreliability "
                         "units (0 = no deadline)")
    ap.add_argument("--unreliable-fail-rate", type=float, default=0.0,
                    help="per-(client, round) transient failure "
                         "probability of the arrival model")
    ap.add_argument("--pool-workers", type=int, default=0,
                    help="shard-materializing worker threads "
                         "(0 = inline)")
    ap.add_argument("--eval-clients-cap", type=int, default=0,
                    help="cap on val/test eval cohort size (large lazy "
                         "populations)")
    ap.add_argument("--codec", default="",
                    choices=["", "int8", "topk"],
                    help="FedMeta upload compression (DESIGN.md §17; "
                         "needs a packed pipeline)")
    ap.add_argument("--topk-frac", type=float, default=0.05,
                    help="fraction of real parameters each client "
                         "transmits under --codec topk")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the per-client EF residual state")
    ap.add_argument("--block-dtype", default="",
                    help="packed gradient-block wire dtype (e.g. "
                         "bfloat16; also the top-k value dtype)")
    ap.add_argument("--opt-state-dtype", default="",
                    help="fused-Adam m/v state dtype (e.g. bfloat16 — "
                         "dequantized in-kernel)")
    ap.add_argument("--dp-clip-norm", type=float, default=0.0,
                    help="central-DP per-client L2 clip (0 = off)")
    ap.add_argument("--dp-noise-multiplier", type=float, default=0.0,
                    help="central-DP noise multiplier z (σ = z·S/m)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--outdir", default="results/experiments")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny rounds/pools for CI smoke")
    args = ap.parse_args()

    over = dict(methods=tuple(args.methods.split(",")), rounds=args.rounds,
                eval_every=args.eval_every,
                local_steps=args.local_steps, target_acc=args.target_acc,
                pipeline=args.pipeline,
                client_chunk=args.client_chunk or None, seed=args.seed,
                prefetch_depth=args.prefetch_depth,
                flush_every=args.flush_every, fuse_rounds=args.fuse_rounds)
    if args.aggregator != "mean":
        over["aggregator"] = args.aggregator
    if args.fault_dropout or args.fault_byzantine or args.fault_nonfinite:
        over["faults"] = FaultConfig(dropout=args.fault_dropout,
                                     byzantine=args.fault_byzantine,
                                     nonfinite=args.fault_nonfinite)
    if args.lazy_population:
        over.update(lazy_population=True,
                    cache_clients=args.cache_clients or None)
    if args.over_select:
        over["over_select"] = args.over_select
    if args.round_deadline:
        over["round_deadline"] = args.round_deadline
    if args.unreliable_fail_rate:
        from repro.federated.population import UnreliabilityConfig
        over["unreliability"] = UnreliabilityConfig(
            fail_rate=args.unreliable_fail_rate, seed=args.seed)
    if args.pool_workers:
        over["pool_workers"] = args.pool_workers
    if args.codec:
        over["compression"] = CompressionConfig(
            args.codec, topk_frac=args.topk_frac,
            error_feedback=not args.no_error_feedback)
    if args.block_dtype:
        over["block_dtype"] = args.block_dtype
    if args.opt_state_dtype:
        over["opt_state_dtype"] = args.opt_state_dtype
    if args.dp_clip_norm:
        over["dp"] = DPConfig(clip_norm=args.dp_clip_norm,
                              noise_multiplier=args.dp_noise_multiplier,
                              seed=args.seed)
    if args.eval_clients_cap:
        over["eval_clients_cap"] = args.eval_clients_cap
    if args.clients:
        over["num_clients"] = args.clients
    if args.support_frac is not None:
        over["support_frac"] = args.support_frac
    if args.dry_run:
        # smoke names + smoke outdir (unless overridden): a dry run must
        # not overwrite the committed full-run artifacts under
        # results/experiments/
        over.update(rounds=4, eval_every=2, num_clients=24)
        if args.outdir == "results/experiments":
            args.outdir = "results/experiments-smoke"

    for dataset in args.datasets.split(","):
        plan = default_plan(
            dataset, **over,
            **({"name": f"{dataset}_smoke"} if args.dry_run else {}))
        out = run_comparison(plan, out_dir=args.outdir, log=print)
        print(f"\n=== {dataset} (pipeline={plan.pipeline}, "
              f"rounds={plan.rounds}) ===")
        print(format_table(out))
        print()


if __name__ == "__main__":
    main()
