"""Quickstart: FedMeta vs FedAvg on the synthetic Sent140 federated
dataset in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import classification_loss, make_algorithm
from repro.data import make_sent140
from repro.federated.fedavg import FedAvgTrainer
from repro.federated.server import (FederatedTrainer, evaluate_global,
                                    evaluate_meta)
from repro.models.paper import sent_lstm
from repro.optim import adam


def main():
    # 1. A federated dataset: each twitter user is a client (= a task).
    ds = make_sent140(num_clients=60, seed=0)
    train, val, test = ds.split_clients(seed=0)
    print(f"dataset: {ds.stats()}")

    # 2. A model + the FedMeta algorithm (paper Algorithm 1).
    model = sent_lstm(vocab=2000, hidden=32, embed_dim=16)
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm("maml", loss_fn, eval_fn, inner_lr=0.01)

    # 3. Meta-train: each round samples 4 clients, collects meta-gradients.
    trainer = FederatedTrainer(algo, adam(1e-3), train, clients_per_round=4,
                               support_frac=0.2, support_size=16,
                               query_size=16)
    state = trainer.init(jax.random.PRNGKey(0), model.init)
    state = trainer.run(state, rounds=120)

    # 4. Evaluate on unseen clients: adapt on support, test on query.
    acc, _, _ = evaluate_meta(algo, trainer.phi_tree(state), test,
                              support_frac=0.2, support_size=16,
                              query_size=16)
    print(f"FedMeta(MAML) test accuracy on new clients: {acc:.3f}")
    print(f"communication so far: {trainer.comm.summary()}")

    # 5. The FedAvg baseline — same split, same sampling stream, same
    # communication accounting (the experiment plane runs this at scale;
    # see examples/compare_fedmeta_fedavg.py).
    fedavg = FedAvgTrainer(loss_fn, eval_fn, local_lr=1e-3, local_steps=3,
                           train_clients=train, clients_per_round=4,
                           support_frac=0.2, support_size=16, query_size=16)
    fa_state = fedavg.init(jax.random.PRNGKey(0), model.init)
    fa_state = fedavg.run(fa_state, rounds=120)
    fa_acc, _, _ = evaluate_global(eval_fn, fa_state["theta"], test,
                                   support_frac=0.2, support_size=16,
                                   query_size=16)
    print(f"FedAvg test accuracy on new clients:       {fa_acc:.3f}")
    print(f"communication so far: {fedavg.comm.summary()}")


if __name__ == "__main__":
    main()
