"""The paper's production recommendation scenario (§4.3 / Table 3)
through the scenario plane: FedMeta's small LOCAL-head recommender vs
FedAvg's GLOBAL-service classifier, on one shared client split and
sampling stream, with per-method θ-size communication accounting and
fairness (per-client accuracy distribution) blocks in the artifact.

The paper's point is a size asymmetry: a production service has a huge
catalogue (2,400 services; 2,420-way unified classifier), but each
client only ever uses a handful (2–36), so FedMeta can ship a model
whose head covers just the client's own services (40-way) — fewer bytes
per round AND a better-conditioned per-client problem. The scenario
plane makes both halves measurable: `CommTracker` charges each method
its own θ bytes, and the comm-to-target table reports bytes — not
rounds — to the shared target.

  PYTHONPATH=src python examples/table3_production.py --rounds 60

  # CI smoke (few rounds, tiny pools):
  PYTHONPATH=src python examples/table3_production.py --dry-run

For the non-federated Table-3 baselines (MFU/MRU/NB/LR-self/NN-self and
the unified fine-tuned NN), see ``benchmarks/table3_production.py``.
"""
import argparse

from repro.federated.experiment import (DEFAULT_METHODS, default_plan,
                                        format_table, run_comparison)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS))
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--clients", type=int, default=0,
                    help="override registry client-pool size")
    ap.add_argument("--local-head", type=int, default=0,
                    help="override the FedMeta head width (registry: 40)")
    ap.add_argument("--target-acc", type=float, default=None,
                    help="fixed target accuracy (default: highest "
                         "accuracy every method sustainably reaches)")
    ap.add_argument("--pipeline", default="tree",
                    choices=["tree", "packed", "client_plane"])
    ap.add_argument("--prefetch-depth", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--outdir", default="results/experiments")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny rounds/pools for CI smoke")
    args = ap.parse_args()

    over = dict(methods=tuple(args.methods.split(",")), rounds=args.rounds,
                eval_every=args.eval_every, target_acc=args.target_acc,
                pipeline=args.pipeline, prefetch_depth=args.prefetch_depth,
                seed=args.seed)
    if args.clients:
        over["num_clients"] = args.clients
    if args.local_head:
        over["local_head"] = args.local_head
    if args.dry_run:
        # smoke name + smoke outdir (unless overridden): a dry run must
        # not overwrite — or sit next to — the committed full-run
        # recommend_compare.json
        over.update(rounds=4, eval_every=2, num_clients=24,
                    name="recommend_smoke")
        if args.outdir == "results/experiments":
            args.outdir = "results/experiments-smoke"

    plan = default_plan("recommend", **over)
    out = run_comparison(plan, out_dir=args.outdir, log=print)

    print(f"\n=== recommend (local_head={plan.local_head}, "
          f"rounds={plan.rounds}) ===")
    print(format_table(out))
    print("\nper-method model size + fairness (per-client accuracy "
          "distribution at final eval):")
    for m, res in out["methods"].items():
        f, c = res["fairness"], res["comm"]
        print(f"  {m:<14} phi_MB={c['phi_MB']:.4f}  mean={f['mean']:.4f}  "
              f"var={f['variance']:.5f}  worst10%={f['worst10_mean']:.4f}  "
              f"p10={f['deciles'][0]:.4f}  p90={f['deciles'][-1]:.4f}")


if __name__ == "__main__":
    main()
