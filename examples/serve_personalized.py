"""Personalized serving (the deployment path of paper §3.2): adapt the
meta-learned initialization to a client's support set, then serve batched
decode requests against a prefilled KV cache — the same prefill/decode
entry points the dry-run lowers at production scale.

  PYTHONPATH=src python examples/serve_personalized.py --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import make_algorithm
from repro.core.losses import lm_loss
from repro.launch.steps import make_apply_fn, make_decode_step, make_prefill_step
from repro.models import init_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    rng = np.random.RandomState(0)
    params = init_lm(jax.random.PRNGKey(0), cfg)

    # ---- 1. per-client adaptation (FedMeta deployment step)
    loss_fn, eval_fn = lm_loss(make_apply_fn(cfg))
    algo = make_algorithm("fomaml", loss_fn, eval_fn, inner_lr=0.05)
    support = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 64)), jnp.int32)
    theta_u = algo.adapt({"theta": params}, support)
    print(f"adapted {cfg.name} to client support set "
          f"({support.shape[0]} sequences)")

    # ---- 2. batched prefill
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    logits, cache = prefill(theta_u, {"tokens": prompts})
    next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    print(f"prefilled {args.batch} requests x {args.prompt_len} tokens; "
          f"cache length = {int(cache['length'])}")

    # ---- 3. decode loop
    out = [next_tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = decode(theta_u, cache, next_tok)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(next_tok)
    dt = (time.perf_counter() - t0) / (args.tokens - 1)
    gen = jnp.concatenate(out, axis=1)
    print(f"generated {gen.shape} tokens, {dt*1e3:.1f} ms/token/batch "
          f"(CPU reduced config)")
    print("sample:", np.asarray(gen[0])[:12].tolist())


if __name__ == "__main__":
    main()
