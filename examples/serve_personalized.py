"""Personalized serving (the deployment path of paper §3.2) through the
adaptation-on-demand engine: seeded synthetic traffic hits a
`ServingEngine` that batches support-set adaptations on the training
kernel's (chunk, N) plane, caches adapted rows per client, and serves
each request's prompt through prefill + decode under its own θ_u.

  PYTHONPATH=src python examples/serve_personalized.py --tokens 8
  PYTHONPATH=src python examples/serve_personalized.py --dry-run   # CI
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.federated.serving import TrafficModel
from repro.launch.serve import build_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--adapt-batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="smallest settings that still cover "
                         "adapt -> cache -> prefill -> decode (CI smoke)")
    args = ap.parse_args()

    if args.dry_run:
        args.requests, args.clients = 4, 2
        args.prompt_len, args.tokens = 8, 2

    cfg = reduced_config(get_config(args.arch))
    engine = build_engine(cfg, adapt_batch=args.adapt_batch, seed=args.seed)

    traffic = TrafficModel(num_clients=args.clients, rate=16.0,
                           support_sizes=(2, 4), think_time=0.01,
                           seed=args.seed)
    make_support = lambda r, size: jnp.asarray(
        r.randint(0, cfg.vocab_size, (size, 32)), jnp.int32)
    make_prompt = lambda r: jnp.asarray(
        r.randint(0, cfg.vocab_size, (args.prompt_len,)), jnp.int32)
    requests = traffic.requests(args.requests, make_support, make_prompt)
    print(f"{cfg.name}: {len(requests)} requests from "
          f"{args.clients} clients (Poisson arrivals, per-client support)")

    report = engine.serve(requests, max_new_tokens=args.tokens)
    s = report.summary()
    print(f"served {s['requests']} requests: {s['hits']} cache hits, "
          f"{s['misses']} adaptations "
          f"(p50 {s['adapt_p50_ms']:.1f} ms, p99 {s['adapt_p99_ms']:.1f} ms)")
    print(f"decode p50 {s.get('decode_p50_ms', 0.0):.1f} ms for "
          f"{args.tokens} tokens; {s['requests_per_s']:.2f} req/s "
          f"(CPU reduced config, cold compile included)")
    print("sample:", np.asarray(report.records[0]["tokens"]).tolist())


if __name__ == "__main__":
    main()
