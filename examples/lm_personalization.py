"""FedMeta on a language model (bridges the paper to the assigned
architectures): meta-train a reduced SmolLM so it adapts to a new
client's token "dialect" in one gradient step; report per-client NLL
before and after adaptation.

  PYTHONPATH=src python examples/lm_personalization.py --rounds 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.lm_tasks import make_lm_task_batch
from repro.launch.steps import make_train_step
from repro.core.losses import lm_loss
from repro.launch.steps import make_apply_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--algo", default="fomaml")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    print(f"arch: {cfg.name} ({cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size})")
    train_step, init_state, algo, _ = make_train_step(
        cfg, algo_name=args.algo, inner_lr=0.1, outer_lr=3e-3)
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(train_step)

    for r in range(args.rounds):
        tasks = make_lm_task_batch(args.clients, 2, 2, args.seq_len,
                                   cfg.vocab_size, seed=r)
        batch = {
            "support": {"tokens": jnp.asarray(tasks.support_tokens)[None]},
            "query": {"tokens": jnp.asarray(tasks.query_tokens)[None]},
        }
        state, metrics = step(state, batch)
        if (r + 1) % 10 == 0:
            print(f"round {r+1:3d}  query_nll="
                  f"{float(metrics['nll']):.4f}  query_acc="
                  f"{float(metrics['accuracy']):.4f}")

    # ---- adapt to a brand-new client and measure the NLL drop
    loss_fn, eval_fn = lm_loss(make_apply_fn(cfg))
    new = make_lm_task_batch(1, 2, 2, args.seq_len, cfg.vocab_size,
                             seed=10_000)
    sup = jnp.asarray(new.support_tokens[0])
    qry = jnp.asarray(new.query_tokens[0])
    before, m0 = eval_fn(state["phi"]["theta"], qry)
    theta_u = algo.adapt(state["phi"], sup)
    after, m1 = eval_fn(theta_u, qry)
    print(f"new client:  NLL before adapt {float(m0['nll']):.4f} -> "
          f"after {float(m1['nll']):.4f}  "
          f"(acc {float(m0['accuracy']):.3f} -> {float(m1['accuracy']):.3f})")


if __name__ == "__main__":
    main()
