"""End-to-end driver (deliverable b): meta-train the paper's FEMNIST CNN
with FedMeta for a few hundred rounds, with periodic evaluation,
checkpointing, communication accounting, and a FedAvg baseline run on
the same client split through the experiment plane — the full
Figure-2-style experiment at CPU scale.

  PYTHONPATH=src python examples/femnist_fedmeta.py --rounds 300 \
      --algo meta-sgd --ckpt /tmp/fedmeta_femnist
"""
import argparse
import json

import jax

from repro.checkpoint import save_server_state
from repro.core import classification_loss, make_algorithm
from repro.data import make_femnist
from repro.federated.experiment import (comm_to_target, default_plan,
                                        make_trainer)
from repro.federated.server import FederatedTrainer, evaluate_meta, \
    evaluate_global
from repro.models.paper import femnist_cnn
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--algo", default="maml",
                    choices=["maml", "fomaml", "meta-sgd", "reptile"])
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--support-frac", type=float, default=0.2)
    ap.add_argument("--inner-lr", type=float, default=0.01)
    ap.add_argument("--outer-lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/fedmeta_femnist")
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--packed", action="store_true",
                    help="run FedMeta on the packed parameter plane")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the FedAvg baseline comparison")
    args = ap.parse_args()

    ds = make_femnist(num_clients=args.clients, mean_samples=60, seed=0)
    train, val, test = ds.split_clients(seed=0)
    print("dataset:", json.dumps(ds.stats()))

    model = femnist_cnn(num_classes=62, hidden=128)
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm(args.algo, loss_fn, eval_fn,
                          inner_lr=args.inner_lr)
    trainer = FederatedTrainer(algo, adam(args.outer_lr), train,
                               clients_per_round=args.clients_per_round,
                               support_frac=args.support_frac,
                               support_size=16, query_size=16,
                               packed=args.packed)
    state = trainer.init(jax.random.PRNGKey(0), model.init)
    flops = trainer.measure_flops(state)
    print(f"client procedure: {flops/1e9:.2f} GFLOPs / client / round")

    for start in range(0, args.rounds, args.eval_every):
        n = min(args.eval_every, args.rounds - start)
        state = trainer.run(state, n)
        # phi_tree() — NOT state["phi"] — so the packed pipeline (flat φ
        # buffer) evaluates identically to the tree pipeline
        acc, _, _ = evaluate_meta(algo, trainer.phi_tree(state), val,
                                  support_frac=args.support_frac,
                                  support_size=16, query_size=16,
                                  evaluator=trainer.evaluator())
        trainer.history[-1]["eval_acc"] = acc
        path = save_server_state(args.ckpt, start + n, state)
        print(f"round {start+n:4d}  val_acc={acc:.4f}  "
              f"{trainer.comm.summary()}  ckpt={path}")

    test_acc, per_client, _ = evaluate_meta(algo, trainer.phi_tree(state),
                                            test,
                                            support_frac=args.support_frac,
                                            support_size=16, query_size=16,
                                            evaluator=trainer.evaluator())
    print(f"FINAL: FedMeta({args.algo}) test acc = {test_acc:.4f} "
          f"(min client {per_client.min():.3f}, "
          f"max {per_client.max():.3f})")

    if args.no_baseline:
        return

    # FedAvg baseline on the SAME split/stream via the experiment plane
    plan = default_plan("femnist", rounds=args.rounds,
                        eval_every=args.eval_every, num_clients=args.clients,
                        clients_per_round=args.clients_per_round,
                        support_frac=args.support_frac)
    fa = make_trainer(plan, "fedavg", loss_fn, eval_fn, train)
    fa_state = fa.init(jax.random.PRNGKey(0), model.init)
    fa.measure_flops(fa_state)
    fa_state = fa.run(fa_state, args.rounds, eval_every=args.eval_every,
                      eval_clients=val)
    fa_acc, _, _ = evaluate_global(eval_fn, fa_state["theta"], test,
                                   support_frac=args.support_frac,
                                   support_size=16, query_size=16,
                                   evaluator=fa.evaluator())
    print(f"BASELINE: FedAvg test acc = {fa_acc:.4f}  {fa.comm.summary()}")
    target = min(acc, max((r.get("eval_acc") or 0.0) for r in fa.history))
    fmt = lambda row: f"{row['comm_MB']:.2f}MB@r{row['rounds']}" if row \
        else "not reached"  # noqa: E731
    print(f"comm to target_acc={target:.4f}: "
          f"FedMeta={fmt(comm_to_target(trainer.history, target))} "
          f"FedAvg={fmt(comm_to_target(fa.history, target))}")


if __name__ == "__main__":
    main()
