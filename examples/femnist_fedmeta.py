"""End-to-end driver (deliverable b): meta-train the paper's FEMNIST CNN
with FedMeta for a few hundred rounds, with periodic evaluation,
checkpointing, communication accounting, and a FedAvg baseline — the
full Figure-2-style experiment at CPU scale.

  PYTHONPATH=src python examples/femnist_fedmeta.py --rounds 300 \
      --algo meta-sgd --ckpt /tmp/fedmeta_femnist
"""
import argparse
import json

import jax

from repro.checkpoint import save_server_state
from repro.core import classification_loss, make_algorithm
from repro.data import make_femnist
from repro.federated.server import FederatedTrainer, evaluate_meta
from repro.models.paper import femnist_cnn
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--algo", default="maml",
                    choices=["maml", "fomaml", "meta-sgd", "reptile"])
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--support-frac", type=float, default=0.2)
    ap.add_argument("--inner-lr", type=float, default=0.01)
    ap.add_argument("--outer-lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/fedmeta_femnist")
    ap.add_argument("--eval-every", type=int, default=50)
    args = ap.parse_args()

    ds = make_femnist(num_clients=args.clients, mean_samples=60, seed=0)
    train, val, test = ds.split_clients(seed=0)
    print("dataset:", json.dumps(ds.stats()))

    model = femnist_cnn(num_classes=62, hidden=128)
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm(args.algo, loss_fn, eval_fn,
                          inner_lr=args.inner_lr)
    trainer = FederatedTrainer(algo, adam(args.outer_lr), train,
                               clients_per_round=args.clients_per_round,
                               support_frac=args.support_frac,
                               support_size=16, query_size=16)
    state = trainer.init(jax.random.PRNGKey(0), model.init)
    flops = trainer.measure_flops(state)
    print(f"client procedure: {flops/1e9:.2f} GFLOPs / client / round")

    for start in range(0, args.rounds, args.eval_every):
        n = min(args.eval_every, args.rounds - start)
        state = trainer.run(state, n)
        acc, _ = evaluate_meta(algo, state["phi"], val,
                               support_frac=args.support_frac,
                               support_size=16, query_size=16)
        path = save_server_state(args.ckpt, start + n, state)
        print(f"round {start+n:4d}  val_acc={acc:.4f}  "
              f"{trainer.comm.summary()}  ckpt={path}")

    test_acc, per_client = evaluate_meta(algo, state["phi"], test,
                                         support_frac=args.support_frac,
                                         support_size=16, query_size=16)
    print(f"FINAL: FedMeta({args.algo}) test acc = {test_acc:.4f} "
          f"(min client {per_client.min():.3f}, "
          f"max {per_client.max():.3f})")


if __name__ == "__main__":
    main()
